"""Quickstart: OMFS scheduling a multi-tenant workload (pure simulation).

Shows Algorithm 1 end-to-end on a 128-CPU cluster with three tenants:
  * A (50%) — bursty, submits late, must reclaim immediately,
  * B (30%) — floods the machine with checkpointable jobs,
  * C (20%) — a few non-preemptible jobs (never over-entitlement).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core.metrics import compute_metrics
from repro.core.simulator import simulate
from repro.core.types import Job, JobClass, SchedulerConfig, User

USERS = [User("A", 50.0), User("B", 30.0), User("C", 20.0)]


def build_jobs():
    jobs = []
    # B floods at t=0 with checkpointable jobs (beyond its 30%)
    for i in range(6):
        jobs.append(Job(user="B", cpus=24, work=400, priority=i,
                        job_class=JobClass.CHECKPOINTABLE, submit_time=0))
    # C runs non-preemptible within its entitlement
    jobs.append(Job(user="C", cpus=16, work=300,
                    job_class=JobClass.NON_PREEMPTIBLE, submit_time=10))
    # A arrives late and claims its half of the machine
    jobs.append(Job(user="A", cpus=48, work=200,
                    job_class=JobClass.CHECKPOINTABLE, submit_time=120))
    return jobs


def main():
    cfg = SchedulerConfig(cpu_total=128, quantum=30, cr_overhead=5)
    res = simulate(USERS, build_jobs(), cfg, horizon=900)
    m = compute_metrics(res)

    print("=== OMFS quickstart ===")
    print(f"utilization          : {m.utilization:.3f}")
    print(f"jain fairness        : {m.jain_fairness:.3f}")
    print(f"checkpoint preemptions: {m.checkpoints}")
    claim = [j for j in res.state.jobs.values() if j.user == "A"][0]
    print(f"A's reclaim latency  : {claim.first_start - claim.submit_time} ticks")

    # ASCII utilization timeline per user
    print("\nper-user CPUs over time (every 30 ticks):")
    print(f"{'tick':>6s}  " + "  ".join(f"{u:>4s}" for u in ("A", "B", "C")) + "   busy")
    for t in range(0, len(res.log), 30):
        tick = res.log[t]
        row = "  ".join(f"{tick.per_user_cpus.get(u, 0):4d}" for u in ("A", "B", "C"))
        bar = "#" * (tick.busy // 4)
        print(f"{t:6d}  {row}   {bar}")

    print("\neviction/checkpoint decisions around A's arrival:")
    for tick in res.log[118:126]:
        for d in tick.decisions:
            if d.admitted and (d.checkpointed or d.killed):
                print(f"  t={tick.time}: job{d.job_id} admitted; "
                      f"checkpointed={d.checkpointed} killed={d.killed}")


if __name__ == "__main__":
    main()
