"""Fleet-scale what-if study with the vectorized JAX scheduler.

Simulates a 4096-chip fleet with 8 tenants and ~2000 jobs under OMFS and
under usage capping, using the jitted lax scheduler (`core.omfs_jax`) —
the Python reference would take minutes; the JAX simulator does it in
seconds (including compile).  Prints utilization and per-tenant shares.

Run:  PYTHONPATH=src python examples/multi_tenant_fleet.py
"""
import time

import jax
import numpy as np

from repro.core import omfs_jax
from repro.core.baselines import ALL_BASELINES
from repro.core.metrics import compute_metrics
from repro.core.simulator import simulate
from repro.core.types import SchedulerConfig
from repro.core.workload import WorkloadSpec, make_jobs, make_users


def main():
    spec = WorkloadSpec(
        n_users=8, horizon=400, cpu_total=4096, seed=2,
        arrival_rate=0.25, mean_work=80, burstiness=1.0,
    )
    users = make_users(spec)
    jobs = make_jobs(spec, users)[:2000]
    cfg = SchedulerConfig(cpu_total=4096, quantum=15, cr_overhead=2)
    print(f"fleet: {cfg.cpu_total} chips, {len(users)} tenants, {len(jobs)} jobs, "
          f"horizon {spec.horizon} ticks")

    t0 = time.perf_counter()
    tbl, busy = omfs_jax.simulate_jax(users, jobs, cfg, spec.horizon,
                                      pass_depth=64)
    jax.block_until_ready(busy)
    dt = time.perf_counter() - t0
    busy = np.asarray(busy)
    print(f"\nOMFS (JAX simulator): {dt:.1f}s wall ({spec.horizon/dt:.0f} ticks/s)")
    print(f"  mean utilization: {busy.mean()/cfg.cpu_total:.3f}")
    t = np.asarray(tbl.state)
    print(f"  jobs done: {(t == omfs_jax.DONE).sum()}, killed: "
          f"{(t == omfs_jax.KILLED).sum()}, "
          f"checkpoints: {int(np.asarray(tbl.n_ckpt).sum())}")

    # utilization timeline
    print("\n  utilization timeline (every 20 ticks):")
    for i in range(0, spec.horizon, 20):
        frac = busy[i] / cfg.cpu_total
        print(f"  t={i:4d} {'#' * int(frac * 50):<50s} {frac:.2f}")

    # capping baseline via the Python reference on a smaller slice
    small = [j.clone() for j in jobs[:400]]
    res = simulate(users, small, cfg, spec.horizon,
                   policy=ALL_BASELINES["capping"])
    m_cap = compute_metrics(res)
    res = simulate(users, [j.clone() for j in small], cfg, spec.horizon)
    m_omfs = compute_metrics(res)
    print(f"\n400-job cross-check (Python ref): OMFS util {m_omfs.utilization:.3f} "
          f"vs capping {m_cap.utilization:.3f}")


if __name__ == "__main__":
    main()
