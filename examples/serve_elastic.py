"""Serving + elastic restart demo.

1. Serve a small LM: batched prefill then a greedy decode loop (the same
   prefill/decode step functions the dry-run lowers for the decode cells).
2. Elastic restart: checkpoint the server's weights, "lose" the process,
   restore onto a fresh template — generations continue identically.

Run:  PYTHONPATH=src python examples/serve_elastic.py
"""
import tempfile
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager, ManagerConfig
from repro.configs.base import ModelConfig
from repro.models.model import build_model


def build():
    cfg = ModelConfig(name="serve-lm", family="dense", n_layers=4,
                      d_model=128, n_heads=8, n_kv_heads=4, d_ff=512,
                      vocab=2048)
    return cfg, build_model(cfg, q_chunk=64, kv_chunk=64)


def generate(model, params, prompts, steps=16):
    b, s = prompts.shape
    cache = model.init_cache(b, s + steps)
    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)
    cache, logits = prefill(params, {"tokens": prompts}, cache)
    toks = []
    t0 = time.perf_counter()
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    for _ in range(steps):
        toks.append(tok)
        cache, logits = decode(params, cache, tok)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    return jnp.concatenate(toks, axis=1), b * steps / dt


def main():
    cfg, model = build()
    params = model.init(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 12), 0, cfg.vocab)

    out, tps = generate(model, params, prompts)
    print(f"served batch of {prompts.shape[0]}: {tps:.0f} tok/s (1 CPU core)")
    print("generations:\n", np.asarray(out))

    # ---- elastic restart: save, 'crash', restore onto a fresh template ----
    tmp = Path(tempfile.mkdtemp(prefix="serve_"))
    mgr = CheckpointManager(ManagerConfig(root=tmp, durable_every=1,
                                          async_durable=False))
    mgr.save(0, params)
    del params                                     # the 'node failure'

    cfg2, model2 = build()                         # fresh process
    template = model2.param_shapes()
    params2, name = mgr.restore(template)
    out2, _ = generate(model2, params2, prompts)
    same = bool((out == out2).all())
    print(f"\nrestored from {name}; generations identical: {same}")
    assert same
    mgr.close()


if __name__ == "__main__":
    main()
