"""End-to-end driver: real LM training under OMFS with live transparent C/R.

Two tenants share one device pool.  Tenant B trains an LM beyond its
entitlement; tenant A's job arrives mid-run and claims its share — B's job
is checkpointed to the fast tier, evicted, restored later, and finishes with
a loss curve **bitwise identical** to an uninterrupted run (verified at the
end — this is the paper's 'transparent' claim made concrete).

Presets:
  --preset ci    ~8M params,  60 scheduler ticks   (default; CPU-friendly)
  --preset full  ~125M params, a few hundred steps (for real accelerators)

Run:  PYTHONPATH=src python examples/train_under_omfs.py [--preset ci]
"""
import argparse
import tempfile
from pathlib import Path

import numpy as np

from repro.checkpoint.manager import CheckpointManager, ManagerConfig
from repro.cluster.executor import ClusterExecutor, ManagedJob, TrainJob
from repro.configs.base import ModelConfig
from repro.core.types import Job, JobClass, JobState, SchedulerConfig, User
from repro.data.pipeline import DataConfig
from repro.models.model import build_model, count_params
from repro.train.steps import TrainConfig

PRESETS = {
    "ci": dict(d_model=128, n_layers=4, d_ff=512, vocab=2048, seq=64,
               batch=8, work_b=24, work_a=6, horizon=60, steps_per_tick=2),
    "full": dict(d_model=768, n_layers=12, d_ff=3072, vocab=8192, seq=256,
                 batch=32, work_b=150, work_a=50, horizon=400, steps_per_tick=2),
}


def make_job(p, seed):
    cfg = ModelConfig(
        name=f"lm-{p['d_model']}", family="dense",
        n_layers=p["n_layers"], d_model=p["d_model"], n_heads=8, n_kv_heads=4,
        d_ff=p["d_ff"], vocab=p["vocab"],
    )
    model = build_model(cfg, q_chunk=64, kv_chunk=64)
    n = count_params(cfg)["total"]
    tcfg = TrainConfig(lr=3e-4, warmup_steps=20, total_steps=5000)
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=p["seq"], global_batch=p["batch"],
                      seed=seed)
    return TrainJob(model, tcfg, dcfg, seed=seed), n


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=list(PRESETS), default="ci")
    args = ap.parse_args()
    p = PRESETS[args.preset]
    tmp = Path(tempfile.mkdtemp(prefix="omfs_train_"))

    job_b, n_params = make_job(p, seed=1)
    job_a, _ = make_job(p, seed=2)
    print(f"model: {n_params/1e6:.1f}M params per tenant job")

    users = [User("A", 50.0), User("B", 50.0)]
    ex = ClusterExecutor(users, SchedulerConfig(cpu_total=16, quantum=3),
                         steps_per_tick=p["steps_per_tick"])
    jb = Job(user="B", cpus=12, work=p["work_b"],
             job_class=JobClass.CHECKPOINTABLE, submit_time=0)
    ja = Job(user="A", cpus=8, work=p["work_a"],
             job_class=JobClass.CHECKPOINTABLE, submit_time=5)
    mb = ManagedJob(jb, job_b, CheckpointManager(
        ManagerConfig(root=tmp / "b", durable_every=4)))
    ma = ManagedJob(ja, job_a, CheckpointManager(
        ManagerConfig(root=tmp / "a", durable_every=4)))
    ex.submit(mb)
    ex.submit(ma)
    ex.run(p["horizon"])

    print("\nscheduler events:")
    for e in ex.events:
        print("  " + e)
    print(f"\nB: {jb.state.name}, steps={len(job_b.losses)}, "
          f"checkpoints={mb.checkpoints}, restores={mb.restores}")
    print(f"A: {ja.state.name}, steps={len(job_a.losses)}")
    print(f"B loss: first={job_b.losses[0]:.4f} last={job_b.losses[-1]:.4f}")

    # transparency proof: uninterrupted twin
    ref, _ = make_job(p, seed=1)
    ref.cold_start()
    ref_losses = [ref.run_step() for _ in range(len(job_b.losses))]
    identical = (np.asarray(ref_losses) == np.asarray(job_b.losses)).all()
    print(f"\npreempted == uninterrupted loss curve (bitwise): {identical}")
    assert identical, "transparent C/R violated!"
    assert job_b.losses[-1] < job_b.losses[0], "loss should decrease"
    print("OK: transparent checkpoint-restart preemption verified.")


if __name__ == "__main__":
    main()
