"""Shared helpers for the benchmark harness: timing + CSV/JSON emission."""
from __future__ import annotations

import json
import os
import time
from typing import Callable, List, Tuple

ROWS: List[Tuple[str, float, str]] = []


def emit(name: str, value: float, derived: str = "") -> None:
    ROWS.append((name, value, derived))
    print(f"{name},{value:.6g},{derived}")


def write_rows(bench: str, outdir: str = "") -> str:
    """Dump every emitted row to ``BENCH_<bench>.json`` — CI uploads these
    as artifacts so the perf trajectory is tracked per-PR.

    ``outdir`` defaults to ``$BENCH_OUTDIR`` (else the CWD) so CI can run
    the same bench command N times into bench-run1/2/3 directories and
    gate on the per-row median (`compare_bench --median`)."""
    outdir = outdir or os.environ.get("BENCH_OUTDIR", ".")
    os.makedirs(outdir, exist_ok=True)
    path = os.path.join(outdir, f"BENCH_{bench}.json")
    with open(path, "w") as f:
        json.dump([{"name": n, "value": v, "derived": d}
                   for n, v, d in ROWS], f, indent=1)
    print(f"wrote {path} ({len(ROWS)} rows)")
    return path


def time_us(fn: Callable, *args, warmup: int = 1, iters: int = 5) -> float:
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    # block on jax outputs if any
    try:
        import jax
        jax.block_until_ready(out)
    except Exception:
        pass
    return (time.perf_counter() - t0) / iters * 1e6
