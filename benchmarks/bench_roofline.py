"""Roofline table (deliverable g): reads the dry-run JSON records and emits
per-(arch x shape x mesh) terms.  Run the dry-run sweep first:

  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import emit

RESULTS = Path(__file__).resolve().parent / "results" / "dryrun"


def main() -> None:
    if not RESULTS.exists():
        emit("roofline/no_dryrun_results", 0, "run repro.launch.dryrun first")
        return
    rows = [json.loads(p.read_text()) for p in sorted(RESULTS.glob("*.json"))]
    ok = [r for r in rows if r["status"] == "ok"]
    emit("roofline/cells_ok", len(ok), f"of {len(rows)} recorded")
    for r in ok:
        name = f"roofline/{r['arch']}__{r['shape']}__{r['mesh']}"
        if r.get("tag"):
            name += f"__{r['tag']}"
        if "roofline" not in r:   # compile-only cells (multi-pod / stragglers)
            emit(name, 0.0,
                 f"compile-only;mem_GiB={r['memory']['peak_estimate_bytes']/2**30:.2f}")
            continue
        rf = r["roofline"]
        dominant = rf["bottleneck"]
        total = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
        frac = rf["compute_s"] / total if total > 0 else 0.0
        emit(name, total,
             f"bottleneck={dominant};compute_s={rf['compute_s']:.4f};"
             f"memory_s={rf['memory_s']:.4f};coll_s={rf['collective_s']:.4f};"
             f"MF%={100*(rf['model_flops_ratio'] or 0):.0f};"
             f"mem_GiB={r['memory']['peak_estimate_bytes']/2**30:.2f}")


if __name__ == "__main__":
    main()
