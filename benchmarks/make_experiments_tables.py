"""Regenerate the EXPERIMENTS.md roofline table from the dry-run records.

Replaces the <!-- ROOFLINE_TABLE --> marker block in EXPERIMENTS.md.
"""
from __future__ import annotations

import json
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
RESULTS = ROOT / "benchmarks" / "results" / "dryrun"
EXP = ROOT / "EXPERIMENTS.md"


def table() -> str:
    rows = [json.loads(p.read_text()) for p in sorted(RESULTS.glob("*.json"))
            if "__" in p.stem and len(p.stem.split("__")) == 3]
    lines = [
        "| arch | shape | mesh | status | HBM GiB/dev | compute ms | memory ms "
        "| collective ms | bottleneck | MF% |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["status"] == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | skip | - | - | - | - "
                f"| {r['reason'].split(':')[0]} | - |")
            continue
        if r["status"] != "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | FAILED | - | - | - "
                f"| - | {r.get('error','')[:40]} | - |")
            continue
        mem = r["memory"]["peak_estimate_bytes"] / 2**30
        if "roofline" not in r:
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | {mem:.2f} "
                f"| - | - | - | compile-only | - |")
            continue
        rf = r["roofline"]
        mf = (rf["model_flops_ratio"] or 0) * 100
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | {mem:.2f} "
            f"| {rf['compute_s']*1e3:.1f} | {rf['memory_s']*1e3:.1f} "
            f"| {rf['collective_s']*1e3:.1f} | {rf['bottleneck']} | {mf:.0f} |")
    return "\n".join(lines)


def main():
    text = EXP.read_text()
    marker = "<!-- ROOFLINE_TABLE -->"
    start = text.index(marker)
    end = text.index("## S5", start)
    new = text[: start + len(marker)] + "\n\n" + table() + "\n\n" + text[end:]
    EXP.write_text(new)
    print("roofline table updated:", len(table().splitlines()) - 2, "rows")


if __name__ == "__main__":
    main()
