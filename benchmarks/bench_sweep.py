"""Policy-sweep throughput: ONE vmapped compiled program vs the sequential
per-cell loop (`engine.simulate_batch` vs `engine.simulate` — ISSUE 7 /
ROADMAP "vmap/shard_map a batch of scenario×policy×seed combos").

The grid is the hillclimb.py-style auto-tuning workload: quantum ×
pass_depth × victim-key policy × seed.  Sequentially, every (quantum,
pass_depth, policy) point is a SEPARATE XLA program (those knobs are baked
into the trace as Python constants) — the full 256-cell sweep pays 128
compiles plus 256 dispatches.  `simulate_batch` threads the knobs as
traced int32 scalars on the batch axis, so the whole grid is one compile +
one dispatch, with the compiled queue loop statically truncated at the
batch-wide max pass_depth (masked iterations past each cell's own depth
are no-ops, so results are unchanged).

Timing is reported both ways:

* ``speedup_cold`` — first-touch sweep including each side's compiles (the
  one-shot auto-tuning story; this is where the >=10x acceptance bar
  lives, asserted in ``--full`` runs),
* ``speedup_warm`` + ``*_cells_per_s`` — steady-state re-sweeps (what the
  CI regression gate tracks; compile noise excluded).

Per-cell results are asserted bit-identical between the two paths on every
run (tables + busy series), so the speedup is at equal results by
construction.

``--smoke`` shrinks the grid for CI; the gated rows keep the same names.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import emit, write_rows
from repro.core import engine, omfs_jax
from repro.core.types import SchedulerConfig
from repro.core.workload import WorkloadSpec, make_jobs, make_users

CPU_TOTAL = 32


def _workload(seed: int, n_jobs: int, horizon: int):
    spec = WorkloadSpec(n_users=4, horizon=horizon, cpu_total=CPU_TOTAL,
                        seed=seed, arrival_rate=0.15, mean_work=20,
                        class_mix=(0.15, 0.35, 0.5))
    users = make_users(spec)
    jobs = make_jobs(spec, users)[:n_jobs]
    return users, jobs


def _grid(smoke: bool):
    if smoke:
        quanta, depths, seeds = (2, 8), (2, 4), range(2)
        n_jobs, horizon = 24, 60
    else:
        quanta, depths = (1, 2, 3, 4, 5, 6, 8, 12), (1, 2, 3, 4, 5, 6, 7, 8)
        seeds = range(2)
        n_jobs, horizon = 32, 100
    policies = ("omfs", "omfs_cheap_victim")
    workloads = {s: _workload(s, n_jobs, horizon) for s in seeds}
    cells = [
        (q, d, p, s)
        for q in quanta for d in depths for p in policies for s in seeds
    ]
    return cells, workloads, horizon


def _run_sequential(cells, workloads, horizon):
    out = []
    for q, d, p, s in cells:
        users, jobs = workloads[s]
        cfg = SchedulerConfig(cpu_total=CPU_TOTAL, quantum=q)
        out.append(engine.simulate(users, jobs, cfg, horizon, policy=p,
                                   backend="jax", pass_depth=d))
    jax.block_until_ready(out[-1].table)
    return out


def _run_batch(batch_cells, cfg, horizon):
    out = engine.simulate_batch(batch_cells, cfg, horizon)
    jax.block_until_ready(out[-1].table)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small grid for CI (same gated row names)")
    ap.add_argument("--full", action="store_true",
                    help="assert the >=10x cold-sweep acceptance bar")
    args = ap.parse_args()

    cells, workloads, horizon = _grid(args.smoke and not args.full)
    n = len(cells)
    cfg = SchedulerConfig(cpu_total=CPU_TOTAL, quantum=1)  # knobs override
    batch_cells = [
        engine.BatchCell(users=workloads[s][0], jobs=workloads[s][1],
                         policy=p, quantum=q, pass_depth=d)
        for q, d, p, s in cells
    ]

    # --- cold: first touch pays each side's compiles (the sweep story) ----
    t0 = time.perf_counter()
    seq = _run_sequential(cells, workloads, horizon)
    t_seq_cold = time.perf_counter() - t0

    t0 = time.perf_counter()
    batch = _run_batch(batch_cells, cfg, horizon)
    t_batch_cold = time.perf_counter() - t0

    # --- equal results: every cell, tables + busy, bit for bit ------------
    for (q, d, p, s), sres, bres in zip(cells, seq, batch):
        assert omfs_jax.tables_equal(sres.table, bres.table), \
            f"sweep cell diverged: quantum={q} depth={d} policy={p} seed={s}"
        assert np.array_equal(sres.busy_series(), bres.busy_series()), \
            f"busy series diverged: quantum={q} depth={d} policy={p} seed={s}"

    # --- warm: steady-state re-sweeps (stable rows for the CI gate) -------
    t_seq_warm = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        _run_sequential(cells, workloads, horizon)
        t_seq_warm = min(t_seq_warm, time.perf_counter() - t0)
    t_batch_warm = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        _run_batch(batch_cells, cfg, horizon)
        t_batch_warm = min(t_batch_warm, time.perf_counter() - t0)

    grid = f"cells={n};horizon={horizon};grid=quantum*depth*policy*seed"
    emit("sweep/batch_cells_per_s", n / t_batch_warm, grid)
    emit("sweep/seq_cells_per_s", n / t_seq_warm, grid)
    emit("sweep/speedup_warm", t_seq_warm / t_batch_warm,
         "x, steady-state (per-cell results bit-identical)")
    emit("sweep/speedup_cold", t_seq_cold / t_batch_cold,
         f"x, incl. compiles: seq pays one XLA program per "
         f"(quantum,depth,policy) point, batch compiles once")

    if args.full:
        assert n >= 256, f"full grid must be >=256 cells, got {n}"
        assert t_seq_cold / t_batch_cold >= 10.0, (
            f"cold sweep speedup {t_seq_cold / t_batch_cold:.1f}x below the "
            "10x acceptance bar")

    write_rows("sweep")


if __name__ == "__main__":
    main()
