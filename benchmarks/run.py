"""Benchmark harness entry point: one section per paper claim/table.

Usage:  PYTHONPATH=src python -m benchmarks.run [section ...]
Prints ``name,value,derived`` CSV rows (benchmarks.common.emit).
"""
from __future__ import annotations

import sys


SECTIONS = ("scheduler", "cr_cost", "sched_scale", "kernels", "roofline")


def main() -> None:
    chosen = sys.argv[1:] or SECTIONS
    print("name,value,derived")
    for section in chosen:
        if section == "scheduler":
            from benchmarks import bench_scheduler
            bench_scheduler.main()
        elif section == "cr_cost":
            from benchmarks import bench_cr_cost
            bench_cr_cost.main()
        elif section == "sched_scale":
            from benchmarks import bench_sched_scale
            bench_sched_scale.main()
        elif section == "kernels":
            from benchmarks import bench_kernels
            bench_kernels.main()
        elif section == "roofline":
            from benchmarks import bench_roofline
            bench_roofline.main()
        else:
            raise SystemExit(f"unknown section {section!r}; know {SECTIONS}")


if __name__ == '__main__':
    main()
