"""Fleet-scale scheduler throughput: Python reference vs vectorized JAX,
and — the PR-2 headline — the reference O(J)-per-admission JAX pass vs the
incremental-aggregate pass (`core.omfs_jax.make_omfs_pass(incremental=True)`,
DESIGN.md §Incremental aggregates).

The JAX simulator is what makes 1000+-node / 100k-job what-if studies cheap —
this benchmark measures ticks/second at increasing job counts, with the
SLURM-style ``pass_depth`` bound for the O(J^2) pass, and asserts the
optimized pass produces bit-identical schedule signatures to the reference.

``--smoke`` runs one tiny case (CI keeps the hot path importable + correct).
"""
from __future__ import annotations

import argparse
import time

import jax

from benchmarks.common import emit, write_rows
from repro.core import omfs_jax
from repro.core.crcost import UNBOUNDED, CRCostModel, TieredCRCostModel
from repro.core.simulator import simulate
from repro.core.types import SchedulerConfig
from repro.core.workload import WorkloadSpec, make_jobs, make_users


def _workload(n_jobs: int, cpu_total: int, n_users: int = 16,
              arrival_rate: float = 0.5, seed: int = 1):
    """A workload that actually *reaches* ``n_jobs`` table rows: the spec
    horizon scales with the target so the arrival process generates enough
    jobs (jobs past the simulated horizon still cost O(J) table work, which
    is exactly the scale knob under test)."""
    gen_horizon = max(200, int(1.5 * n_jobs / (n_users * arrival_rate)))
    spec = WorkloadSpec(n_users=n_users, horizon=gen_horizon,
                        cpu_total=cpu_total, seed=seed,
                        arrival_rate=arrival_rate, mean_work=60)
    users = make_users(spec)
    jobs = make_jobs(spec, users)[:n_jobs]
    assert len(jobs) == n_jobs, f"workload too small: {len(jobs)} < {n_jobs}"
    return users, jobs


def _time_jax(users, jobs, cfg, horizon, pass_depth, incremental, reps=5):
    # warm up with the same shapes so compilation stays out of the timing;
    # best-of-`reps` so the CI regression gate compares stable numbers
    _, busy = omfs_jax.simulate_jax(users, jobs, cfg, horizon, pass_depth,
                                    incremental=incremental)
    jax.block_until_ready(busy)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        tbl, busy = omfs_jax.simulate_jax(users, jobs, cfg, horizon,
                                          pass_depth,
                                          incremental=incremental)
        jax.block_until_ready(busy)
        best = min(best, time.perf_counter() - t0)
    return tbl, busy, best


def run_case(n_jobs: int, cpu_total: int, pass_depth, horizon: int) -> None:
    users, jobs = _workload(n_jobs, cpu_total)
    cfg = SchedulerConfig(cpu_total=cpu_total, quantum=10)

    if n_jobs <= 400:  # Python reference gets slow fast
        t_py = float("inf")
        for _ in range(5):   # best-of-5: this row anchors the CI gate
            t0 = time.perf_counter()
            simulate(users, [j.clone() for j in jobs], cfg, horizon)
            t_py = min(t_py, time.perf_counter() - t0)
        emit(f"sched_scale/python_{n_jobs}jobs_ticks_per_s",
             horizon / t_py, f"cpus={cpu_total}")

    tbl_ref, _, t_ref = _time_jax(users, jobs, cfg, horizon, pass_depth, False)
    emit(f"sched_scale/jax_ref_{n_jobs}jobs_ticks_per_s", horizon / t_ref,
         f"cpus={cpu_total};pass_depth={pass_depth}")

    tbl_inc, busy, t_inc = _time_jax(users, jobs, cfg, horizon, pass_depth, True)
    emit(f"sched_scale/jax_inc_{n_jobs}jobs_ticks_per_s", horizon / t_inc,
         f"cpus={cpu_total};pass_depth={pass_depth};"
         f"util={float(busy.mean())/cpu_total:.3f}")

    assert omfs_jax.tables_equal(tbl_ref, tbl_inc), \
        f"incremental pass changed the schedule at J={n_jobs}"
    emit(f"sched_scale/incremental_speedup_{n_jobs}jobs", t_ref / t_inc,
         "x vs reference pass (identical signatures)")

    # size-aware C/R cost model enabled: same incremental pass, the jobs'
    # heterogeneous state sizes now charge save/restore penalties.  The
    # acceptance bar is <= 10% tick-throughput regression (the costs are
    # precomputed table columns + O(1) scatters, not per-tick O(J) work).
    cfg_cost = SchedulerConfig(
        cpu_total=cpu_total, quantum=10,
        cr_cost=CRCostModel(save_mib_per_tick=4096, restore_mib_per_tick=8192,
                            save_base=1, restore_base=1))
    _, _, t_cost = _time_jax(users, jobs, cfg_cost, horizon, pass_depth, True)
    emit(f"sched_scale/jax_costmodel_{n_jobs}jobs_ticks_per_s",
         horizon / t_cost,
         f"rel_to_free={t_inc / t_cost:.3f};"
         f"(>=0.9 keeps the cost model inside the perf budget)")

    # tiered eviction placement enabled: the per-victim placement lax.scan
    # runs ONLY on the eviction branch, so tick throughput must stay close
    # to the flat cost model's.
    cfg_tiered = _tiered_cfg(cpu_total)
    _, _, t_tier = _time_jax(users, jobs, cfg_tiered, horizon, pass_depth, True)
    emit(f"sched_scale/jax_tiered_{n_jobs}jobs_ticks_per_s",
         horizon / t_tier,
         f"rel_to_costmodel={t_cost / t_tier:.3f};"
         f"(placement scan confined to the eviction branch)")


def _tiered_cfg(cpu_total: int, backend: str = "lax") -> SchedulerConfig:
    """Tiered C/R config for the backend A/B: tiers exercise the FULL fused
    surface (victim keys + masked sort + cumsum cutoff + greedy placement),
    not just the flat-cost subset."""
    return SchedulerConfig(
        cpu_total=cpu_total, quantum=10,
        kernel_backend=backend,
        cr_tiers=TieredCRCostModel(
            tiers=(CRCostModel(save_mib_per_tick=4096,
                               restore_mib_per_tick=8192,
                               save_base=1, restore_base=1),
                   CRCostModel(save_mib_per_tick=512,
                               restore_mib_per_tick=1024,
                               save_base=2, restore_base=2)),
            capacity_mib=(16 << 10, UNBOUNDED)))


def _lattice_cfg(cpu_total: int) -> SchedulerConfig:
    """T=4 HBM/DRAM/NVMe/object hierarchy with the measured delta
    coefficients (182/256, `crcost.measured_delta_num`) — the [J, T]
    lattice's stress case: four save/restore columns ride the victim sort
    and the greedy placement walks four capacity lanes."""
    from repro.core.crcost import measured_delta_num
    d = measured_delta_num()
    return SchedulerConfig(
        cpu_total=cpu_total, quantum=10,
        cr_tiers=TieredCRCostModel(
            tiers=(CRCostModel(save_mib_per_tick=8192,
                               restore_mib_per_tick=16384,
                               delta_num=d, delta_den=256),
                   CRCostModel(save_mib_per_tick=4096,
                               restore_mib_per_tick=8192, save_base=1,
                               delta_num=d, delta_den=256),
                   CRCostModel(save_mib_per_tick=512,
                               restore_mib_per_tick=1024, save_base=1,
                               restore_base=1, delta_num=d, delta_den=256),
                   CRCostModel(save_mib_per_tick=64,
                               restore_mib_per_tick=128, save_base=2,
                               restore_base=2, delta_num=d, delta_den=256)),
            capacity_mib=(4 << 10, 16 << 10, 64 << 10, UNBOUNDED)))


def lattice_case(n_jobs: int, cpu_total: int, pass_depth,
                 horizon: int) -> None:
    """[J, T] cost-lattice throughput gate (ISSUE 10): a T=4 delta-aware
    hierarchy must hold tick throughput within 10% of the T=2 two-column
    model at fleet scale — the extra tiers are more int32 lanes on the
    existing sort/scan, never extra passes."""
    users, jobs = _workload(n_jobs, cpu_total)
    _, _, t_two = _time_jax(users, jobs, _tiered_cfg(cpu_total), horizon,
                            pass_depth, True)
    _, _, t_lat = _time_jax(users, jobs, _lattice_cfg(cpu_total), horizon,
                            pass_depth, True)
    rel = t_two / t_lat
    emit(f"sched_scale/jax_lattice_{n_jobs}jobs_ticks_per_s",
         horizon / t_lat,
         f"rel_to_two_column={rel:.3f};tiers=4;delta=182/256;"
         "(>=0.9 at J>=10k keeps the lattice inside the perf budget)")
    if n_jobs >= 10_000:
        assert rel >= 0.9, (
            f"T=4 lattice throughput {rel:.1%} of the two-column model at "
            f"J={n_jobs} — the lattice broke the <=10% overhead budget")


def backend_case(n_jobs: int, cpu_total: int, pass_depth, horizon: int,
                 reps: int = 3) -> None:
    """The tentpole A/B: eviction machinery served by the ``lax`` path
    (hoisted lexsort + cumsum + placement `lax.scan`) vs the fused
    `kernels.sched_select` Pallas kernel, same incremental pass, same
    tiered cost model, asserted bit-identical.

    On this CPU container ``kernel_backend="pallas"`` auto-falls back to
    interpret mode (the kernel body runs as XLA ops), so the pallas rows
    here measure *dispatch + interpret* overhead, not the TPU win — the
    expected TPU story is the roofline row (`sched_roofline_entry`).  Both
    rows are still `_ticks_per_s`-gated: a regression in either dispatch
    path (or an accidental retrace) shows up as a throughput drop."""
    users, jobs = _workload(n_jobs, cpu_total)
    cfg_lax = _tiered_cfg(cpu_total, "lax")
    cfg_pal = _tiered_cfg(cpu_total, "pallas")

    tbl_lax, _, t_lax = _time_jax(users, jobs, cfg_lax, horizon, pass_depth,
                                  True, reps)
    emit(f"sched_scale/sched_kernel_lax_{n_jobs}jobs_ticks_per_s",
         horizon / t_lax, f"cpus={cpu_total};pass_depth={pass_depth}")

    tbl_pal, _, t_pal = _time_jax(users, jobs, cfg_pal, horizon, pass_depth,
                                  True, reps)
    emit(f"sched_scale/sched_kernel_pallas_{n_jobs}jobs_ticks_per_s",
         horizon / t_pal,
         f"cpus={cpu_total};pass_depth={pass_depth};"
         f"interpret={jax.default_backend() != 'tpu'}")

    assert omfs_jax.tables_equal(tbl_lax, tbl_pal), \
        f"pallas backend changed the schedule at J={n_jobs}"
    # informational, NOT gated (interpret-mode ratios are meaningless on
    # CPU; on TPU this becomes the headline number)
    emit(f"sched_scale/pallas_vs_lax_ratio_{n_jobs}jobs", t_lax / t_pal,
         "x lax (identical tables; interpret mode => expect < 1 on CPU)")


def sched_roofline_entry(n_jobs: int = 262_144) -> None:
    """Roofline statement of the expected TPU win for the fused kernel.

    Per *eviction tick* at J jobs the lax path pays (a) an HBM-resident
    variadic lexsort — ~log2(J)*(log2(J)+1)/2 bitonic stages over ~5 int32
    operands — and (b) a J-step sequential `lax.scan` for greedy placement,
    whose per-step loop latency dominates everything at fleet scale.  The
    fused kernel reads 8 int32 columns from HBM once, keeps every
    intermediate in VMEM, and bounds the placement loop by the planned
    count.  Numbers below use nominal v4-ish rates (HBM 1.2 TB/s, VMEM
    ~20x that, ~1us/sequential-step); the value is the expected
    per-eviction-tick speedup, emitted as an ungated roofline row."""
    hbm_bps, vmem_bps, step_s = 1.2e12, 2.2e13, 1e-6
    jp = 1 << max(7, (n_jobs - 1).bit_length())
    log2j = jp.bit_length() - 1
    stages = log2j * (log2j + 1) // 2
    # lax: bitonic sort traffic in HBM (5 operands, read+write per stage)
    # plus the J-step placement scan
    lax_sort_s = stages * 5 * 2 * 4 * jp / hbm_bps
    lax_scan_s = n_jobs * step_s
    t_lax = lax_sort_s + lax_scan_s
    # pallas: one HBM round trip (8 cols in, 3 out) + the same stage count
    # of VMEM-resident traffic (~6 live operands)
    pallas_io_s = (8 + 3) * 4 * jp / hbm_bps
    pallas_vmem_s = stages * 6 * 2 * 4 * jp / vmem_bps
    t_pallas = pallas_io_s + pallas_vmem_s
    emit(f"sched_scale/roofline_sched_select_{n_jobs}jobs_expected_speedup",
         t_lax / t_pallas,
         f"lax~{t_lax*1e3:.1f}ms(sort {lax_sort_s*1e3:.2f}+scan "
         f"{lax_scan_s*1e3:.1f})/evict-tick vs pallas~{t_pallas*1e6:.0f}us;"
         f"VMEM-bound at ~{6 * 4 * jp >> 20}MiB live")


def instrumented_case(n_jobs: int, cpu_total: int, horizon: int) -> None:
    """Event-ring overhead gate (repro.obs): tick throughput with
    ``record_events=True`` — in-scan capture + host-side ring decode — must
    stay within 10% of the uninstrumented run at fleet scale (J = 10k, the
    acceptance bar: capture is ~30 elementwise ops + one scatter on [8*J],
    amortized to noise once a tick costs tens of ms).  Smaller runs emit
    the row for the trajectory without the hard assert — there the sub-ms
    jitted tick is comparable to the fixed capture/decode cost and the
    ratio measures host speed, not the ring."""
    import json as _json
    import os as _os

    from repro.core import engine
    from repro.obs import registry_from_result

    users, jobs = _workload(n_jobs, cpu_total)
    cfg = SchedulerConfig(cpu_total=cpu_total, quantum=10)

    def timed(record):
        t0 = time.perf_counter()
        res = engine.simulate(users, jobs, cfg, horizon, backend="jax",
                              record_events=record)
        jax.block_until_ready(res.busy)
        return res, time.perf_counter() - t0

    timed(False), timed(True)                         # warm both programs
    t_plain = t_inst = float("inf")
    res = None
    # interleave plain/instrumented reps: the ratio then compares
    # neighboring measurements, so host-speed drift across the bench run
    # (thermal, co-tenants) cancels instead of masquerading as overhead
    for _ in range(5):
        _, tp = timed(False)
        res, ti = timed(True)
        t_plain = min(t_plain, tp)
        t_inst = min(t_inst, ti)
    rel = t_plain / t_inst
    dropped = res.events_dropped_total()
    emit(f"sched_scale/jax_instrumented_{n_jobs}jobs_ticks_per_s",
         horizon / t_inst,
         f"rel_to_plain={rel:.3f};events={len(res.events)};"
         f"dropped={dropped}")
    # DROPPED is never silent: its own row, even (especially) when zero
    emit(f"sched_scale/instrumented_events_dropped_{n_jobs}jobs",
         float(dropped), "lossless ring => must stay 0")
    assert dropped == 0, \
        f"lossless ring dropped {dropped} events at J={n_jobs}"
    if n_jobs >= 10_000:
        assert rel >= 0.9, (
            f"instrumented throughput {rel:.1%} of plain at J={n_jobs} — "
            "the event ring broke the <=10% overhead budget")

    # metrics-registry JSON snapshot rides along with the bench artifacts
    # (METRICS_*, not BENCH_*: compare_bench globs BENCH_*.json for rows)
    outdir = _os.environ.get("BENCH_OUTDIR", ".")
    _os.makedirs(outdir, exist_ok=True)
    snap = _os.path.join(outdir, "METRICS_sched_scale.json")
    with open(snap, "w") as f:
        _json.dump(registry_from_result(res, users=users).to_json(), f,
                   indent=1)
    print(f"wrote {snap}")


def profiling_case(horizon: int, capacity: int, segment_len: int) -> None:
    """Streaming-engine profiling hooks: wall time split into compile
    (fresh segment-runner builds), dispatch (jitted segment execution) and
    host-side compaction (the stream boundary).  Timings are machine noise,
    not gated rows — they land in the bench JSON and the step summary so a
    compile-time or boundary blow-up is visible per-PR."""
    from repro.core import engine
    from repro.core.workload import endless_arrivals
    from repro.obs import ProfileTimers

    spec = WorkloadSpec(n_users=8, horizon=horizon, cpu_total=64, seed=3,
                        arrival_rate=0.4, mean_work=40)
    users = make_users(spec)
    cfg = SchedulerConfig(cpu_total=64, quantum=10)
    prof = ProfileTimers()
    res = engine.simulate_stream(users, endless_arrivals(spec, users), cfg,
                                 horizon, "omfs", capacity=capacity,
                                 segment_len=segment_len,
                                 record_events=True, profile=prof)
    snap = prof.snapshot()
    for section in ("compile", "dispatch", "compaction"):
        s = snap.get(section, {"total_s": 0.0, "calls": 0})
        emit(f"sched_scale/stream_profile_{section}_s", s["total_s"],
             f"calls={s['calls']};capacity={capacity};"
             f"segment_len={segment_len}")
    emit("sched_scale/stream_events_dropped",
         float(res.events_dropped_total()),
         f"events={len(res.events)} (lossless ring => must stay 0)")
    assert res.events_dropped_total() == 0


def _obs_step_summary() -> None:
    """Surface the telemetry rows (ring drops + profiling split) in the CI
    step summary — ring overflow must never be silent (repro.obs)."""
    import os as _os

    path = _os.environ.get("GITHUB_STEP_SUMMARY")
    if not path:
        return
    from benchmarks.common import ROWS

    picks = [(n, v, d) for n, v, d in ROWS
             if "instrumented" in n or "stream_profile" in n
             or "events_dropped" in n]
    if not picks:
        return
    lines = ["## Scheduler telemetry (repro.obs)", "",
             "| row | value | detail |", "|---|---|---|"]
    lines += [f"| `{n}` | {v:.6g} | {d} |" for n, v, d in picks]
    with open(path, "a") as f:
        f.write("\n".join(lines) + "\n\n")


def donation_case(n_jobs: int, cpu_total: int, horizon: int) -> None:
    """Peak-memory gate for the donated table buffers (ISSUE 7 satellite).

    The jitted runners declare ``donate_argnums=(0,)``: XLA reuses the
    input table's buffers for the output, so a sweep's working set is ONE
    table, not input+output.  Two asserts make that a regression gate
    rather than a hope: the donated input must actually be deleted, and
    the total live-array footprint after the run must not have grown by a
    second table copy (slack: the busy series plus one column)."""
    import resource

    from repro.core import engine

    users, jobs = _workload(n_jobs, cpu_total)
    cfg = SchedulerConfig(cpu_total=cpu_total, quantum=10)
    run = engine._jitted_runner(cfg, omfs_jax.make_omfs_pass(64), horizon)
    tbl, ent = omfs_jax.table_from_jobs(jobs, users, cfg.cpu_total, cfg)
    table_bytes = sum(getattr(tbl, f).nbytes for f in tbl._fields)

    donated = engine._copy_table(tbl)      # keep `tbl` alive as the yardstick
    jax.block_until_ready(donated.cpus)
    before = sum(a.nbytes for a in jax.live_arrays())
    out, busy = run(donated, ent)
    jax.block_until_ready(busy)
    after = sum(a.nbytes for a in jax.live_arrays())

    assert donated.cpus.is_deleted(), \
        "input table was NOT donated — the runner holds two table copies"
    grew = after - before
    slack = busy.nbytes + tbl.cpus.nbytes
    assert grew <= slack, (
        f"live arrays grew {grew}B > {slack}B slack for a {table_bytes}B "
        "table — donation regressed (output no longer reuses the input "
        "buffers)")
    rss_mib = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss // 1024
    emit(f"sched_scale/donation_extra_copies_{n_jobs}jobs",
         grew / table_bytes,
         f"x table ({table_bytes}B); input deleted=True; "
         f"rss={rss_mib}MiB (informational)")
    del out, busy


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one tiny case for CI (seconds, still asserts "
                         "signature equality)")
    ap.add_argument("--full", action="store_true",
                    help="include the J=100k and J=256k cases")
    args = ap.parse_args()

    if args.smoke:
        # 200 ticks: long enough that the timed region dominates timer and
        # dispatch noise — the bench-regression gate needs stable rows
        cases = ((64, 128, None, 200),)
        backend_cases = [(64, 128, None, 200, 3)]
    else:
        cases = [(100, 256, None, 200), (400, 1024, 64, 200),
                 (2000, 4096, 64, 200), (10_000, 8192, 64, 100)]
        backend_cases = [(10_000, 8192, 64, 40, 3)]
        if args.full:
            cases.append((100_000, 16384, 32, 50))
            # ISSUE 9 acceptance: gated lax-vs-pallas rows at J >= 100k.
            # interpret mode makes the pallas side slow on CPU, so the
            # horizons shrink as J grows — the rows stay gate-compatible
            backend_cases += [(100_000, 16384, 32, 16, 2),
                              (262_144, 16384, 32, 8, 2)]

    for n_jobs, cpu_total, pass_depth, horizon in cases:
        run_case(n_jobs, cpu_total, pass_depth, horizon)
    for n_jobs, cpu_total, pass_depth, horizon, reps in backend_cases:
        backend_case(n_jobs, cpu_total, pass_depth, horizon, reps)
    lattice_case(*((64, 128, None, 200) if args.smoke
                   else (10_000, 8192, 64, 100)))
    sched_roofline_entry()
    donation_case(*((64, 128, 50) if args.smoke else (2000, 4096, 50)))
    if args.smoke:
        instrumented_case(64, 128, 200)
        profiling_case(horizon=60, capacity=32, segment_len=20)
    else:
        instrumented_case(10_000, 8192, 100)
        profiling_case(horizon=400, capacity=256, segment_len=50)
    _obs_step_summary()
    write_rows("sched_scale")


if __name__ == "__main__":
    main()
