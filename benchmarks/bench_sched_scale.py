"""Fleet-scale scheduler throughput: Python reference vs vectorized JAX.

The JAX simulator is what makes 1000+-node / 10k+-job what-if studies cheap
(DESIGN SS2) — this benchmark measures ticks/second for both at increasing
job counts, with the SLURM-style ``pass_depth`` bound for the O(J^2) pass.
"""
from __future__ import annotations

import time

import jax

from benchmarks.common import emit
from repro.core import omfs_jax
from repro.core.simulator import simulate
from repro.core.types import SchedulerConfig
from repro.core.workload import WorkloadSpec, make_jobs, make_users


def main() -> None:
    horizon = 200
    for n_jobs, cpu_total, pass_depth in ((100, 256, None), (400, 1024, 64),
                                          (2000, 4096, 64)):
        spec = WorkloadSpec(n_users=8, horizon=horizon, cpu_total=cpu_total,
                            seed=1, arrival_rate=0.3, mean_work=60)
        users = make_users(spec)
        jobs = make_jobs(spec, users)[:n_jobs]

        if n_jobs <= 400:  # Python reference gets slow fast
            t0 = time.perf_counter()
            simulate(users, [j.clone() for j in jobs],
                     SchedulerConfig(cpu_total=cpu_total, quantum=10), horizon)
            t_py = time.perf_counter() - t0
            emit(f"sched_scale/python_{n_jobs}jobs_ticks_per_s",
                 horizon / t_py, f"cpus={cpu_total}")

        cfg = SchedulerConfig(cpu_total=cpu_total, quantum=10)
        # compile once
        tbl, _ = omfs_jax.simulate_jax(users, jobs, cfg, 1, pass_depth)
        t0 = time.perf_counter()
        tbl, busy = omfs_jax.simulate_jax(users, jobs, cfg, horizon, pass_depth)
        jax.block_until_ready(busy)
        t_jax = time.perf_counter() - t0
        emit(f"sched_scale/jax_{n_jobs}jobs_ticks_per_s", horizon / t_jax,
             f"cpus={cpu_total};pass_depth={pass_depth};"
             f"util={float(busy.mean())/cpu_total:.3f}")


if __name__ == "__main__":
    main()
