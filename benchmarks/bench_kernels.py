"""Kernel microbenchmarks (CPU interpret mode: correctness-representative
shapes; wall times are indicative only — the TPU numbers come from the
roofline analysis, not from this CPU container)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_us
from repro.kernels.ckpt_codec.ops import quantize_array
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.mlstm_scan.ops import mlstm_chunked
from repro.kernels.moe_gmm.ops import expert_swiglu
from repro.kernels.ssm_scan.ops import selective_scan

KEY = jax.random.PRNGKey(0)


def main() -> None:
    # flash attention, modest shape
    B, S, H, KVH, D = 1, 256, 4, 2, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KVH, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KVH, D), jnp.float32)
    us = time_us(lambda: flash_attention(q, k, v, block_q=128, block_k=128,
                                         interpret=True), iters=2)
    flops = 4 * B * H * S * S * D
    emit("kernel/flash_attention_us", us, f"shape=b{B}s{S}h{H}d{D};flops={flops}")

    # moe grouped matmul
    E, C, d, f = 4, 128, 256, 512
    x = jax.random.normal(ks[0], (E, C, d), jnp.float32) * 0.1
    wg = jax.random.normal(ks[1], (E, d, f), jnp.float32) * 0.02
    wd = jax.random.normal(ks[2], (E, f, d), jnp.float32) * 0.02
    us = time_us(lambda: expert_swiglu(x, wg, wg, wd, interpret=True), iters=2)
    emit("kernel/moe_gmm_us", us, f"E{E}C{C}d{d}f{f}")

    # mamba selective scan
    Bm, Sm, di, dsz = 2, 256, 128, 16
    delta = jax.nn.softplus(jax.random.normal(ks[0], (Bm, Sm, di))) * 0.1
    bm = jax.random.normal(ks[1], (Bm, Sm, dsz))
    cm = jax.random.normal(ks[2], (Bm, Sm, dsz))
    xm = jax.random.normal(ks[0], (Bm, Sm, di))
    a = -jnp.exp(jax.random.normal(ks[1], (di, dsz)) * 0.3)
    h0 = jnp.zeros((Bm, di, dsz))
    us = time_us(lambda: selective_scan(delta, bm, cm, xm, a, h0, chunk=64,
                                        block_d=64, interpret=True), iters=1)
    emit("kernel/ssm_scan_us", us, f"b{Bm}s{Sm}d{di}n{dsz}")

    # mLSTM chunked
    BH, Sx, dh = 4, 256, 64
    qx = jax.random.normal(ks[0], (BH, Sx, dh))
    kx = jax.random.normal(ks[1], (BH, Sx, dh)) / np.sqrt(dh)
    vx = jax.random.normal(ks[2], (BH, Sx, dh))
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[0], (BH, Sx)) + 3)
    li = jax.random.normal(ks[1], (BH, Sx))
    us = time_us(lambda: mlstm_chunked(qx, kx, vx, lf, li, chunk=64,
                                       interpret=True), iters=1)
    emit("kernel/mlstm_scan_us", us, f"bh{BH}s{Sx}dh{dh}")

    # checkpoint codec throughput
    xq = jax.random.normal(KEY, (1 << 20,))
    us = time_us(lambda: quantize_array(xq, interpret=True), iters=2)
    emit("kernel/ckpt_codec_us", us,
         f"bytes={xq.nbytes};GBps={xq.nbytes/us/1e3:.2f}")


if __name__ == "__main__":
    main()
