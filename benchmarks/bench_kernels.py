"""Kernel microbenchmarks (CPU interpret mode: correctness-representative
shapes; wall times are indicative only — the TPU numbers come from the
roofline analysis, not from this CPU container)."""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_us, write_rows
from repro.kernels.ckpt_codec.ops import quantize_array
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.mlstm_scan.ops import mlstm_chunked
from repro.kernels.moe_gmm.ops import expert_swiglu
from repro.kernels.sched_select.ops import plan_evictions_fused
from repro.kernels.ssm_scan.ops import selective_scan

KEY = jax.random.PRNGKey(0)


def sched_select_rows() -> None:
    """Interpret-mode rows for the fused victim-select/placement kernel
    (`kernels.sched_select`, ISSUE 9): flat-cost and tiered variants at a
    fleet-representative J.  Like every `_us` row here the wall times are
    indicative; the gated engine-level lax-vs-pallas rows live in
    `bench_sched_scale` and the TPU story in its roofline entry."""
    j = 4096
    ks = jax.random.split(KEY, 6)
    prio = jax.random.randint(ks[0], (j,), 0, 100, jnp.int32)
    rstart = jax.random.randint(ks[1], (j,), 0, 500, jnp.int32)
    jid = jnp.arange(j, dtype=jnp.int32)
    csave = jax.random.randint(ks[2], (j,), 1, 50, jnp.int32)
    evict = jax.random.bernoulli(ks[3], 0.3, (j,))
    cpus = jax.random.randint(ks[4], (j,), 1, 16, jnp.int32)
    mib = jax.random.randint(ks[5], (j,), 64, 4096, jnp.int32)
    is_ckpt = evict
    zeros = jnp.zeros((j,), jnp.int32)
    # T=2 effective save lattice: fast tier = the cheap-victim key column
    lat = jnp.stack([csave, csave * 4], axis=1)

    us = time_us(lambda: plan_evictions_fused(
        prio, rstart, jid, csave, evict, cpus, zeros, jnp.zeros((j,), bool),
        jnp.zeros((j, 1), jnp.int32),
        jnp.int32(8), jnp.int32(64), jnp.zeros((1,), jnp.int32),
        jnp.full((1,), -1, jnp.int32),
        cheap=False, tiered=False, interpret=True), iters=2)
    emit("kernel/sched_select_us", us, f"J={j};flat cost;masked bitonic+"
         "cumsum cutoff")

    us = time_us(lambda: plan_evictions_fused(
        prio, rstart, jid, csave, evict, cpus, mib, is_ckpt, lat,
        jnp.int32(8), jnp.int32(64), jnp.zeros((2,), jnp.int32),
        jnp.asarray([16 << 10, -1], jnp.int32),
        cheap=True, tiered=True, bounded=True, interpret=True), iters=2)
    emit("kernel/sched_select_tiered_us", us, f"J={j};cheap-victim keys+"
         "greedy tier placement over the [J,T] lattice")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sched-only", action="store_true",
                    help="only the sched_select rows (fast enough for the "
                         "CI bench loop; the model kernels stay manual)")
    args = ap.parse_args(argv)
    if args.sched_only:
        sched_select_rows()
        write_rows("kernels")
        return
    model_kernel_rows()
    sched_select_rows()
    write_rows("kernels")


def model_kernel_rows() -> None:
    # flash attention, modest shape
    B, S, H, KVH, D = 1, 256, 4, 2, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KVH, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KVH, D), jnp.float32)
    us = time_us(lambda: flash_attention(q, k, v, block_q=128, block_k=128,
                                         interpret=True), iters=2)
    flops = 4 * B * H * S * S * D
    emit("kernel/flash_attention_us", us, f"shape=b{B}s{S}h{H}d{D};flops={flops}")

    # moe grouped matmul
    E, C, d, f = 4, 128, 256, 512
    x = jax.random.normal(ks[0], (E, C, d), jnp.float32) * 0.1
    wg = jax.random.normal(ks[1], (E, d, f), jnp.float32) * 0.02
    wd = jax.random.normal(ks[2], (E, f, d), jnp.float32) * 0.02
    us = time_us(lambda: expert_swiglu(x, wg, wg, wd, interpret=True), iters=2)
    emit("kernel/moe_gmm_us", us, f"E{E}C{C}d{d}f{f}")

    # mamba selective scan
    Bm, Sm, di, dsz = 2, 256, 128, 16
    delta = jax.nn.softplus(jax.random.normal(ks[0], (Bm, Sm, di))) * 0.1
    bm = jax.random.normal(ks[1], (Bm, Sm, dsz))
    cm = jax.random.normal(ks[2], (Bm, Sm, dsz))
    xm = jax.random.normal(ks[0], (Bm, Sm, di))
    a = -jnp.exp(jax.random.normal(ks[1], (di, dsz)) * 0.3)
    h0 = jnp.zeros((Bm, di, dsz))
    us = time_us(lambda: selective_scan(delta, bm, cm, xm, a, h0, chunk=64,
                                        block_d=64, interpret=True), iters=1)
    emit("kernel/ssm_scan_us", us, f"b{Bm}s{Sm}d{di}n{dsz}")

    # mLSTM chunked
    BH, Sx, dh = 4, 256, 64
    qx = jax.random.normal(ks[0], (BH, Sx, dh))
    kx = jax.random.normal(ks[1], (BH, Sx, dh)) / np.sqrt(dh)
    vx = jax.random.normal(ks[2], (BH, Sx, dh))
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[0], (BH, Sx)) + 3)
    li = jax.random.normal(ks[1], (BH, Sx))
    us = time_us(lambda: mlstm_chunked(qx, kx, vx, lf, li, chunk=64,
                                       interpret=True), iters=1)
    emit("kernel/mlstm_scan_us", us, f"bh{BH}s{Sx}dh{dh}")

    # checkpoint codec throughput
    xq = jax.random.normal(KEY, (1 << 20,))
    us = time_us(lambda: quantize_array(xq, interpret=True), iters=2)
    emit("kernel/ckpt_codec_us", us,
         f"bytes={xq.nbytes};GBps={xq.nbytes/us/1e3:.2f}")


if __name__ == "__main__":
    main()  # pragma: no cover
