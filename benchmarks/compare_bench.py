"""Bench-regression gate: diff fresh BENCH_*.json against the committed
baseline and fail CI on a >15% regression.

The benchmarks already emit their rows to ``BENCH_<bench>.json``
(`benchmarks.common.write_rows`) and CI uploads them as artifacts — but
until this gate nothing *read* them.  Now the perf trajectory is locked:

* ``python -m benchmarks.compare_bench`` — compare every gated row in
  ``benchmarks/BENCH_baseline.json`` against the fresh files in the CWD;
  exit 1 if any regresses by more than its tolerance.  A trajectory table
  is printed, and appended to ``$GITHUB_STEP_SUMMARY`` when set.
* ``python -m benchmarks.compare_bench --median DIR [DIR ...]`` — same
  gate, but each row's fresh value is the per-row MEDIAN across the
  directories (CI runs every smoke bench three times into bench-run1/2/3
  via ``$BENCH_OUTDIR``).  Median-of-3 is what let the tolerance tighten
  from 20% to 15%: a single noisy run can no longer fail — or mask — a
  regression on a shared runner.
* ``python -m benchmarks.compare_bench --write-baseline`` — regenerate the
  baseline from the fresh files (run the smoke benches first).  Do this
  deliberately, in the PR that changes the performance story.

Gated rows are higher-is-better (tick throughput, goodput, speedups).
Absolute ticks/second are machine-dependent, so throughput rows are
normalized by an ANCHOR row before comparison — the pure-Python
reference-backend throughput measured in the same run, which scales with
host speed the same way the JAX rows do.  Goodput/ratio rows are
deterministic and compare raw.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import statistics
import sys
from typing import Dict, List, Optional

BASELINE_PATH = os.path.join(os.path.dirname(__file__), "BENCH_baseline.json")

#: the machine-speed anchor: python-backend scheduler ticks/second
ANCHOR = "sched_scale/python_64jobs_ticks_per_s"
DEFAULT_RTOL = 0.15

#: (substring, normalize_by_anchor) — which fresh rows become gated
#: baseline entries.  Throughput rows normalize; quality rows compare raw.
GATED_PATTERNS = (
    ("_ticks_per_s", True),
    ("_cells_per_s", True),
    ("incremental_speedup", False),
    ("goodput", False),
    ("policy_matrix/omfs_jax_util", False),
)
#: rows that are deltas/drops (lower magnitude is fine) — never gated
EXCLUDE_SUBSTRINGS = ("goodput_drop", "goodput_recovered")


def load_fresh(patterns=("BENCH_*.json",), dirname: str = ".") -> Dict[str, float]:
    rows: Dict[str, float] = {}
    for pat in patterns:
        for path in sorted(glob.glob(os.path.join(dirname, pat))):
            if os.path.abspath(path) == os.path.abspath(BASELINE_PATH):
                continue
            with open(path) as f:
                for row in json.load(f):
                    rows[row["name"]] = float(row["value"])
    return rows


def load_median(dirs: List[str]) -> Dict[str, float]:
    """Per-row median across N bench-run directories.  A row only present
    in some runs medians over those (a bench that crashed mid-run still
    fails the gate via its MISSING rows, not via a KeyError here)."""
    per_run = [load_fresh(dirname=d) for d in dirs]
    out: Dict[str, float] = {}
    for name in sorted(set().union(*per_run) if per_run else ()):
        out[name] = statistics.median(
            r[name] for r in per_run if name in r)
    return out


def make_baseline(fresh: Dict[str, float]) -> List[dict]:
    entries = []
    for name, value in sorted(fresh.items()):
        if any(x in name for x in EXCLUDE_SUBSTRINGS):
            continue
        for pat, normalize in GATED_PATTERNS:
            if pat in name and name != ANCHOR:
                entries.append({
                    "name": name,
                    "value": value,
                    "rtol": DEFAULT_RTOL,
                    "normalize_by": ANCHOR if normalize else None,
                })
                break
    anchor = fresh.get(ANCHOR)
    if anchor is None:
        raise SystemExit(f"anchor row {ANCHOR!r} missing — run "
                         "bench_sched_scale --smoke first")
    return [{"name": ANCHOR, "value": anchor, "rtol": None,
             "normalize_by": None}] + entries


def compare(baseline: List[dict], fresh: Dict[str, float]):
    """Returns (table rows, failures).  A gated row regresses when its
    (possibly anchor-normalized) fresh value drops more than ``rtol``
    below the same normalization of the baseline value."""
    base_by_name = {e["name"]: e for e in baseline}
    anchor_base = base_by_name.get(ANCHOR, {}).get("value")
    anchor_fresh = fresh.get(ANCHOR)

    table, failures = [], []
    for entry in baseline:
        name, rtol = entry["name"], entry["rtol"]
        base = entry["value"]
        cur: Optional[float] = fresh.get(name)
        if cur is None:
            table.append((name, base, None, None, "MISSING"))
            # a missing ANCHOR row (rtol None) also fails: without it every
            # normalized throughput row would silently stop being gated
            failures.append(f"{name}: row missing from fresh results")
            continue
        b, c = base, cur
        if entry.get("normalize_by"):
            if not anchor_base or not anchor_fresh:
                table.append((name, base, cur, None, "NO-ANCHOR"))
                failures.append(
                    f"{name}: anchor row unavailable, gate cannot run")
                continue
            b, c = base / anchor_base, cur / anchor_fresh
        delta = (c - b) / b if b else 0.0
        if rtol is None:
            status = "anchor"
        elif delta < -rtol:
            status = "REGRESSED"
            failures.append(
                f"{name}: {c:.4g} vs baseline {b:.4g} "
                f"({delta:+.1%}, tolerance -{rtol:.0%})")
        else:
            status = "ok"
        table.append((name, base, cur, delta, status))
    return table, failures


def render(table, failures) -> str:
    lines = ["| benchmark | baseline | current | delta | status |",
             "|---|---|---|---|---|"]
    for name, base, cur, delta, status in table:
        cur_s = f"{cur:.4g}" if cur is not None else "—"
        delta_s = f"{delta:+.1%}" if delta is not None else "—"
        mark = "❌" if status in ("REGRESSED", "MISSING", "NO-ANCHOR") \
            else "✅"
        lines.append(f"| `{name}` | {base:.4g} | {cur_s} | {delta_s} "
                     f"| {mark} {status} |")
    verdict = (f"**{len(failures)} benchmark regression(s) beyond "
               "tolerance**" if failures else
               "**no benchmark regressions beyond tolerance**")
    return "\n".join(["## Bench trajectory", ""] + lines + ["", verdict, ""])


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--write-baseline", action="store_true",
                    help="regenerate benchmarks/BENCH_baseline.json from "
                         "the fresh BENCH_*.json files in the CWD")
    ap.add_argument("--baseline", default=BASELINE_PATH)
    ap.add_argument("--median", nargs="+", metavar="DIR",
                    help="gate on the per-row median of the BENCH_*.json "
                         "files across these directories (CI's "
                         "median-of-3) instead of the CWD's files")
    args = ap.parse_args(argv)

    fresh = load_median(args.median) if args.median else load_fresh()
    if not fresh:
        where = " ".join(args.median) if args.median else "the CWD"
        print(f"no BENCH_*.json found in {where} — run the smoke benches")
        return 2

    if args.write_baseline:
        entries = make_baseline(fresh)
        with open(args.baseline, "w") as f:
            json.dump(entries, f, indent=1)
        print(f"wrote {args.baseline} ({len(entries)} rows, "
              f"anchor={ANCHOR})")
        return 0

    with open(args.baseline) as f:
        baseline = json.load(f)
    table, failures = compare(baseline, fresh)
    report = render(table, failures)
    print(report)

    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a") as f:
            f.write(report + "\n")

    if failures:
        print("FAIL: " + "; ".join(failures), file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
