"""Scheduler benchmarks quantifying the paper's qualitative claims.

* utilization / fairness / wait — OMFS vs static / capping / FCFS /
  backfill / backfill+C/R on identical pooled workloads (paper SII vs SI).
* reclaim latency — memoryless fairness: entitled demand is served
  immediately (the "no justified complaints" property).
* oversubscription — a job larger than its owner's whole entitlement.
* quantum sweep — C/R-frequency vs responsiveness trade-off (SII).
* thrashing — the size-aware C/R cost model (core.crcost) materially
  changing the schedule: goodput vs utilization under free / NVM-fast /
  disk-slow tiers on the same eviction ping-pong workload.
* tier placement — the tiered eviction-placement subsystem
  (core.crcost.TieredCRCostModel): a fast-tier capacity sweep showing
  placement-aware preemption recovering the goodput a single slow tier
  loses, plus the size-aware `omfs_cheap_victim` policy variant.
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import emit, write_rows
from repro.core import engine
from repro.core.baselines import ALL_BASELINES
from repro.core.crcost import UNBOUNDED, CRCostModel, TieredCRCostModel
from repro.core.metrics import compute_metrics
from repro.core.simulator import simulate
from repro.core.types import SchedulerConfig
from repro.core.workload import (
    WorkloadSpec,
    make_jobs,
    make_users,
    oversub_scenario,
    reclaim_scenario,
    thrashing_scenario,
)


def bench_utilization() -> None:
    """Paper Table (implied): utilization & fairness per policy."""
    spec = WorkloadSpec(n_users=4, horizon=1500, cpu_total=128, seed=7,
                        arrival_rate=0.05, burstiness=1.0)
    users = make_users(spec)
    jobs = make_jobs(spec, users)
    cfg = SchedulerConfig(cpu_total=128, quantum=20, cr_overhead=2)
    res = simulate(users, [j.clone() for j in jobs], cfg, spec.horizon)
    m = compute_metrics(res)
    emit("utilization/omfs", m.utilization,
         f"jain={m.jain_fairness:.3f};wait={m.mean_wait:.1f};ckpt={m.checkpoints}")
    for name, pol in ALL_BASELINES.items():
        res = simulate(users, [j.clone() for j in jobs], cfg, spec.horizon,
                       policy=pol)
        m = compute_metrics(res)
        emit(f"utilization/{name}", m.utilization,
             f"jain={m.jain_fairness:.3f};wait={m.mean_wait:.1f};ckpt={m.checkpoints}")


def bench_reclaim_latency() -> None:
    """Ticks from submit to start for an entitled claim, per policy."""
    for q in (5, 10, 30):
        users, jobs, jid = reclaim_scenario(128, quantum=q)
        cfg = SchedulerConfig(cpu_total=128, quantum=q)
        res = simulate(users, [j.clone() for j in jobs], cfg, 600)
        j = res.state.jobs[jid]
        lat = (j.first_start - j.submit_time) if j.first_start >= 0 else -1
        emit(f"reclaim_latency/omfs_q{q}", lat, "ticks")
    # capping baseline never needs reclaim (but also never pooled B's idle!)
    users, jobs, jid = reclaim_scenario(128, quantum=10)
    res = simulate(users, [j.clone() for j in jobs],
                   SchedulerConfig(cpu_total=128, quantum=10), 600,
                   policy=ALL_BASELINES["fcfs"])
    j = res.state.jobs[jid]
    lat = (j.first_start - j.submit_time) if j.first_start >= 0 else 600
    emit("reclaim_latency/fcfs", lat, "ticks (head-of-line blocking)")


def bench_oversub() -> None:
    """A 75%-of-machine job from a 25% user: runnable under OMFS only."""
    users, jobs, jid = oversub_scenario(128)
    for name in ("omfs", "capping", "static_partition"):
        if name == "omfs":
            res = simulate(users, [j.clone() for j in jobs],
                           SchedulerConfig(cpu_total=128, quantum=5), 500)
        else:
            res = simulate(users, [j.clone() for j in jobs],
                           SchedulerConfig(cpu_total=128, quantum=5), 500,
                           policy=ALL_BASELINES[name])
        j = res.state.jobs[jid]
        done = 1.0 if j.finish_time >= 0 and j.state.name == "DONE" else 0.0
        emit(f"oversub_job_completes/{name}", done,
             f"start={j.first_start}")


def bench_quantum() -> None:
    """Thrashing vs quantum: preemptions, C/R overhead, reclaim wait."""
    spec = WorkloadSpec(n_users=4, horizon=1000, cpu_total=128, seed=5,
                        arrival_rate=0.06, burstiness=1.5)
    users = make_users(spec)
    jobs = make_jobs(spec, users)
    for q in (0, 5, 15, 30, 60, 120):
        cfg = SchedulerConfig(cpu_total=128, quantum=q, cr_overhead=3)
        res = simulate(users, [j.clone() for j in jobs], cfg, spec.horizon)
        m = compute_metrics(res)
        emit(f"quantum_sweep/q{q}_preemptions", m.preemptions,
             f"util={m.utilization:.3f};overhead={m.cr_overhead_units};"
             f"wait={m.mean_wait:.1f}")


def bench_policy_matrix(horizon: int = 400) -> None:
    """Every registered policy on both engine backends, one comparison table:
    utilization, goodput, wasted work, mean wait, preemption/checkpoint
    counts (paper Table, implied, now runnable at either fidelity) — with a
    size-aware C/R cost model charging real save/restore penalties."""
    spec = WorkloadSpec(n_users=4, horizon=horizon, cpu_total=64, seed=9,
                        arrival_rate=0.08, mean_work=40)
    users = make_users(spec)
    jobs = make_jobs(spec, users)
    cfg = SchedulerConfig(
        cpu_total=64, quantum=10, cr_overhead=2,
        cr_cost=CRCostModel(save_mib_per_tick=512, restore_mib_per_tick=1024))

    rows = []
    names = list(engine.POLICIES)
    # every policy's JAX run shares ONE compiled scan (the policy is a
    # lax.switch index) instead of compiling a fresh scan per policy —
    # engine.simulate_matrix; results stay bit-identical to per-policy
    # engine.simulate(backend="jax")
    jax_results = {r.policy: r for r in engine.simulate_matrix(
        users, jobs, cfg, spec.horizon, names)}
    for name in names:
        for backend in ("python", "jax"):
            # engine.simulate never mutates its input jobs (python clones,
            # jax only reads), so the same list serves every iteration
            if backend == "jax":
                res = jax_results[name]
            else:
                res = engine.simulate(users, jobs, cfg, spec.horizon,
                                      policy=name, backend=backend)
            s = res.summary()
            rows.append(s)
            emit(f"policy_matrix/{name}_{backend}_util", s["utilization"],
                 f"goodput={s['goodput']:.3f};wasted={s['wasted_frac']:.3f};"
                 f"wait={s['mean_wait']:.1f};preempt={s['preemptions']};"
                 f"ckpt={s['checkpoints']};killed={s['killed']}")

    hdr = ("policy", "backend", "utilization", "goodput", "wasted_frac",
           "mean_wait", "preemptions", "checkpoints", "spills", "killed",
           "done")
    widths = [max(len(h), 12) for h in hdr]
    print("\n" + "  ".join(h.ljust(w) for h, w in zip(hdr, widths)))
    for s in rows:
        print("  ".join(
            (f"{s[h]:.3f}" if isinstance(s[h], float) else str(s[h])).ljust(w)
            for h, w in zip(hdr, widths)))


def bench_thrashing(horizon: int = 400) -> None:
    """The cost model's headline: on the eviction ping-pong scenario a slow
    C/R tier INCREASES utilization (the machine is busy re-writing state)
    while goodput collapses — the paper's argument for fast NVM tiers,
    measured."""
    tiers = (
        ("free", CRCostModel()),
        ("nvm", CRCostModel(save_mib_per_tick=16384,
                            restore_mib_per_tick=32768)),
        ("disk", CRCostModel(save_mib_per_tick=2048,
                             restore_mib_per_tick=4096)),
    )
    base = None
    for name, model in tiers:
        users, jobs = thrashing_scenario(64, quantum=5)
        cfg = SchedulerConfig(cpu_total=64, quantum=5, cr_cost=model)
        res = simulate(users, [j.clone() for j in jobs], cfg, horizon)
        m = compute_metrics(res)
        emit(f"thrashing/{name}_goodput", m.goodput,
             f"util={m.utilization:.3f};wasted={m.wasted_work_frac:.3f};"
             f"ckpt={m.checkpoints};overhead={m.cr_overhead_units}")
        if name == "free":
            base = m.goodput
    if base:
        emit("thrashing/goodput_drop_disk_vs_free", base - m.goodput,
             "the measured thrashing-cost term")


# fast NVM-like tier vs a slow durable disk tier (same models as
# bench_thrashing so the sweep endpoints are directly comparable)
_FAST = CRCostModel(save_mib_per_tick=16384, restore_mib_per_tick=32768)
_DISK = CRCostModel(save_mib_per_tick=2048, restore_mib_per_tick=4096)


def bench_tier_placement(horizon: int = 400) -> None:
    """Fast-tier capacity sweep on the thrashing scenario: with 0 MiB of
    fast tier every checkpoint spills to disk (= the single-tier disk
    model); each capacity step lets more of the eviction ping-pong land on
    the fast tier, recovering goodput — placement-aware preemption is
    where the utilization gain actually comes from.  Also measures the
    size-aware `omfs_cheap_victim` victim order against the faithful one
    on the same heterogeneous flood."""
    # heterogeneous flood: snapshots of 16..128 GiB compete for capacity
    gibs = (128, 64, 32, 16)
    total_mib = sum(g << 10 for g in gibs)

    def run(policy, cfg):
        users, jobs = thrashing_scenario(64, quantum=5, state_gibs=gibs)
        res = engine.simulate(users, jobs, cfg, horizon,
                              policy=policy, backend="python")
        return res.summary()

    single = run("omfs", SchedulerConfig(cpu_total=64, quantum=5,
                                         cr_cost=_DISK))
    emit("tier_placement/single_disk_goodput", single["goodput"],
         f"util={single['utilization']:.3f};the no-placement baseline")

    goodput_at = {}
    for frac, cap in (("0", 0), ("quarter", total_mib // 4),
                      ("half", total_mib // 2), ("all", total_mib),
                      ("unbounded", UNBOUNDED)):
        tiers = TieredCRCostModel(tiers=(_FAST, _DISK),
                                  capacity_mib=(cap, UNBOUNDED))
        cfg = SchedulerConfig(cpu_total=64, quantum=5, cr_tiers=tiers)
        s = run("omfs", cfg)
        goodput_at[frac] = s["goodput"]
        emit(f"tier_placement/capacity_{frac}_goodput", s["goodput"],
             f"cap_mib={cap};util={s['utilization']:.3f};"
             f"ckpt={s['checkpoints']};spills={s['spills']}")
        # size-aware victim selection on the same tiered machine
        c = run("omfs_cheap_victim", cfg)
        emit(f"tier_placement/capacity_{frac}_cheap_victim_goodput",
             c["goodput"],
             f"vs_faithful={c['goodput'] - s['goodput']:+.3f};"
             f"ckpt={c['checkpoints']};spills={c['spills']}")

    # the headline claims, asserted (the CI gate also tracks the values):
    # zero fast capacity degenerates to the single-tier disk model, and
    # ANY fast capacity only improves on it
    assert abs(goodput_at["0"] - single["goodput"]) < 1e-9, \
        "cap=0 tiered placement must degenerate to the single-tier model"
    assert all(g >= single["goodput"] - 1e-9 for g in goodput_at.values()), \
        "tiered placement regressed goodput vs the single-tier disk model"
    emit("tier_placement/goodput_recovered_all_vs_disk",
         goodput_at["all"] - single["goodput"],
         "what placing the ping-pong on the fast tier buys")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short horizons for CI (policy matrix + thrashing)")
    args = ap.parse_args(argv)

    if args.smoke:
        bench_policy_matrix(horizon=120)
        # 400 ticks so the charged overhead is actually *executed* (goodput
        # only drops once jobs run past their base work) — still a 16-job
        # Python sim, seconds even on CI
        bench_thrashing(horizon=400)
        bench_tier_placement(horizon=400)
    else:
        bench_utilization()
        bench_reclaim_latency()
        bench_oversub()
        bench_quantum()
        bench_policy_matrix()
        bench_thrashing()
        bench_tier_placement()
    write_rows("scheduler")


if __name__ == "__main__":
    main()
