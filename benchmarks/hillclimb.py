"""Perf-hillclimb runner: A/B a dry-run cell against tuning overrides.

Each experiment re-lowers + re-compiles the cell with a change and reports
the roofline-term deltas vs. the recorded baseline — the measure step of
the hypothesis -> change -> measure -> validate loop (EXPERIMENTS.md SSPerf).

Usage:
  PYTHONPATH=src python -m benchmarks.hillclimb --cell dbrx-132b/train_4k \
      --tag accum8 --set grad_accum=8
  PYTHONPATH=src python -m benchmarks.hillclimb --cell glm4-9b/decode_32k \
      --tag kvshard --cfg decode_kv_shard=true
"""
import argparse
import json
from pathlib import Path

from repro.launch.dryrun import RESULTS_DIR, run_cell


def parse_kv(items):
    out = {}
    for item in items or []:
        k, v = item.split("=", 1)
        if v.lower() in ("true", "false"):
            out[k] = v.lower() == "true"
        else:
            try:
                out[k] = int(v)
            except ValueError:
                out[k] = float(v)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, help="<arch>/<shape>")
    ap.add_argument("--tag", required=True)
    ap.add_argument("--set", nargs="*", help="tuning overrides k=v "
                    "(q_chunk, kv_chunk, grad_accum)")
    ap.add_argument("--cfg", nargs="*", help="ModelConfig overrides k=v")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args(argv)

    arch, shape = args.cell.split("/")
    override = parse_kv(args.set)
    cfg_over = parse_kv(args.cfg)
    if cfg_over:
        override["cfg"] = cfg_over

    rec = run_cell(arch, shape, args.multi_pod, RESULTS_DIR,
                   tuning_override=override or None, tag=args.tag)

    mesh_name = "2x16x16" if args.multi_pod else "16x16"
    base_path = RESULTS_DIR / f"{arch}__{shape}__{mesh_name}.json"
    if base_path.exists() and rec.get("status") == "ok":
        base = json.loads(base_path.read_text())
        if base.get("status") == "ok":
            b, n = base["roofline"], rec["roofline"]
            bm, nm = base["memory"], rec["memory"]
            print("\n=== delta vs baseline ===")
            for term in ("compute_s", "memory_s", "collective_s"):
                if b[term] > 0:
                    print(f"{term:14s}: {b[term]*1e3:10.1f} -> {n[term]*1e3:10.1f} ms  "
                          f"({(n[term]/b[term]-1)*100:+.1f}%)")
            print(f"{'hbm GiB':14s}: {bm['peak_estimate_bytes']/2**30:10.2f} -> "
                  f"{nm['peak_estimate_bytes']/2**30:10.2f}")


if __name__ == "__main__":
    main()
