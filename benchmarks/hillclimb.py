"""Perf-hillclimb runner: A/B a dry-run cell against tuning overrides, or
sweep the scheduler's tuning grid as one compiled batch.

Model mode (the original): each experiment re-lowers + re-compiles the cell
with a change and reports the roofline-term deltas vs. the recorded
baseline — the measure step of the hypothesis -> change -> measure ->
validate loop (EXPERIMENTS.md SSPerf).

Scheduler mode (``--sched-grid``, ISSUE 9 satellite): the
quantum × pass_depth × victim-key grid runs through ONE
`engine.simulate_batch` call — every cell is a batch row of a single
compiled vmapped scan (quantum/pass_depth ride the traced `Knobs`, the
victim-key variant is the omfs vs omfs_cheap_victim `lax.switch` index),
so the whole grid costs one compile instead of one per cell.  The
leaderboard ranks cells by goodput; ``--backend pallas`` routes the
eviction machinery through the fused `kernels.sched_select` kernel.

Usage:
  PYTHONPATH=src python -m benchmarks.hillclimb --cell dbrx-132b/train_4k \
      --tag accum8 --set grad_accum=8
  PYTHONPATH=src python -m benchmarks.hillclimb --cell glm4-9b/decode_32k \
      --tag kvshard --cfg decode_kv_shard=true
  PYTHONPATH=src python -m benchmarks.hillclimb --sched-grid \
      --quantums 1,2,4,8 --depths 16,64 --jobs 400 --horizon 200
"""
import argparse
import json
import time

from repro.launch.dryrun import RESULTS_DIR, run_cell


def parse_kv(items):
    out = {}
    for item in items or []:
        k, v = item.split("=", 1)
        if v.lower() in ("true", "false"):
            out[k] = v.lower() == "true"
        else:
            try:
                out[k] = int(v)
            except ValueError:
                out[k] = float(v)
    return out


def sched_grid(args) -> None:
    """One `simulate_batch` call for the whole scheduler tuning grid."""
    from repro.core import engine
    from repro.core.types import SchedulerConfig
    from repro.core.workload import WorkloadSpec, make_jobs, make_users

    quantums = [int(x) for x in args.quantums.split(",")]
    depths = [int(x) for x in args.depths.split(",")]
    # victim-key axis: faithful keys (priority, run_start, jid) vs the
    # cheap-victim ordering that ranks by checkpoint cost first
    policies = ("omfs", "omfs_cheap_victim")

    gen_horizon = max(200, int(1.5 * args.jobs / (8 * 0.3)))
    spec = WorkloadSpec(n_users=8, horizon=gen_horizon,
                        cpu_total=args.cpu_total, seed=args.seed,
                        arrival_rate=0.3, mean_work=40)
    users = make_users(spec)
    jobs = make_jobs(spec, users)[:args.jobs]
    assert len(jobs) == args.jobs, f"workload too small: {len(jobs)}"
    cfg = SchedulerConfig(cpu_total=args.cpu_total,
                          kernel_backend=args.backend)

    cells = [engine.BatchCell(users, jobs, policy=p, quantum=q, pass_depth=d)
             for p in policies for q in quantums for d in depths]
    t0 = time.perf_counter()
    results = engine.simulate_batch(cells, cfg, args.horizon)
    wall = time.perf_counter() - t0

    rows = []
    for cell, res in zip(cells, results):
        s = res.summary()
        rows.append((s["goodput"], cell.policy, cell.quantum,
                     cell.pass_depth, s["utilization"], s["preemptions"],
                     s["spills"], s["mean_wait"], s["done"]))
    rows.sort(key=lambda r: -r[0])

    print(f"\n=== sched grid: {len(cells)} cells in ONE batched sweep "
          f"({wall:.2f}s, {len(cells) / wall:.1f} cells/s, "
          f"backend={args.backend}) ===")
    print(f"{'goodput':>8} {'policy':>18} {'q':>3} {'depth':>5} "
          f"{'util':>6} {'preempt':>7} {'spill':>5} {'wait':>6} {'done':>5}")
    for g, p, q, d, u, pre, sp, w, done in rows:
        print(f"{g:8.4f} {p:>18} {q:3d} {d:5d} {u:6.3f} {pre:7d} "
              f"{sp:5d} {w:6.1f} {done:5d}")
    g, p, q, d = rows[0][:4]
    print(f"\nbest: policy={p} quantum={q} pass_depth={d} "
          f"(goodput={g:.4f})")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", help="<arch>/<shape> (model A/B mode)")
    ap.add_argument("--tag")
    ap.add_argument("--set", nargs="*", help="tuning overrides k=v "
                    "(q_chunk, kv_chunk, grad_accum)")
    ap.add_argument("--cfg", nargs="*", help="ModelConfig overrides k=v")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--sched-grid", action="store_true",
                    help="sweep the scheduler quantum x pass_depth x "
                         "victim-key grid as one simulate_batch call")
    ap.add_argument("--quantums", default="1,2,4,8")
    ap.add_argument("--depths", default="16,64")
    ap.add_argument("--jobs", type=int, default=400)
    ap.add_argument("--horizon", type=int, default=200)
    ap.add_argument("--cpu-total", type=int, default=64)
    ap.add_argument("--seed", type=int, default=1)
    ap.add_argument("--backend", default="lax",
                    choices=["lax", "pallas", "pallas_interpret"],
                    help="kernel_backend for the eviction machinery")
    args = ap.parse_args(argv)

    if args.sched_grid:
        sched_grid(args)
        return
    if not args.cell or not args.tag:
        ap.error("--cell and --tag are required (or use --sched-grid)")

    arch, shape = args.cell.split("/")
    override = parse_kv(args.set)
    cfg_over = parse_kv(args.cfg)
    if cfg_over:
        override["cfg"] = cfg_over

    rec = run_cell(arch, shape, args.multi_pod, RESULTS_DIR,
                   tuning_override=override or None, tag=args.tag)

    mesh_name = "2x16x16" if args.multi_pod else "16x16"
    base_path = RESULTS_DIR / f"{arch}__{shape}__{mesh_name}.json"
    if base_path.exists() and rec.get("status") == "ok":
        base = json.loads(base_path.read_text())
        if base.get("status") == "ok":
            b, n = base["roofline"], rec["roofline"]
            bm, nm = base["memory"], rec["memory"]
            print("\n=== delta vs baseline ===")
            for term in ("compute_s", "memory_s", "collective_s"):
                if b[term] > 0:
                    print(f"{term:14s}: {b[term]*1e3:10.1f} -> {n[term]*1e3:10.1f} ms  "
                          f"({(n[term]/b[term]-1)*100:+.1f}%)")
            print(f"{'hbm GiB':14s}: {bm['peak_estimate_bytes']/2**30:10.2f} -> "
                  f"{nm['peak_estimate_bytes']/2**30:10.2f}")


if __name__ == "__main__":
    main()
