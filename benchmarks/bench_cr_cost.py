"""C/R cost: the paper's thrashing-cost term, measured on a real TrainState.

Tiers/codecs compared on one snapshot of a ~25M-param training job:
  mem          — host-DRAM fast tier (the NVM/DCPMM analogue)
  disk_raw     — durable tier, no compression
  disk_zstd    — durable tier, zstd-3
  delta_zstd   — XOR-delta vs previous snapshot + zstd (recurrent C/R)
  int8_quant   — Pallas ckpt_codec block quantization (fast tier, 4x smaller)

Reported: bytes written and save+restore wall time (single CPU core, so the
times are indicative; the BYTES are platform-independent and are what the
roofline-style C/R cost model consumes).

The closing section is the calibration flow (DESIGN.md §C/R cost model):
a `CheckpointService` save/restore cycle on the same state feeds
`CRCostModel.from_stats`, and the resulting integer model predicts the
scheduler-tick cost of checkpointing this job — the measured thrashing
term the simulator charges at eviction/restart.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, write_rows
from repro.checkpoint import delta as delta_mod
from repro.checkpoint.manager import ManagerConfig
from repro.checkpoint.reshard import save_global
from repro.checkpoint.service import CheckpointService
from repro.checkpoint.tiers import DiskTier, MemTier
from repro.configs import get_smoke_config
from repro.core.crcost import state_mib_of
from repro.data.pipeline import DataConfig, SyntheticLM, shard_batch
from repro.kernels.ckpt_codec.ops import dequantize_array, quantize_array
from repro.models.model import build_model
from repro.train.state import init_train_state
from repro.train.steps import TrainConfig, make_train_step


def _train_state(steps=3, smoke=False):
    cfg = get_smoke_config("internlm2-1.8b").replace(
        d_ff=256 if smoke else 512, n_layers=2 if smoke else 4,
        d_model=128 if smoke else 256, vocab=4096 if smoke else 8192)
    model = build_model(cfg, q_chunk=64, kv_chunk=64)
    state = init_train_state(model.init(jax.random.PRNGKey(0)))
    step = jax.jit(make_train_step(model, TrainConfig()), donate_argnums=(0,))
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8))
    states = []
    for i in range(steps):
        state, _ = step(state, shard_batch(data.batch_at(i)))
        states.append(jax.tree.map(lambda a: a.copy(), state))
    return states


def main() -> None:
    import tempfile
    from pathlib import Path

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny model + fewer steps for CI")
    ap.add_argument("--tick-seconds", type=float, default=0.1,
                    help="wall length of one scheduler tick for calibration")
    args = ap.parse_args()

    states = _train_state(steps=2 if args.smoke else 3, smoke=args.smoke)
    prev, cur = save_global(states[-2]), save_global(states[-1])
    total_raw = sum(a.nbytes for a in cur.values())
    emit("cr_cost/state_bytes_raw", total_raw, "fp32 master + adam moments")

    # mem tier
    tier = MemTier(8 << 30)
    t0 = time.perf_counter()
    tier.save_leaves("s", cur)
    t_save = time.perf_counter() - t0
    t0 = time.perf_counter()
    tier.restore("s")
    t_rest = time.perf_counter() - t0
    emit("cr_cost/mem_save_ms", t_save * 1e3, f"restore_ms={t_rest*1e3:.1f}")

    tmp = Path(tempfile.mkdtemp())
    for name, level in (("disk_raw", None), ("disk_zstd", 3)):
        tier = DiskTier(tmp / name, compress=level)
        t0 = time.perf_counter()
        tier.save_leaves("s", cur)
        t_save = time.perf_counter() - t0
        t0 = time.perf_counter()
        tier.restore("s")
        t_rest = time.perf_counter() - t0
        emit(f"cr_cost/{name}_bytes", tier.stats.bytes_written,
             f"save_ms={t_save*1e3:.1f};restore_ms={t_rest*1e3:.1f};"
             f"ratio={tier.stats.bytes_written/total_raw:.3f}")

    # delta vs previous snapshot
    t0 = time.perf_counter()
    blobs, sizes = delta_mod.encode_snapshot(cur, prev)
    t_enc = time.perf_counter() - t0
    delta_bytes = sum(sizes.values())
    emit("cr_cost/delta_zstd_bytes", delta_bytes,
         f"encode_ms={t_enc*1e3:.1f};ratio={delta_bytes/total_raw:.3f};"
         f"delta_frac={np.mean([b.is_delta for b in blobs.values()]):.2f}")

    # int8 quantized fast-tier (optimizer moments; error-tolerant)
    t0 = time.perf_counter()
    q_bytes = 0
    for _k, a in cur.items():
        if a.dtype == np.float32 and a.size >= 128:
            q, s = quantize_array(jnp.asarray(a))
            q_bytes += q.size + s.size * 4
        else:
            q_bytes += a.nbytes
    t_q = time.perf_counter() - t0
    emit("cr_cost/int8_quant_bytes", q_bytes,
         f"encode_ms={t_q*1e3:.1f};ratio={q_bytes/total_raw:.3f}")

    # ---- calibration: measured TierStats -> scheduler CRCostModel ---------
    svc = CheckpointService(ManagerConfig(
        root=tmp / "svc", durable_every=1, async_durable=False))
    template = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), states[-1])
    svc.save(0, states[-2])
    svc.save(1, states[-1])
    svc.restore(template)
    # compress_ratio stays 1.0: the service's measured bandwidth is RAW
    # bytes over wall time that already includes compression, i.e. an
    # effective raw throughput — applying the delta ratio on top would
    # discount the cost twice (see CRCostModel.from_measured)
    model_cal = svc.calibrate(tick_seconds=args.tick_seconds)
    mib = state_mib_of(total_raw)
    emit("cr_cost/model_save_mib_per_tick", model_cal.save_mib_per_tick,
         f"tick_s={args.tick_seconds}")
    emit("cr_cost/model_restore_mib_per_tick", model_cal.restore_mib_per_tick,
         f"tick_s={args.tick_seconds}")
    emit("cr_cost/model_save_ticks", model_cal.save_cost(mib),
         f"state_mib={mib};the eviction charge the simulator applies")
    emit("cr_cost/model_restore_ticks", model_cal.restore_cost(mib),
         f"state_mib={mib};the restart charge the simulator applies")
    svc.close()

    write_rows("cr_cost")


if __name__ == "__main__":
    main()
