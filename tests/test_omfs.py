"""Algorithm 1 unit tests: every branch, every paper quirk, both scenarios."""
import pytest

from repro.core.omfs import runner, scheduler_pass
from repro.core.simulator import simulate
from repro.core.types import (
    ClusterState,
    Job,
    JobClass,
    JobState,
    SchedulerConfig,
    User,
)
from repro.core.workload import oversub_scenario, reclaim_scenario


def make_state(cpu_total=16, quantum=0, users=None, **kw):
    users = users or [User("A", 50.0), User("B", 50.0)]
    cfg = SchedulerConfig(cpu_total=cpu_total, quantum=quantum, **kw)
    return ClusterState(config=cfg, users={u.name: u for u in users})


def add_job(state, **kw):
    job = Job(**kw)
    job.state = JobState.PENDING
    state.jobs[job.id] = job
    return job


def run_job(state, **kw):
    job = add_job(state, **kw)
    dec = runner(state, job)
    assert dec.admitted, dec.reason
    return job


# ---------------------------------------------------------------------------
# line-by-line behaviour
# ---------------------------------------------------------------------------


def test_line23_non_preemptible_within_entitlement_runs():
    st = make_state()
    j = add_job(st, user="A", cpus=7, work=10, job_class=JobClass.NON_PREEMPTIBLE)
    assert runner(st, j).admitted


def test_line23_exact_entitlement_quirk():
    """Paper uses >=: a non-preemptible job EXACTLY at the entitlement is
    rejected (kept faithfully; see DESIGN.md)."""
    st = make_state()
    j = add_job(st, user="A", cpus=8, work=10, job_class=JobClass.NON_PREEMPTIBLE)
    dec = runner(st, j)
    assert not dec.admitted and "line 23" in dec.reason


def test_line26_idle_overrides_entitlement():
    """Checkpointable jobs may exceed their entitlement on an idle machine."""
    st = make_state()
    j = add_job(st, user="A", cpus=12, work=10, job_class=JobClass.CHECKPOINTABLE)
    dec = runner(st, j)
    assert dec.admitted and "line 26" in dec.reason


def test_line26_strict_inequality_quirk():
    """Paper uses >: a job wanting EXACTLY all idle CPUs doesn't pass line
    26; over-entitlement it then dies at line 28 (quirk kept faithfully)."""
    st = make_state()
    j = add_job(st, user="A", cpus=16, work=10, job_class=JobClass.CHECKPOINTABLE)
    dec = runner(st, j)
    assert not dec.admitted and "line 28" in dec.reason


def test_line28_within_entitlement_equal_boundary_ok():
    """cpus == unused entitlement passes line 28 (strict >)."""
    st = make_state()
    run_job(st, user="B", cpus=16 - 1, work=100, job_class=JobClass.CHECKPOINTABLE,
            priority=0)
    # machine nearly full; A asks for exactly its entitlement -> eviction path
    st.time = 100  # everyone past quantum
    j = add_job(st, user="A", cpus=8, work=10, job_class=JobClass.CHECKPOINTABLE)
    dec = runner(st, j)
    assert dec.admitted
    assert dec.checkpointed, "B's checkpointable job must have been checkpointed"


def test_eviction_prefers_lowest_priority_then_longest_running():
    st = make_state(cpu_total=16, quantum=0)
    j_low = run_job(st, user="B", cpus=6, work=100,
                    job_class=JobClass.CHECKPOINTABLE, priority=0)
    j_high = run_job(st, user="B", cpus=6, work=100,
                     job_class=JobClass.CHECKPOINTABLE, priority=5)
    st.time = 10
    j = add_job(st, user="A", cpus=8, work=10, job_class=JobClass.CHECKPOINTABLE)
    dec = runner(st, j)
    assert dec.admitted
    assert j_low.id in dec.evicted
    assert j_high.id not in dec.evicted


def test_non_checkpointable_victims_are_dropped():
    st = make_state(cpu_total=16, quantum=0)
    victim = run_job(st, user="B", cpus=12, work=100,
                     job_class=JobClass.PREEMPTIBLE)
    st.time = 10
    j = add_job(st, user="A", cpus=8, work=10, job_class=JobClass.CHECKPOINTABLE)
    dec = runner(st, j)
    assert dec.admitted and victim.id in dec.killed
    assert victim.state == JobState.KILLED  # line 34: dropped


def test_quantum_protects_fresh_jobs():
    st = make_state(cpu_total=16, quantum=30)
    run_job(st, user="B", cpus=12, work=100, job_class=JobClass.CHECKPOINTABLE)
    st.time = 10  # victim has run 10 < 30 ticks: not evictable
    j = add_job(st, user="A", cpus=8, work=10, job_class=JobClass.CHECKPOINTABLE)
    dec = runner(st, j)
    assert not dec.admitted and "quantum" in dec.reason
    st.time = 31  # quantum elapsed
    dec = runner(st, j)
    assert dec.admitted


def test_non_preemptible_jobs_never_evicted():
    st = make_state(cpu_total=16, quantum=0)
    safe = run_job(st, user="B", cpus=7, work=100,
                   job_class=JobClass.NON_PREEMPTIBLE)
    run_job(st, user="B", cpus=8, work=100, job_class=JobClass.CHECKPOINTABLE)
    st.time = 100
    j = add_job(st, user="A", cpus=8, work=10, job_class=JobClass.CHECKPOINTABLE)
    dec = runner(st, j)
    assert dec.admitted
    assert safe.id not in dec.evicted
    assert safe.state == JobState.RUNNING


def test_memorylessness_no_history_penalty():
    """A user who hogged the idle machine for ages is NOT penalized once
    the other user's demand is satisfied — admission only looks at current
    allocation (unlike history-based fair share)."""
    st = make_state(cpu_total=16, quantum=0)
    hog = run_job(st, user="B", cpus=12, work=10_000, job_class=JobClass.CHECKPOINTABLE)
    st.time = 5_000  # B hogged for 5000 ticks
    j = add_job(st, user="A", cpus=4, work=10, job_class=JobClass.CHECKPOINTABLE)
    assert runner(st, j).admitted  # line 26 (idle = 4 > ... no; idle=4, not > 4)
    # B can immediately re-grow into freed capacity later: no decayed usage
    st.jobs[j.id].state = JobState.DONE
    j2 = add_job(st, user="B", cpus=3, work=10, job_class=JobClass.CHECKPOINTABLE)
    assert runner(st, j2).admitted


# ---------------------------------------------------------------------------
# paper scenarios end-to-end
# ---------------------------------------------------------------------------


def test_oversub_scenario():
    """A job larger than its user's whole entitlement runs on an idle
    machine with no manual intervention (paper SII)."""
    users, jobs, jid = oversub_scenario(64)
    res = simulate(users, jobs, SchedulerConfig(cpu_total=64, quantum=5), horizon=400)
    j = res.state.jobs[jid]
    assert j.state == JobState.DONE
    assert j.first_start <= 2


def test_reclaim_scenario_immediate():
    """The entitled user reclaims capacity immediately (memoryless
    fairness), with the flooding user's jobs transparently checkpointed."""
    users, jobs, jid = reclaim_scenario(64, quantum=10)
    res = simulate(users, jobs, SchedulerConfig(cpu_total=64, quantum=10), horizon=400)
    j = res.state.jobs[jid]
    assert j.first_start - j.submit_time <= 2
    assert sum(x.n_checkpoints for x in res.state.jobs.values()) >= 1


def test_cr_overhead_accounting():
    users, jobs, jid = reclaim_scenario(64, quantum=10)
    res = simulate(users, jobs, SchedulerConfig(cpu_total=64, quantum=10, cr_overhead=7),
                   horizon=400)
    evicted = [x for x in res.state.jobs.values() if x.n_checkpoints > 0]
    assert evicted and all(x.overhead == 7 * x.n_checkpoints for x in evicted)


def test_killed_requeue_restart_pays_no_restore_cost():
    """drop_killed=False restarts a PREEMPTIBLE victim from scratch: there
    is no snapshot to read, so the size-aware restore cost must NOT be
    charged (only checkpointed jobs pay at restart)."""
    from repro.core.crcost import CRCostModel

    st = make_state(cpu_total=16, quantum=0, drop_killed=False, cr_overhead=3,
                    cr_cost=CRCostModel(save_mib_per_tick=1,
                                        restore_mib_per_tick=1,
                                        save_base=2, restore_base=2))
    victim = run_job(st, user="B", cpus=12, work=100,
                     job_class=JobClass.PREEMPTIBLE, state_bytes=64 << 20)
    st.time = 10
    j = add_job(st, user="A", cpus=8, work=10, job_class=JobClass.CHECKPOINTABLE)
    dec = runner(st, j)
    assert dec.admitted and victim.id in dec.killed
    assert victim.state == JobState.PENDING and victim.progress == 0
    assert victim.overhead == 0          # neither save nor restore charged
    # restart it: still nothing (n_checkpoints == 0 -> nothing to restore)
    st.jobs[j.id].state = JobState.DONE
    dec2 = runner(st, victim)
    assert dec2.admitted
    assert victim.overhead == 0
