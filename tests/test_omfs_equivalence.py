"""Property test: the vectorized JAX scheduler is step-equivalent to the
Python Algorithm-1 reference on randomized workloads."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import omfs_jax
from repro.core.simulator import simulate
from repro.core.types import Job, JobClass, JobState, SchedulerConfig, User
from repro.core.workload import WorkloadSpec, make_jobs, make_users


def _signatures(users, jobs, cfg, horizon):
    res = simulate(users, [j.clone() for j in jobs], cfg, horizon)
    tbl, busy = omfs_jax.simulate_jax(users, jobs, cfg, horizon)
    py = [t[1:] for t in res.schedule_signature()]   # drop ids
    jx = [t[1:] for t in omfs_jax.signature_from_table(tbl)]
    return py, jx, res, busy


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    quantum=st.integers(0, 20),
    cr=st.integers(0, 5),
    n_users=st.integers(2, 4),
)
def test_python_jax_equivalence(seed, quantum, cr, n_users):
    spec = WorkloadSpec(
        n_users=n_users, horizon=120, cpu_total=32, seed=seed,
        arrival_rate=0.1, mean_work=30,
    )
    users = make_users(spec)
    jobs = make_jobs(spec, users)[:40]
    if not jobs:
        return
    cfg = SchedulerConfig(cpu_total=32, quantum=quantum, cr_overhead=cr)
    py, jx, _, _ = _signatures(users, jobs, cfg, spec.horizon)
    assert py == jx


@pytest.mark.parametrize("drop_killed", [True, False])
def test_equivalence_kill_policies(drop_killed):
    spec = WorkloadSpec(n_users=3, horizon=150, cpu_total=32, seed=7,
                        arrival_rate=0.12, mean_work=40,
                        class_mix=(0.1, 0.6, 0.3))
    users = make_users(spec)
    jobs = make_jobs(spec, users)[:40]
    cfg = SchedulerConfig(cpu_total=32, quantum=5, drop_killed=drop_killed)
    py, jx, _, _ = _signatures(users, jobs, cfg, spec.horizon)
    assert py == jx


def test_busy_series_matches_python_log():
    spec = WorkloadSpec(n_users=3, horizon=100, cpu_total=32, seed=3)
    users = make_users(spec)
    jobs = make_jobs(spec, users)[:30]
    cfg = SchedulerConfig(cpu_total=32, quantum=10)
    res = simulate(users, [j.clone() for j in jobs], cfg, 100)
    _, busy = omfs_jax.simulate_jax(users, jobs, cfg, 100)
    py_busy = np.array([t.busy for t in res.log])
    assert (np.asarray(busy) == py_busy).all()


def test_beyond_paper_flags_equivalent_too():
    spec = WorkloadSpec(n_users=3, horizon=120, cpu_total=32, seed=11)
    users = make_users(spec)
    jobs = make_jobs(spec, users)[:30]
    cfg = SchedulerConfig(
        cpu_total=32, quantum=5,
        victim_filter_over_entitlement=True, avoid_self_eviction=True)
    py, jx, _, _ = _signatures(users, jobs, cfg, spec.horizon)
    assert py == jx
