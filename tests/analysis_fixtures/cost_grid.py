"""Seeded cost-grid violations: floats / true division reaching the /256
integer cost grid (exact lines asserted by the test)."""


def build(jobs, JobTable):
    cost_save = jobs.mib / 256             # line 5: cost-grid true division
    return JobTable(
        cost_save=cost_save,
        cost_restore=jobs.mib * 1.5,       # line 8: cost-grid float literal
    )


def save_cost(mib, rate):
    return float(mib) / rate               # line 13: cost-grid in grid fn


def fine(jobs, JobTable):
    return JobTable(
        cost_save=(jobs.mib + 255) // 256,   # integer ceil-div: clean
        cost_restore=jobs.mib // 256,
    )
