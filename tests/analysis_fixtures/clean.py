"""Negative fixture: correct idioms only — the analyzer must report ZERO
violations for this file."""
import threading

import jax
import jax.numpy as jnp
from jax import lax


@jax.jit
def branchless(tbl):
    need = jnp.maximum(tbl.cpus - 4, 0)
    return lax.cond(jnp.any(need > 0).astype(bool).dtype == jnp.bool_.dtype,
                    lambda t: t, lambda t: t, tbl)


@jax.jit
def static_shapes(tbl, cfg):
    # cfg is a static jit arg; shape/dtype reads are trace-time constants
    if cfg.cpu_total > 8:
        k = tbl.cpus.shape[0]
        return jnp.zeros((k,), dtype=tbl.cpus.dtype)
    return tbl.cpus


def integer_grid(jobs, JobTable):
    return JobTable(cost_save=(jobs.mib + 255) // 256)


class GuardedCounter:
    def __init__(self):
        self._lock = threading.Lock()
        self.count = 0

    def bump(self):
        with self._lock:
            self.count += 1

    def read(self):
        with self._lock:
            return self.count
