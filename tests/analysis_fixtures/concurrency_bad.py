"""Seeded thread-shared-state + lock-order violations (exact lines
asserted by the test)."""
import threading
from concurrent.futures import ThreadPoolExecutor


class Writer:
    def __init__(self):
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._lock = threading.Lock()
        self.count = 0
        self.log = []

    def start(self, payload):
        return self._pool.submit(self._write, payload)

    def _write(self, payload):
        self.count += 1                    # line 18: thread-shared-state
        self.log = self.log + [payload]    # line 19: thread-shared-state

    def snapshot(self):
        self.count = 0                     # line 22: thread-shared-state
        return list(self.log)


class Deadlocker:
    def __init__(self):
        self.a_lock = threading.Lock()
        self.b_lock = threading.Lock()
        self.x = 0

    def ab(self):
        with self.a_lock:
            with self.b_lock:              # line 34: lock-order (a->b)
                self.x += 1

    def ba(self):
        with self.b_lock:
            with self.a_lock:              # line 39: lock-order (b->a)
                self.x -= 1
