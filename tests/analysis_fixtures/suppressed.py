"""Suppression mechanics fixture (exact lines asserted by the test)."""


def tolerated(x, acc=[]):  # analysis: ignore[mutable-default] -- fixture: valid suppression
    return acc + [x]


def unused_suppression(x):
    return x + 1  # analysis: ignore[tracer-leak] -- nothing to suppress here


def missing_reason(x, acc=[]):  # analysis: ignore[mutable-default]
    return acc + [x]


def unknown_rule(x):
    return x  # analysis: ignore[no-such-rule] -- bogus rule id
