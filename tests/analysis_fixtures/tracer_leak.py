"""Seeded tracer-leak violations — every flagged line is asserted exactly
by tests/test_analysis.py; renumbering lines requires updating the test."""
import jax
import jax.numpy as jnp


@jax.jit
def bad_branch(tbl):
    x = jnp.sum(tbl.cpus)
    if x > 0:                              # line 10: tracer-leak (if)
        return x
    return -x


@jax.jit
def bad_conversions(tbl):
    n = int(jnp.sum(tbl.work))             # line 17: tracer-leak int()
    flag = bool(tbl.state[0])              # line 18: tracer-leak bool()
    v = jnp.max(tbl.priority).item()       # line 19: tracer-leak .item()
    return n + int(flag) + v


def soft_context(tbl):
    # no @jit, but a JobTable param: still a leak when branching on columns
    while jnp.any(tbl.state == 1):         # line 26: tracer-leak (while)
        tbl = tbl._replace(state=tbl.state * 0)
    return tbl


@jax.jit
def fine(tbl):
    x = jnp.sum(tbl.cpus)
    y = jnp.where(x > 0, x, -x)            # branchless: clean
    if tbl.cpus.shape[0] > 4:              # shape is static: clean
        y = y + 1
    return y


def host_epilogue(tbl):
    # soft context + explicit device_get laundering: clean
    total = int(jax.device_get(jnp.sum(tbl.cpus)))
    if total > 0:
        return total
    return 0
