"""Seeded host-sync violations (exact lines asserted by the test)."""
import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def hidden_syncs(tbl):
    occ = jnp.cumsum(tbl.cpus)
    host = np.asarray(occ)                 # line 10: host-sync np.asarray
    occ.block_until_ready()                # line 11: host-sync block_until_ready
    return host


def host_side_helper(tbl):
    # soft context: an explicit host transfer here is the *point* of the
    # helper (signature_from_table does exactly this) — clean.
    return np.asarray(jax.device_get(tbl.cpus)).tolist()
