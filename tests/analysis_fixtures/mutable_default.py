"""Seeded mutable-default violations (exact lines asserted by the test)."""


def bad_list(x, acc=[]):                   # line 4: mutable-default
    acc.append(x)
    return acc


def bad_dict(x, seen={}):                  # line 9: mutable-default
    seen[x] = True
    return seen


def bad_call(x, order=list()):             # line 14: mutable-default
    order.append(x)
    return order


def fine(x, acc=None, n=0, name="q", tags=()):
    return (acc or []) + [x]
