"""Analyzer self-tests: every rule fires on its seeded fixture at the
exact line, stays silent on the clean fixture, and the CLI exit codes +
suppression mechanics behave.

The fixtures live in ``tests/analysis_fixtures/`` (excluded from the
default ``src/repro`` scan).  Assertions pin ``(rule, line)`` pairs, so
editing a fixture means re-pinning here — deliberate: the analyzer's
output location is part of its contract (CI step summaries link to it).
"""
from pathlib import Path

import pytest

from repro import analysis
from repro.analysis import known_failures
from repro.analysis.base import RULES, SourceFile, known_rule_ids
from repro.analysis.concurrency import analyze_concurrency

REPO = Path(__file__).resolve().parent.parent
FIXTURES = REPO / "tests" / "analysis_fixtures"


def run_file_rules(*names):
    violations, _ = analysis.collect_violations(
        REPO, targets=[FIXTURES / n for n in names],
        include_trace=False, include_project=False)
    return sorted((v.rule, v.line) for v in violations)


def test_registry_is_complete():
    assert sorted(RULES) == [
        "backend-contract", "branch-confinement", "column-dataflow",
        "cost-grid", "event-schema", "host-sync", "jaxpr-float-cast",
        "known-failures", "lock-order", "mutable-default", "retrace",
        "thread-shared-state", "tracer-leak"]
    assert "suppression" in known_rule_ids()
    for rule in RULES.values():
        assert rule.kind in ("file", "project", "trace")
        assert rule.doc


def test_tracer_leak_fixture_exact_lines():
    assert run_file_rules("tracer_leak.py") == [
        ("tracer-leak", 10),     # if on traced value
        ("tracer-leak", 17),     # int()
        ("tracer-leak", 18),     # bool()
        ("tracer-leak", 19),     # .item()
        ("tracer-leak", 20),     # int(flag) — taint flows through flag
        ("tracer-leak", 25),     # while on traced value (soft context)
    ]


def test_host_sync_fixture_exact_lines():
    assert run_file_rules("host_sync.py") == [
        ("host-sync", 10),       # np.asarray inside jit
        ("host-sync", 11),       # .block_until_ready inside jit
    ]


def test_cost_grid_fixture_exact_lines():
    assert run_file_rules("cost_grid.py") == [
        ("cost-grid", 6),        # true division assigned to cost_save
        ("cost-grid", 9),        # float literal in JobTable keyword
        ("cost-grid", 14),       # float() inside a grid cost function
    ]


def test_mutable_default_fixture_exact_lines():
    assert run_file_rules("mutable_default.py") == [
        ("mutable-default", 4),
        ("mutable-default", 9),
        ("mutable-default", 14),
    ]


def test_clean_fixture_is_silent():
    assert run_file_rules("clean.py") == []


def test_suppression_mechanics():
    got = run_file_rules("suppressed.py")
    # line 4's mutable-default is validly suppressed — absent from output
    assert ("mutable-default", 4) not in got
    assert got == [
        ("mutable-default", 12),  # missing-reason suppression doesn't count
        ("suppression", 9),       # unused suppression
        ("suppression", 12),      # missing '-- reason'
        ("suppression", 17),      # unknown rule id
    ]


def test_concurrency_fixture_exact_lines():
    sf = SourceFile(FIXTURES / "concurrency_bad.py")
    got = sorted((v.rule, v.line) for v in analyze_concurrency([sf]))
    assert got == [
        ("lock-order", 34),            # a->b here, b->a at line 39
        ("thread-shared-state", 18),   # _write runs on the pool thread
        ("thread-shared-state", 19),
        ("thread-shared-state", 22),   # snapshot races the pool thread
    ]


def test_cli_exit_codes(capsys):
    # violations -> nonzero, rule id + file:line on stdout
    rc = analysis.main([
        "--no-trace", "--no-project",
        str(FIXTURES / "mutable_default.py")])
    out = capsys.readouterr().out
    assert rc == 1
    assert "[mutable-default]" in out
    assert "mutable_default.py:4" in out
    # clean file -> zero
    rc = analysis.main([
        "--no-trace", "--no-project", str(FIXTURES / "clean.py")])
    assert rc == 0


def test_real_tree_is_analysis_clean():
    """src/repro passes every file + project rule (the CI gate, minus the
    trace layer, which compiles and is exercised by the analysis CI job)."""
    violations, _ = analysis.collect_violations(REPO, include_trace=False)
    assert violations == [], "\n".join(str(v) for v in violations)


def test_backend_contract_flags_missing_equivalence_entry(tmp_path):
    """A policy registered in the live engine but absent from a
    literal-name equivalence suite is flagged (one violation per
    uncovered policy); a registry-derived suite covers by construction."""
    from repro.analysis.contracts import check_backend_contract
    from repro.core import engine

    fake = tmp_path / "tests" / "test_policies_equivalence.py"
    fake.parent.mkdir(parents=True)
    fake.write_text('def test_one():\n    run("omfs")\n')
    got = [v for v in check_backend_contract(tmp_path)
           if "never exercised" in v.message]
    uncovered = sorted(engine.POLICIES)
    assert len(got) == len(uncovered) - 1          # every policy but "omfs"
    assert all(v.rule == "backend-contract" for v in got)

    fake.write_text("from repro.core import engine\n"
                    "NAMES = sorted(engine.POLICIES)\n")
    assert [v for v in check_backend_contract(tmp_path)
            if "never exercised" in v.message] == []


def _event_tree(tmp_path, *, events, capture="", metrics="", trace="",
                engine="", kernel=""):
    """Materialize a minimal fake tree for the event-schema rule."""
    obs = tmp_path / "src" / "repro" / "obs"
    core = tmp_path / "src" / "repro" / "core"
    obs.mkdir(parents=True)
    core.mkdir(parents=True)
    (obs / "events.py").write_text(events)
    if capture is not None:
        (obs / "jax_capture.py").write_text(capture)
    (obs / "metrics.py").write_text(metrics)
    (obs / "trace.py").write_text(trace)
    (core / "engine.py").write_text(engine)
    (core / "omfs.py").write_text(kernel)
    return tmp_path


_SCHEMA_OK = """\
class EventType:
    SUBMIT = 0
    FINISH = 1

def events_from_diff(pre, jobs, t):
    use(EventType.SUBMIT, EventType.FINISH)
"""

_CAPTURE_OK = """\
def event_flags(pre, post, t):
    use(EventType.SUBMIT, EventType.FINISH)
"""

_CONSUME_OK = "use(EventType.SUBMIT, EventType.FINISH)\n"


def test_event_schema_clean_tree_passes(tmp_path):
    from repro.analysis.event_schema import check_event_schema

    root = _event_tree(tmp_path, events=_SCHEMA_OK, capture=_CAPTURE_OK,
                       metrics=_CONSUME_OK)
    assert check_event_schema(root) == []


def test_event_schema_flags_unemitted_and_unconsumed(tmp_path):
    """A declared type the Python emitter / JAX flag matrix / consumers
    never touch is a silent telemetry hole — three distinct violations."""
    from repro.analysis.event_schema import check_event_schema

    events = ("class EventType:\n    SUBMIT = 0\n    EVICT = 1\n\n"
              "def events_from_diff(pre, jobs, t):\n"
              "    use(EventType.SUBMIT)\n")
    root = _event_tree(tmp_path, events=events,
                       capture="def event_flags(pre, post, t):\n"
                               "    use(EventType.SUBMIT)\n",
                       metrics="use(EventType.SUBMIT)\n")
    msgs = [v.message for v in check_event_schema(root)]
    assert any("events_from_diff never references" in m for m in msgs)
    assert any("event_flags" in m for m in msgs)
    assert any("nor the trace exporter consumes" in m for m in msgs)
    # the declared-but-unemitted violations pin the enum member's line
    lines = [v.line for v in check_event_schema(root)
             if "events_from_diff" in v.message]
    assert lines == [3]                            # EVICT = 1


def test_event_schema_flags_phantom_reference(tmp_path):
    from repro.analysis.event_schema import check_event_schema

    root = _event_tree(tmp_path, events=_SCHEMA_OK, capture=_CAPTURE_OK,
                       metrics=_CONSUME_OK,
                       trace="x = EventType.TELEPORT\n")
    got = [v for v in check_event_schema(root)
           if "referenced but not declared" in v.message]
    assert len(got) == 1
    assert got[0].line == 1


def test_event_schema_flags_hot_path_capture(tmp_path):
    """The uninstrumented tick path referencing the capture layer breaks
    the byte-identical guarantee; the *_events twins are exempt."""
    from repro.analysis.event_schema import check_event_schema

    engine = ("def _tick_step(cfg, tbl, t):\n"
              "    return capture_tick(tbl, tbl, t, 8)\n"
              "def _jitted_runner_events(cfg):\n"
              "    return capture_tick\n")
    root = _event_tree(tmp_path, events=_SCHEMA_OK, capture=_CAPTURE_OK,
                       metrics=_CONSUME_OK, engine=engine)
    got = [v for v in check_event_schema(root)
           if "hot-path" in v.message]
    assert len(got) == 1                           # only _tick_step, not twin
    assert "_tick_step" in got[0].message


def test_event_schema_flags_kernel_obs_import(tmp_path):
    from repro.analysis.event_schema import check_event_schema

    root = _event_tree(tmp_path, events=_SCHEMA_OK, capture=_CAPTURE_OK,
                       metrics=_CONSUME_OK,
                       kernel="from repro.obs.bus import EventBus\n")
    got = [v for v in check_event_schema(root)
           if "kernel imports repro.obs" in v.message]
    assert len(got) == 1


def test_event_schema_flags_missing_schema_files(tmp_path):
    from repro.analysis.event_schema import check_event_schema

    (tmp_path / "src" / "repro").mkdir(parents=True)
    got = check_event_schema(tmp_path)
    assert len(got) == 1 and "events.py missing" in got[0].message

    root = _event_tree(tmp_path, events=_SCHEMA_OK, metrics=_CONSUME_OK)
    (root / "src" / "repro" / "obs" / "jax_capture.py").unlink()
    msgs = [v.message for v in check_event_schema(root)]
    assert any("no in-scan emitter" in m for m in msgs)


def test_known_failures_registry_valid_and_loadable():
    assert known_failures.check_known_failures(REPO) == []
    known = known_failures.load_known_failures(REPO)
    assert len(known) >= 1
    for nodeid, reason in known.items():
        assert "::" in nodeid and reason.strip()


def test_github_summary_format():
    from repro.analysis import _github_summary
    from repro.analysis.base import Violation

    md = _github_summary([Violation("cost-grid", "a.py", 3, "x | y")])
    assert "| `cost-grid` | `a.py:3` |" in md
    assert "x \\| y" in md
    assert "No violations" in _github_summary([])
