"""Analyzer self-tests: every rule fires on its seeded fixture at the
exact line, stays silent on the clean fixture, and the CLI exit codes +
suppression mechanics behave.

The fixtures live in ``tests/analysis_fixtures/`` (excluded from the
default ``src/repro`` scan).  Assertions pin ``(rule, line)`` pairs, so
editing a fixture means re-pinning here — deliberate: the analyzer's
output location is part of its contract (CI step summaries link to it).
"""
from pathlib import Path

import pytest

from repro import analysis
from repro.analysis import known_failures
from repro.analysis.base import RULES, SourceFile, known_rule_ids
from repro.analysis.concurrency import analyze_concurrency

REPO = Path(__file__).resolve().parent.parent
FIXTURES = REPO / "tests" / "analysis_fixtures"


def run_file_rules(*names):
    violations, _ = analysis.collect_violations(
        REPO, targets=[FIXTURES / n for n in names],
        include_trace=False, include_project=False)
    return sorted((v.rule, v.line) for v in violations)


def test_registry_is_complete():
    assert sorted(RULES) == [
        "backend-contract", "branch-confinement", "column-dataflow",
        "cost-grid", "host-sync", "jaxpr-float-cast", "known-failures",
        "lock-order", "mutable-default", "retrace", "thread-shared-state",
        "tracer-leak"]
    assert "suppression" in known_rule_ids()
    for rule in RULES.values():
        assert rule.kind in ("file", "project", "trace")
        assert rule.doc


def test_tracer_leak_fixture_exact_lines():
    assert run_file_rules("tracer_leak.py") == [
        ("tracer-leak", 10),     # if on traced value
        ("tracer-leak", 17),     # int()
        ("tracer-leak", 18),     # bool()
        ("tracer-leak", 19),     # .item()
        ("tracer-leak", 20),     # int(flag) — taint flows through flag
        ("tracer-leak", 25),     # while on traced value (soft context)
    ]


def test_host_sync_fixture_exact_lines():
    assert run_file_rules("host_sync.py") == [
        ("host-sync", 10),       # np.asarray inside jit
        ("host-sync", 11),       # .block_until_ready inside jit
    ]


def test_cost_grid_fixture_exact_lines():
    assert run_file_rules("cost_grid.py") == [
        ("cost-grid", 6),        # true division assigned to cost_save
        ("cost-grid", 9),        # float literal in JobTable keyword
        ("cost-grid", 14),       # float() inside a grid cost function
    ]


def test_mutable_default_fixture_exact_lines():
    assert run_file_rules("mutable_default.py") == [
        ("mutable-default", 4),
        ("mutable-default", 9),
        ("mutable-default", 14),
    ]


def test_clean_fixture_is_silent():
    assert run_file_rules("clean.py") == []


def test_suppression_mechanics():
    got = run_file_rules("suppressed.py")
    # line 4's mutable-default is validly suppressed — absent from output
    assert ("mutable-default", 4) not in got
    assert got == [
        ("mutable-default", 12),  # missing-reason suppression doesn't count
        ("suppression", 9),       # unused suppression
        ("suppression", 12),      # missing '-- reason'
        ("suppression", 17),      # unknown rule id
    ]


def test_concurrency_fixture_exact_lines():
    sf = SourceFile(FIXTURES / "concurrency_bad.py")
    got = sorted((v.rule, v.line) for v in analyze_concurrency([sf]))
    assert got == [
        ("lock-order", 34),            # a->b here, b->a at line 39
        ("thread-shared-state", 18),   # _write runs on the pool thread
        ("thread-shared-state", 19),
        ("thread-shared-state", 22),   # snapshot races the pool thread
    ]


def test_cli_exit_codes(capsys):
    # violations -> nonzero, rule id + file:line on stdout
    rc = analysis.main([
        "--no-trace", "--no-project",
        str(FIXTURES / "mutable_default.py")])
    out = capsys.readouterr().out
    assert rc == 1
    assert "[mutable-default]" in out
    assert "mutable_default.py:4" in out
    # clean file -> zero
    rc = analysis.main([
        "--no-trace", "--no-project", str(FIXTURES / "clean.py")])
    assert rc == 0


def test_real_tree_is_analysis_clean():
    """src/repro passes every file + project rule (the CI gate, minus the
    trace layer, which compiles and is exercised by the analysis CI job)."""
    violations, _ = analysis.collect_violations(REPO, include_trace=False)
    assert violations == [], "\n".join(str(v) for v in violations)


def test_backend_contract_flags_missing_equivalence_entry(tmp_path):
    """A policy registered in the live engine but absent from a
    literal-name equivalence suite is flagged (one violation per
    uncovered policy); a registry-derived suite covers by construction."""
    from repro.analysis.contracts import check_backend_contract
    from repro.core import engine

    fake = tmp_path / "tests" / "test_policies_equivalence.py"
    fake.parent.mkdir(parents=True)
    fake.write_text('def test_one():\n    run("omfs")\n')
    got = [v for v in check_backend_contract(tmp_path)
           if "never exercised" in v.message]
    uncovered = sorted(engine.POLICIES)
    assert len(got) == len(uncovered) - 1          # every policy but "omfs"
    assert all(v.rule == "backend-contract" for v in got)

    fake.write_text("from repro.core import engine\n"
                    "NAMES = sorted(engine.POLICIES)\n")
    assert [v for v in check_backend_contract(tmp_path)
            if "never exercised" in v.message] == []


def test_known_failures_registry_valid_and_loadable():
    assert known_failures.check_known_failures(REPO) == []
    known = known_failures.load_known_failures(REPO)
    assert len(known) >= 1
    for nodeid, reason in known.items():
        assert "::" in nodeid and reason.strip()


def test_github_summary_format():
    from repro.analysis import _github_summary
    from repro.analysis.base import Violation

    md = _github_summary([Violation("cost-grid", "a.py", 3, "x | y")])
    assert "| `cost-grid` | `a.py:3` |" in md
    assert "x \\| y" in md
    assert "No violations" in _github_summary([])
