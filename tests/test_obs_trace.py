"""Perfetto/Chrome trace exporter: structural validity of real traces,
the validator's ability to catch seeded corruption, and the CLI gate."""
import json

from repro.core import engine
from repro.core.types import SchedulerConfig
from repro.core.workload import WorkloadSpec, make_jobs, make_users
from repro.obs import trace_from_result, validate_trace
from repro.obs.trace import US_PER_TICK, main as trace_main


def _workload(seed=7, horizon=120, cpus=32, quantum=4):
    spec = WorkloadSpec(n_users=3, horizon=horizon, cpu_total=cpus, seed=seed,
                        arrival_rate=0.12, mean_work=30,
                        class_mix=(0.15, 0.35, 0.5))
    users = make_users(spec)
    jobs = make_jobs(spec, users)[:30]
    cfg = SchedulerConfig(cpu_total=cpus, quantum=quantum, cr_overhead=2)
    return users, jobs, cfg


def _sim(backend="python", seed=7, policy="omfs", horizon=120, cpus=32,
         quantum=4):
    users, jobs, cfg = _workload(seed, horizon, cpus, quantum)
    res = engine.simulate(users, jobs, cfg, horizon, policy=policy,
                          backend=backend, record_events=True)
    return users, res


def test_trace_is_valid_and_structured():
    users, res = _sim()
    trace = trace_from_result(res, users=users)
    assert validate_trace(trace, events=res.events) == []
    evs = trace["traceEvents"]
    spans = [e for e in evs if e.get("ph") == "X"]
    assert spans, "no job spans in a busy schedule"
    # every span sits on a real CPU lane and names a known job
    n_lanes = res.config.cpu_total
    assert all(0 <= e["tid"] < n_lanes for e in spans)
    assert all(e["args"]["user"] != "?" for e in spans)
    # metadata names every lane
    names = {e["args"]["name"] for e in evs
             if e.get("ph") == "M" and e.get("name") == "thread_name"}
    assert names == {f"cpu-{k:02d}" for k in range(n_lanes)}
    # counters cover the horizon
    busy = [e for e in evs
            if e.get("ph") == "C" and e.get("name") == "busy_cpus"]
    assert len(busy) == res.busy_series().size
    assert trace["otherData"]["events_dropped"] == 0


def test_trace_eviction_arrows_pair_and_cross_lanes():
    # seed/cpus chosen so omfs actually evicts and restarts (4 restores)
    users, res = _sim(policy="omfs", seed=12, cpus=16, quantum=2)
    trace = trace_from_result(res, users=users)
    flows = [e for e in trace["traceEvents"] if e.get("ph") in ("s", "f")]
    starts = [e for e in flows if e["ph"] == "s"]
    ends = [e for e in flows if e["ph"] == "f"]
    # quantum preemption under contention produces evict->restart arrows
    assert starts and len(starts) == len(ends)
    by_id = {}
    for e in flows:
        by_id.setdefault(e["id"], []).append(e)
    for pair in by_id.values():
        phases = sorted(p["ph"] for p in pair)
        # every arrow id pairs s with f, arrow points forward in time
        assert phases.count("s") == phases.count("f")
        ts = {p["ph"]: p["ts"] for p in pair[:2]}
        if "s" in ts and "f" in ts:
            assert ts["f"] >= ts["s"]


def test_trace_cross_backend_identical():
    users, jobs, cfg = _workload()
    py = engine.simulate(users, jobs, cfg, 120, policy="omfs",
                         backend="python", record_events=True)
    jx = engine.simulate(users, jobs, cfg, 120, policy="omfs",
                         backend="jax", record_events=True)
    t_py = trace_from_result(py, users=users)
    t_jx = trace_from_result(jx, users=users)
    # normalize the backend tag, everything else must match exactly
    t_py["otherData"]["backend"] = t_jx["otherData"]["backend"] = "any"
    assert json.dumps(t_py, sort_keys=True) == json.dumps(t_jx,
                                                          sort_keys=True)


def test_trace_dropped_counter_surfaces_overflow():
    spec = WorkloadSpec(n_users=3, horizon=100, cpu_total=32, seed=9,
                        arrival_rate=0.12, mean_work=30,
                        class_mix=(0.15, 0.35, 0.5))
    users = make_users(spec)
    jobs = make_jobs(spec, users)[:30]
    cfg = SchedulerConfig(cpu_total=32, quantum=4, cr_overhead=2)
    res = engine.simulate(users, jobs, cfg, 100, policy="omfs",
                          backend="jax", record_events=True, event_ring=4)
    assert res.events_dropped_total() > 0
    trace = trace_from_result(res, users=users)
    dropped = [e for e in trace["traceEvents"]
               if e.get("ph") == "C" and e.get("name") == "events_dropped"]
    assert dropped, "ring overflow must surface as a counter track"
    assert (sum(e["args"]["dropped"] for e in dropped)
            == res.events_dropped_total())
    assert trace["otherData"]["events_dropped"] == res.events_dropped_total()


# ---------------------------------------------------------------------------
# the validator actually catches corruption
# ---------------------------------------------------------------------------


def _valid_trace():
    users, res = _sim()
    return trace_from_result(res, users=users), res


def test_validator_catches_lane_overlap():
    trace, _ = _valid_trace()
    spans = [e for e in trace["traceEvents"] if e.get("ph") == "X"]
    clone = dict(spans[0])
    clone["ts"] = spans[0]["ts"] + US_PER_TICK // 2   # mid-span collision
    trace["traceEvents"].append(clone)
    errs = validate_trace(trace)
    assert any("overlap" in e for e in errs)


def test_validator_catches_unpaired_flow():
    trace, _ = _valid_trace()
    trace["traceEvents"].append({"ph": "s", "pid": 0, "tid": 0,
                                 "cat": "preemption", "name": "evict",
                                 "id": 999_999, "ts": 0})
    errs = validate_trace(trace)
    assert any("never finished" in e for e in errs)


def test_validator_catches_unclosed_start():
    trace, res = _valid_trace()
    # drop every span of some job that appears in the log
    spans = [e for e in trace["traceEvents"] if e.get("ph") == "X"]
    victim = spans[-1]["args"]["jid"]
    # keep the job "open at horizon" in the log by removing its spans
    trace["traceEvents"] = [
        e for e in trace["traceEvents"]
        if not (e.get("ph") == "X" and e["args"].get("jid") == victim)]
    from repro.obs import EventType
    evs = [e for e in res.events
           if not (e.jid == victim
                   and e.etype in (EventType.EVICT, EventType.FINISH))]
    errs = validate_trace(trace, events=evs)
    assert any(f"job {victim}" in e for e in errs)


def test_validator_catches_negative_duration():
    trace, _ = _valid_trace()
    trace["traceEvents"].append({"ph": "X", "pid": 0, "tid": 0,
                                 "cat": "job", "name": "bogus",
                                 "ts": 0, "dur": -5, "args": {}})
    errs = validate_trace(trace)
    assert any("negative duration" in e for e in errs)


def test_validator_rejects_unserializable():
    errs = validate_trace({"traceEvents": [{"ph": "X", "ts": object()}]})
    assert errs and "JSON" in errs[0]


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def test_trace_cli_writes_and_validates(tmp_path, capsys):
    out = tmp_path / "trace.json"
    rc = trace_main(["--backend", "python", "--horizon", "80",
                     "--jobs", "20", "--out", str(out), "--validate"])
    assert rc == 0
    captured = capsys.readouterr().out
    assert "trace valid" in captured
    trace = json.loads(out.read_text())
    assert trace["traceEvents"]
    assert validate_trace(trace) == []
