"""Property tests for the chunked-attention primitive and cache writes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.attention import (
    cache_pos_write,
    cache_write,
    chunked_attention,
    decode_attention,
    ring_slots,
    visibility_mask,
)


def naive_attention(q, k, v, qp, kp, causal=True, window=0, n_meta=0):
    B, Sq, H, Dk = q.shape
    KVH = k.shape[2]
    G = H // KVH
    qr = q.reshape(B, Sq, KVH, G, Dk).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bqkgs", qr, k.astype(jnp.float32)) / np.sqrt(Dk)
    vis = visibility_mask(qp, kp, causal=causal, window=window, n_meta=n_meta)
    s = jnp.where(vis[:, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, -1)
    o = jnp.einsum("bqkgs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, -1)


@settings(max_examples=20, deadline=None)
@given(
    seq=st.integers(3, 80),
    heads=st.sampled_from([(4, 4), (4, 2), (8, 1)]),
    d=st.sampled_from([8, 16]),
    q_chunk=st.sampled_from([8, 16, 64]),
    kv_chunk=st.sampled_from([8, 32]),
    causal=st.booleans(),
    window=st.sampled_from([0, 5, 17]),
)
def test_chunked_attention_matches_naive(seq, heads, d, q_chunk, kv_chunk,
                                         causal, window):
    H, KVH = heads
    n_meta = 2 if window else 0
    key = jax.random.PRNGKey(seq * 131 + H)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (2, seq, H, d), jnp.float32)
    k = jax.random.normal(ks[1], (2, seq, KVH, d), jnp.float32)
    v = jax.random.normal(ks[2], (2, seq, KVH, d), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(seq), (2, seq))
    out = chunked_attention(q, k, v, pos, pos, causal=causal, window=window,
                            n_meta=n_meta, q_chunk=q_chunk, kv_chunk=kv_chunk)
    ref = naive_attention(q, k, v, pos, pos, causal, window, n_meta)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_decode_attention_respects_invalid_slots():
    key = jax.random.PRNGKey(0)
    B, S, H, D = 2, 16, 4, 8
    k = jax.random.normal(key, (B, S, H, D))
    v = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, D))
    q = jax.random.normal(jax.random.fold_in(key, 2), (B, 1, H, D))
    kv_pos = jnp.where(jnp.arange(S) < 5, jnp.arange(S), -1)[None].repeat(B, 0)
    qpos = jnp.full((B, 1), 4)
    out = decode_attention(q, k, v, qpos, kv_pos)
    # equal to attending only the 5 valid slots
    out5 = decode_attention(q, k[:, :5], v[:, :5], qpos, kv_pos[:, :5])
    np.testing.assert_allclose(np.asarray(out), np.asarray(out5), atol=1e-6)


@settings(max_examples=30, deadline=None)
@given(
    size=st.integers(2, 24),
    n_pinned=st.integers(0, 6),
    cursor=st.integers(0, 60),
    n_new=st.integers(1, 40),
)
def test_ring_slots_properties(size, n_pinned, cursor, n_new):
    n_pinned = min(n_pinned, size - 1)
    slots = np.asarray(ring_slots(jnp.int32(cursor), n_new, size, n_pinned))
    idx = cursor + np.arange(n_new)
    live = slots[slots < size]
    # pinned entries land in their own slot; ring entries in [n_pinned, size)
    for i, s in enumerate(slots):
        if s < size:
            if idx[i] < n_pinned:
                assert s == idx[i]
            else:
                assert n_pinned <= s < size
    # no duplicate live slots (last-writer-wins was resolved by dropping)
    assert len(set(live.tolist())) == len(live)


def test_cache_write_ring_semantics_with_pinned_meta():
    """Meta slots survive arbitrary wraparound; ring holds the newest."""
    B, S, KVH, D, n_meta = 1, 6, 1, 2, 2  # ring of 4
    k = jnp.zeros((B, S, KVH, D))
    v = jnp.zeros((B, S, KVH, D))
    def val(i):
        return jnp.full((B, 1, KVH, D), float(i))
    # write positions 0..9 one at a time
    for i in range(10):
        k, v = cache_write(k, v, val(i), val(i), jnp.int32(i), n_pinned=n_meta)
    got = np.asarray(k[0, :, 0, 0])
    assert got[0] == 0 and got[1] == 1          # pinned meta slots
    assert sorted(got[2:].tolist()) == [6, 7, 8, 9]  # newest 4 in the ring


def test_visibility_mask_meta_tokens():
    qp = jnp.asarray([[10]])
    kp = jnp.asarray([[0, 1, 2, 7, 8, 9, 10]])
    vis = visibility_mask(qp, kp, causal=True, window=3, n_meta=2)
    # meta positions 0,1 visible; 2 out of window; 8,9,10 in window; 7 not
    assert vis[0, 0].tolist() == [True, True, False, False, True, True, True]
