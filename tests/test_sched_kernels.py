"""Property tests for the fused victim-select/placement kernel family
(`kernels.sched_select`) and its `SchedulerConfig.kernel_backend` dispatch:
the pallas path must be bit-identical to the lax path — planned victims,
placement tiers, spill counts, events — for every registered policy, under
random tiered C/R costs, at J ∈ {64, 10k}, and through every engine entry
point (`simulate`, `simulate_matrix`, `simulate_batch`, `simulate_stream`).
"""
import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import engine, omfs_jax
from repro.core.crcost import UNBOUNDED, CRCostModel, TieredCRCostModel
from repro.core.types import SchedulerConfig
from repro.core.workload import WorkloadSpec, arrival_stream, make_jobs, make_users
from repro.kernels.sched_select.ops import plan_evictions_fused
from repro.kernels.sched_select.ref import plan_evictions_ref

POLICY_NAMES = sorted(engine.POLICIES)


def _pallas(cfg: SchedulerConfig) -> SchedulerConfig:
    return dataclasses.replace(cfg, kernel_backend="pallas_interpret")


def _workload(seed, n_users=3, cpu_total=32, n_jobs=35, horizon=100):
    spec = WorkloadSpec(n_users=n_users, horizon=horizon, cpu_total=cpu_total,
                        seed=seed, arrival_rate=0.15, mean_work=25,
                        class_mix=(0.15, 0.35, 0.5))
    users = make_users(spec)
    jobs = make_jobs(spec, users)[:n_jobs]
    return users, jobs


def _sized_workload(n_jobs, cpu_total, seed=1, n_users=16):
    """Workload that actually reaches ``n_jobs`` rows (bench generator)."""
    gen_horizon = max(200, int(1.5 * n_jobs / (n_users * 0.5)))
    spec = WorkloadSpec(n_users=n_users, horizon=gen_horizon,
                        cpu_total=cpu_total, seed=seed, arrival_rate=0.5,
                        mean_work=60)
    users = make_users(spec)
    jobs = make_jobs(spec, users)[:n_jobs]
    assert len(jobs) == n_jobs
    return users, jobs


def _tiered_cfg(quantum=3, cap0=64, save_bw=256, spill_bw=32):
    tiers = TieredCRCostModel(
        tiers=(CRCostModel(save_mib_per_tick=save_bw,
                           restore_mib_per_tick=save_bw),
               CRCostModel(save_mib_per_tick=spill_bw,
                           restore_mib_per_tick=spill_bw,
                           save_base=1, restore_base=1)),
        capacity_mib=(cap0, UNBOUNDED))
    return SchedulerConfig(cpu_total=32, quantum=quantum, cr_overhead=1,
                           cr_tiers=tiers)


def _assert_results_equal(a, b):
    """Full EngineResult bit-identity: table (spill counts included),
    busy series, and — when recorded — the typed event log."""
    assert omfs_jax.tables_equal(a.table, b.table)
    assert np.array_equal(a.busy_series(), b.busy_series())
    assert np.array_equal(np.asarray(a.table.n_spill),
                          np.asarray(b.table.n_spill))
    if a.event_counts is not None or b.event_counts is not None:
        assert np.array_equal(np.asarray(a.event_counts),
                              np.asarray(b.event_counts))
        assert a.events == b.events
        assert a.events_dropped_total() == b.events_dropped_total()


# ---------------------------------------------------------------------------
# Kernel unit level: fused pallas_call vs the lexsort/scan reference
# ---------------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_kernel_matches_reference_fuzz(seed):
    """Random bare columns at random tier counts T ∈ {2, 3, 4}, every
    static variant (faithful/cheap × untiered/unbounded/bounded): planned
    victims, feasibility bit, and T-tier lattice placement must match the
    lexsort reference exactly."""
    rng = np.random.default_rng(seed)
    j = int(rng.integers(1, 300))
    n_tiers = int(rng.integers(2, 5))
    save_lat = rng.integers(0, 60, (j, n_tiers)).astype(np.int32)
    cols = dict(
        prio=rng.integers(0, 5, j).astype(np.int32),
        run_start=rng.integers(-1, 40, j).astype(np.int32),
        jid=rng.permutation(j).astype(np.int32),
        key_cost=save_lat[:, 0],
        evictable=rng.random(j) < 0.5,
        cpus=rng.integers(1, 8, j).astype(np.int32),
        state_mib=rng.integers(0, 64, j).astype(np.int32),
        is_ckpt=rng.random(j) < 0.7,
        save_lat=save_lat,
    )
    occ = rng.integers(0, 128, n_tiers).astype(np.int32)
    # random finite caps with sporadic unbounded (-1) tiers; the last
    # tier is always the unbounded spill target (model invariant)
    cap = rng.integers(0, 256, n_tiers).astype(np.int32)
    cap[rng.random(n_tiers) < 0.3] = -1
    cap[-1] = -1
    scalars = dict(idle=int(rng.integers(0, 20)),
                   cpus_needed=int(rng.integers(0, 48)),
                   occ=occ, cap=cap)
    for cheap in (False, True):
        for tiered, bounded in ((False, False), (True, False), (True, True)):
            sc = dict(scalars)
            if not bounded:
                sc["cap"] = np.full(n_tiers, -1, np.int32)
            got = plan_evictions_fused(
                *cols.values(), *sc.values(),
                cheap=cheap, tiered=tiered, bounded=bounded, interpret=True)
            want = plan_evictions_ref(
                *cols.values(), *sc.values(),
                cheap=cheap, tiered=tiered, bounded=bounded)
            for name, g, w in zip(("planned", "enough", "tier"),
                                  got, want):
                assert np.array_equal(np.asarray(g), np.asarray(w)), (
                    f"{name} cheap={cheap} tiered={tiered} bounded={bounded}")


# ---------------------------------------------------------------------------
# Engine level: every registered policy, lax vs pallas_interpret
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", POLICY_NAMES)
@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 10_000), quantum=st.integers(0, 8))
def test_policy_lax_pallas_identical(policy, seed, quantum):
    users, jobs = _workload(seed)
    if not jobs:
        return
    cfg = SchedulerConfig(cpu_total=32, quantum=quantum, cr_overhead=2)
    lax = engine.simulate(users, jobs, cfg, 100, policy=policy,
                          backend="jax", record_events=True)
    pal = engine.simulate(users, jobs, _pallas(cfg), 100, policy=policy,
                          backend="jax", record_events=True)
    _assert_results_equal(lax, pal)


@pytest.mark.parametrize("policy", ["omfs", "omfs_cheap_victim", "backfill_cr"])
@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 10_000), quantum=st.integers(1, 6),
       cap0=st.integers(0, 256), save_bw=st.integers(32, 2048),
       spill_bw=st.integers(16, 512))
def test_tiered_costs_lax_pallas_identical(policy, seed, quantum, cap0,
                                           save_bw, spill_bw):
    """Random tiered C/R cost models: placement tiers (ckpt_tier), spill
    counts, and charged overheads must match across backends — the greedy
    in-kernel placement against the lax.scan."""
    users, jobs = _workload(seed)
    if not jobs:
        return
    cfg = _tiered_cfg(quantum, cap0, save_bw, spill_bw)
    lax = engine.simulate(users, jobs, cfg, 100, policy=policy, backend="jax")
    pal = engine.simulate(users, jobs, _pallas(cfg), 100, policy=policy,
                          backend="jax")
    _assert_results_equal(lax, pal)
    assert np.array_equal(np.asarray(lax.table.ckpt_tier),
                          np.asarray(pal.table.ckpt_tier))


def test_acceptance_j64_all_policies_tiered():
    """J=64: all 7 policies, tiered costs live, events recorded — full
    EngineResult bit-identity, with evictions + spills actually exercised
    (uneven arrivals so early over-entitlement admits become victims)."""
    spec = WorkloadSpec(n_users=3, horizon=400, cpu_total=32, seed=5,
                        arrival_rate=0.1, mean_work=40,
                        class_mix=(0.1, 0.2, 0.7))
    users = make_users(spec)
    jobs = make_jobs(spec, users)[:64]
    assert len(jobs) == 64
    cfg = _tiered_cfg(quantum=2, cap0=8)
    preempts = spills = 0
    for policy in POLICY_NAMES:
        lax = engine.simulate(users, jobs, cfg, 120, policy=policy,
                              backend="jax", record_events=True)
        pal = engine.simulate(users, jobs, _pallas(cfg), 120, policy=policy,
                              backend="jax", record_events=True)
        _assert_results_equal(lax, pal)
        preempts += int(np.asarray(pal.table.n_preempt).sum())
        spills += int(np.asarray(pal.table.n_spill).sum())
    assert preempts > 0, "fixture never hit the eviction machinery"
    assert spills > 0, "fixture never exercised tiered spill accounting"


def test_acceptance_j10k_all_policies_matrix():
    """J=10k: all 7 policies through ONE compiled `simulate_matrix` per
    backend (per-policy results are bit-identical to `simulate` by the
    matrix contract), pass_depth-bounded like the scale benchmarks."""
    users, jobs = _sized_workload(10_000, cpu_total=64)
    cfg = SchedulerConfig(cpu_total=64, quantum=2, cr_overhead=1)
    lax = engine.simulate_matrix(users, jobs, cfg, 20, pass_depth=16)
    pal = engine.simulate_matrix(users, jobs, _pallas(cfg), 20, pass_depth=16)
    preempts = 0
    for a, b in zip(lax, pal):
        assert omfs_jax.tables_equal(a.table, b.table)
        assert np.array_equal(a.busy_series(), b.busy_series())
        preempts += int(np.asarray(b.table.n_preempt).sum())
    assert preempts > 0, "fixture never hit the eviction machinery"


# ---------------------------------------------------------------------------
# Batched / streaming engines
# ---------------------------------------------------------------------------


def test_simulate_batch_cells_pallas():
    """A policy × quantum-knob grid of batch cells under the pallas backend
    equals the same batch under lax, cell by cell (knob overrides force the
    traced-quantum path, where the per-tick hoist must stay disabled)."""
    users, jobs = _workload(seed=5)
    cfg = _tiered_cfg(quantum=3)
    cells = [engine.BatchCell(users=users, jobs=jobs, policy=p, quantum=q)
             for p in ("omfs", "omfs_cheap_victim", "backfill_cr")
             for q in (1, 4)]
    lax = engine.simulate_batch(cells, cfg, 80)
    pal = engine.simulate_batch(cells, _pallas(cfg), 80)
    for a, b in zip(lax, pal):
        assert omfs_jax.tables_equal(a.table, b.table)
        assert np.array_equal(a.busy_series(), b.busy_series())


def test_simulate_stream_pallas():
    users, jobs = _workload(seed=9, n_jobs=60, horizon=120)
    cfg = _tiered_cfg(quantum=2)
    kw = dict(capacity=24, segment_len=16, policy="omfs")
    lax = engine.simulate_stream(users, arrival_stream(jobs), cfg, 120, **kw)
    pal = engine.simulate_stream(users, arrival_stream(jobs), _pallas(cfg),
                                 120, **kw)
    assert lax.signature() == pal.signature()
    assert np.array_equal(lax.busy_series(), pal.busy_series())
    assert lax.stream_stats == pal.stream_stats


def test_reference_pass_pallas():
    """The un-optimized reference pass dispatches too (`_try_admit`)."""
    users, jobs = _workload(seed=3)
    cfg = SchedulerConfig(cpu_total=32, quantum=2, cr_overhead=1)
    t_lax, b_lax = omfs_jax.simulate_jax(users, jobs, cfg, 80,
                                         incremental=False)
    t_pal, b_pal = omfs_jax.simulate_jax(users, jobs, _pallas(cfg), 80,
                                         incremental=False)
    assert omfs_jax.tables_equal(t_lax, t_pal)
    assert np.array_equal(np.asarray(b_lax), np.asarray(b_pal))


# ---------------------------------------------------------------------------
# Dispatch contract
# ---------------------------------------------------------------------------


def test_pallas_auto_interprets_off_tpu():
    """``kernel_backend="pallas"`` falls back to interpret mode away from
    TPUs instead of failing to lower — same results."""
    users, jobs = _workload(seed=1)
    cfg = SchedulerConfig(cpu_total=32, quantum=2)
    lax = engine.simulate(users, jobs, cfg, 60, policy="omfs", backend="jax")
    pal = engine.simulate(users, jobs,
                          dataclasses.replace(cfg, kernel_backend="pallas"),
                          60, policy="omfs", backend="jax")
    _assert_results_equal(lax, pal)


def test_unknown_backend_raises():
    users, jobs = _workload(seed=1)
    cfg = SchedulerConfig(cpu_total=32, kernel_backend="cuda")
    with pytest.raises(ValueError, match="kernel_backend"):
        engine.simulate(users, jobs, cfg, 10, policy="omfs", backend="jax")
