"""The bench-regression gate (`benchmarks.compare_bench`): a >tolerance
drop fails, within-tolerance noise passes, throughput rows compare
anchor-normalized (machine-independent), missing rows fail."""
from benchmarks.compare_bench import ANCHOR, compare, make_baseline, render


def _baseline():
    fresh = {
        ANCHOR: 1000.0,
        "sched_scale/jax_inc_64jobs_ticks_per_s": 4000.0,
        "thrashing/disk_goodput": 0.64,
        "tier_placement/capacity_all_goodput": 0.68,
        "thrashing/goodput_drop_disk_vs_free": 0.05,   # excluded: a delta
    }
    return make_baseline(fresh), fresh


def test_make_baseline_selects_gated_rows():
    baseline, fresh = _baseline()
    names = {e["name"] for e in baseline}
    assert ANCHOR in names
    assert "thrashing/goodput_drop_disk_vs_free" not in names
    by_name = {e["name"]: e for e in baseline}
    assert by_name["sched_scale/jax_inc_64jobs_ticks_per_s"][
        "normalize_by"] == ANCHOR
    assert by_name["thrashing/disk_goodput"]["normalize_by"] is None
    assert by_name[ANCHOR]["rtol"] is None        # the anchor is not gated


def test_within_tolerance_passes():
    baseline, fresh = _baseline()
    fresh = dict(fresh)
    fresh["thrashing/disk_goodput"] *= 0.85       # -15% < 20% tolerance
    _, failures = compare(baseline, fresh)
    assert failures == []


def test_synthetic_regression_fails():
    baseline, fresh = _baseline()
    fresh = dict(fresh)
    fresh["tier_placement/capacity_all_goodput"] *= 0.7    # -30%
    table, failures = compare(baseline, fresh)
    assert len(failures) == 1
    assert "tier_placement/capacity_all_goodput" in failures[0]
    assert "-30.0%" in failures[0]
    assert "REGRESSED" in render(table, failures)


def test_throughput_normalized_by_anchor():
    """A uniformly slower machine (anchor and jax rows both halved) is NOT
    a regression; the jax row dropping much faster than the anchor is."""
    baseline, fresh = _baseline()
    slower = {k: (v * 0.5 if "ticks_per_s" in k else v)
              for k, v in fresh.items()}
    _, failures = compare(baseline, slower)
    assert failures == []
    skewed = dict(fresh)
    skewed["sched_scale/jax_inc_64jobs_ticks_per_s"] *= 0.5   # anchor intact
    _, failures = compare(baseline, skewed)
    assert len(failures) == 1 and "jax_inc" in failures[0]


def test_missing_row_fails():
    baseline, fresh = _baseline()
    fresh = dict(fresh)
    del fresh["thrashing/disk_goodput"]
    table, failures = compare(baseline, fresh)
    assert any("missing" in f for f in failures)
    assert "MISSING" in render(table, failures)


def test_missing_anchor_fails_rather_than_disabling_the_gate():
    """Losing the anchor row (a renamed smoke case) must FAIL, not
    silently skip every anchor-normalized throughput comparison."""
    baseline, fresh = _baseline()
    fresh = dict(fresh)
    del fresh[ANCHOR]
    fresh["sched_scale/jax_inc_64jobs_ticks_per_s"] *= 0.01  # would regress
    table, failures = compare(baseline, fresh)
    assert any(ANCHOR in f and "missing" in f for f in failures)
    assert any("anchor row unavailable" in f for f in failures)
    assert "NO-ANCHOR" in render(table, failures)
