"""Property tests: both backends emit bit-identical lifecycle event logs
for every registered policy — through `simulate`, `simulate_batch` cells,
and the `simulate_stream` conveyor — and the bounded ring never drops
silently."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import engine
from repro.core.crcost import UNBOUNDED, CRCostModel, TieredCRCostModel
from repro.core.types import JobState, SchedulerConfig
from repro.core.workload import (
    WorkloadSpec,
    arrival_stream,
    make_jobs,
    make_users,
)
from repro.obs import (
    MAX_EVENTS_PER_JOB_PER_TICK,
    EventType,
    canonical_sort,
    lossless_ring_size,
)

POLICY_NAMES = sorted(engine.POLICIES)


def _workload(seed, n_users, horizon=100, cpu_total=32):
    spec = WorkloadSpec(n_users=n_users, horizon=horizon, cpu_total=cpu_total,
                        seed=seed, arrival_rate=0.12, mean_work=30,
                        class_mix=(0.15, 0.35, 0.5))
    users = make_users(spec)
    jobs = make_jobs(spec, users)[:35]
    return users, jobs


def _tiered_cfg(quantum=4):
    tiers = TieredCRCostModel(
        tiers=(CRCostModel(save_mib_per_tick=4096,
                           restore_mib_per_tick=8192),
               CRCostModel(save_mib_per_tick=512, restore_mib_per_tick=1024,
                           save_base=1)),
        capacity_mib=(2_000, UNBOUNDED))
    return SchedulerConfig(cpu_total=32, quantum=quantum, cr_overhead=1,
                           cr_tiers=tiers)


# ---------------------------------------------------------------------------
# cross-backend bit-equality
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", POLICY_NAMES)
@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 10_000), quantum=st.integers(0, 12),
       n_users=st.integers(2, 4))
def test_event_log_equivalence_tiered(policy, seed, quantum, n_users):
    """Same events, same order, both backends, under tiered C/R costs —
    the ISSUE's headline acceptance criterion."""
    users, jobs = _workload(seed, n_users)
    if not jobs:
        return
    cfg = _tiered_cfg(quantum)
    py = engine.simulate(users, jobs, cfg, 100, policy=policy,
                         backend="python", record_events=True)
    jx = engine.simulate(users, jobs, cfg, 100, policy=policy,
                         backend="jax", record_events=True)
    assert py.signature() == jx.signature()
    assert canonical_sort(py.events) == canonical_sort(jx.events)
    assert (py.event_counts == jx.event_counts).all()
    assert py.events_dropped_total() == 0
    assert jx.events_dropped_total() == 0


def test_event_log_canonical_order_is_native_order():
    """Both backends already produce the canonical (tick, etype, jid)
    order — the sort the comparison applies is a no-op."""
    users, jobs = _workload(3, 3)
    cfg = _tiered_cfg()
    for backend in ("python", "jax"):
        res = engine.simulate(users, jobs, cfg, 100, policy="omfs",
                              backend=backend, record_events=True)
        assert res.events == canonical_sort(res.events)


def test_events_reconcile_with_table_bookkeeping():
    """The event log and the engine's own per-job counters tell the same
    story: EVICT == n_preemptions, SAVE == n_checkpoints, SPILL ==
    n_spills, FINISH == done jobs, and per-job pre-start DEFER count ==
    first_start - submit_time."""
    users, jobs = _workload(11, 3)
    cfg = _tiered_cfg()
    res = engine.simulate(users, jobs, cfg, 100, policy="omfs",
                          backend="python", record_events=True)
    jobs_by_id = res.sim.state.jobs
    per_type = np.asarray(res.event_counts).sum(axis=0)
    assert per_type[EventType.EVICT] == sum(
        j.n_preemptions for j in jobs_by_id.values())
    assert per_type[EventType.SAVE] == sum(
        j.n_checkpoints for j in jobs_by_id.values())
    assert per_type[EventType.SPILL] == sum(
        j.n_spills for j in jobs_by_id.values())
    assert per_type[EventType.FINISH] == sum(
        1 for j in jobs_by_id.values() if j.state == JobState.DONE)
    waits = {}
    started = set()
    for ev in res.events:
        if ev.etype == EventType.DEFER and ev.jid not in started:
            waits[ev.jid] = waits.get(ev.jid, 0) + 1
        elif ev.etype == EventType.START:
            started.add(ev.jid)
    for jid in started:
        j = jobs_by_id[jid]
        assert waits.get(jid, 0) == j.first_start - j.submit_time


def test_event_summary_matches_compute_metrics():
    from repro.core.metrics import compute_metrics, event_summary

    users, jobs = _workload(5, 3)
    cfg = _tiered_cfg()
    res = engine.simulate(users, jobs, cfg, 100, policy="omfs",
                          backend="python", record_events=True)
    m = compute_metrics(res.sim)
    ev = event_summary(res.events)
    assert ev["preemptions"] == m.preemptions
    assert ev["checkpoints"] == m.checkpoints
    assert ev["spilled_checkpoints"] == m.spilled_checkpoints
    assert ev["mean_wait"] == pytest.approx(m.mean_wait)
    assert ev["p95_wait"] == pytest.approx(m.p95_wait)
    assert ev["jobs_done"] == m.throughput * 100


# ---------------------------------------------------------------------------
# batch + stream paths
# ---------------------------------------------------------------------------


@settings(max_examples=3, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_simulate_batch_cells_carry_events(seed):
    users, jobs = _workload(seed, 3)
    if not jobs:
        return
    cfg = _tiered_cfg()
    cells = [engine.BatchCell(users=users, jobs=jobs, policy=p)
             for p in ("omfs", "fcfs", "backfill_cr")]
    batch = engine.simulate_batch(cells, cfg, 100, record_events=True)
    for cell, got in zip(cells, batch):
        seq = engine.simulate(users, jobs, cfg, 100, policy=cell.policy,
                              backend="jax", record_events=True)
        assert canonical_sort(got.events) == canonical_sort(seq.events)
        assert (got.event_counts == seq.event_counts).all()
        assert got.events_dropped_total() == 0


@settings(max_examples=3, deadline=None)
@given(seed=st.integers(0, 10_000), segment_len=st.sampled_from([7, 25, 64]))
def test_simulate_stream_conveyor_matches_monolithic_events(
        seed, segment_len):
    """Ample capacity: the streaming conveyor's decoded event log (true
    jids through recycled slots, per-segment t0 offsets) is bit-identical
    to the monolithic run's."""
    users, jobs = _workload(seed, 3)
    if not jobs:
        return
    cfg = _tiered_cfg()
    mono = engine.simulate(users, jobs, cfg, 100, policy="omfs",
                           backend="jax", record_events=True)
    st_res = engine.simulate_stream(
        users, arrival_stream(jobs), cfg, 100, "omfs",
        capacity=max(8, len(jobs)), segment_len=segment_len,
        record_events=True)
    assert st_res.stream_stats["deferrals"] == 0
    assert canonical_sort(st_res.events) == canonical_sort(mono.events)
    assert st_res.events_dropped_total() == 0


# ---------------------------------------------------------------------------
# ring sizing + overflow accounting
# ---------------------------------------------------------------------------


def test_lossless_ring_never_drops_and_counts_reconcile():
    users, jobs = _workload(9, 4)
    cfg = _tiered_cfg(quantum=1)     # quantum=1 maximizes churn
    res = engine.simulate(users, jobs, cfg, 100, policy="omfs",
                          backend="jax", record_events=True)
    assert res.events_dropped_total() == 0
    # counts ⟺ decoded events: nothing lost, nothing invented
    assert int(np.asarray(res.event_counts).sum()) == len(res.events)
    per_tick = np.asarray(res.event_counts).sum(axis=1)
    n_jobs = len(jobs)
    assert (per_tick <= MAX_EVENTS_PER_JOB_PER_TICK * n_jobs).all()


def test_tiny_ring_records_dropped_never_silent():
    """Forcing overflow: the decoded log shrinks but the DROPPED series
    accounts for every lost event and the counts matrix stays exact."""
    users, jobs = _workload(9, 4)
    cfg = _tiered_cfg()
    full = engine.simulate(users, jobs, cfg, 100, policy="omfs",
                           backend="jax", record_events=True)
    tiny = engine.simulate(users, jobs, cfg, 100, policy="omfs",
                           backend="jax", record_events=True, event_ring=4)
    assert tiny.events_dropped_total() > 0
    # exact accounting: total events = decoded + dropped
    assert (int(np.asarray(tiny.event_counts).sum())
            == len(tiny.events) + tiny.events_dropped_total())
    # the counts matrix itself is never lossy
    assert (tiny.event_counts == full.event_counts).all()
    # the surviving ring prefix is a prefix of the full log per tick
    assert set(tiny.events) <= set(full.events)


def test_lossless_ring_size_bound():
    assert lossless_ring_size(0) == 8
    assert lossless_ring_size(100) == 100 * MAX_EVENTS_PER_JOB_PER_TICK


def test_event_ring_validates_uninstrumented_unchanged():
    """record_events=False goes through the plain runner and yields no
    event fields — and the busy series matches the instrumented run."""
    users, jobs = _workload(2, 3)
    cfg = _tiered_cfg()
    plain = engine.simulate(users, jobs, cfg, 100, policy="omfs",
                            backend="jax")
    inst = engine.simulate(users, jobs, cfg, 100, policy="omfs",
                           backend="jax", record_events=True)
    assert plain.events is None and plain.event_counts is None
    assert plain.signature() == inst.signature()
    assert (plain.busy_series() == inst.busy_series()).all()


def test_executor_bus_matches_schema():
    """The live executor's EventBus uses the same diff schema: a pure-sim
    descriptor run through ClusterExecutor-style snapshot/record equals
    the engine's own event log."""
    from repro.core.types import ClusterState
    from repro.obs.bus import EventBus

    users, jobs = _workload(4, 3)
    cfg = _tiered_cfg()
    ref = engine.simulate(users, jobs, cfg, 80, policy="omfs",
                          backend="python", record_events=True)
    # replay: same tick kernel, bus-driven capture (what executor.tick does)
    state = ClusterState(config=cfg, users={u.name: u for u in users})
    for j in sorted(jobs, key=lambda x: x.id):
        j = j.clone()
        j.state = JobState.UNSUBMITTED
        state.jobs[j.id] = j
    bus = EventBus()
    pol = engine.POLICIES["omfs"].python_pass
    for t in range(80):
        state.time = t
        bus.snapshot(state.jobs)
        engine.tick_python(state, pol)
        bus.record_tick(state.jobs, t)
    assert bus.events == ref.events
    assert (bus.counts_matrix(80) == ref.event_counts).all()
    assert bus.dropped_total == 0
