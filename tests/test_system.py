"""System-level behaviour: the paper's claims, quantified end-to-end.

These are the headline assertions of the reproduction: OMFS strictly
improves utilization over the capping-style baselines on pooled demand,
keeps entitlement fairness (reclaim is immediate), and bounds thrashing
via the quantum.
"""
import numpy as np
import pytest

from repro.core.baselines import ALL_BASELINES
from repro.core.metrics import compute_metrics
from repro.core.simulator import simulate
from repro.core.types import Job, JobClass, JobState, SchedulerConfig, User
from repro.core.workload import WorkloadSpec, make_jobs, make_users


def _run(policy_name, users, jobs, cfg, horizon):
    if policy_name == "omfs":
        res = simulate(users, [j.clone() for j in jobs], cfg, horizon)
    else:
        res = simulate(users, [j.clone() for j in jobs], cfg, horizon,
                       policy=ALL_BASELINES[policy_name])
    return compute_metrics(res)


@pytest.fixture(scope="module")
def workload():
    spec = WorkloadSpec(n_users=4, horizon=800, cpu_total=64, seed=3,
                        arrival_rate=0.06, burstiness=1.0)
    users = make_users(spec)
    jobs = make_jobs(spec, users)
    return users, jobs, spec


def test_omfs_beats_capping_and_static_utilization(workload):
    """Paper SII: 'improves the utilization over a capping-based system'."""
    users, jobs, spec = workload
    cfg = SchedulerConfig(cpu_total=64, quantum=20, cr_overhead=2)
    omfs = _run("omfs", users, jobs, cfg, spec.horizon)
    capping = _run("capping", users, jobs, cfg, spec.horizon)
    static = _run("static_partition", users, jobs, cfg, spec.horizon)
    assert omfs.utilization > capping.utilization + 0.02
    assert omfs.utilization > static.utilization + 0.02


def test_omfs_fairness_not_sacrificed(workload):
    """Higher utilization must not cost entitlement fairness (Jain over
    entitlement-normalized usage stays comparable to capping)."""
    users, jobs, spec = workload
    cfg = SchedulerConfig(cpu_total=64, quantum=20)
    omfs = _run("omfs", users, jobs, cfg, spec.horizon)
    capping = _run("capping", users, jobs, cfg, spec.horizon)
    assert omfs.jain_fairness > capping.jain_fairness - 0.1


def test_quantum_bounds_thrashing(workload):
    """Larger quantum -> fewer preemptions (SII anti-thrashing)."""
    users, jobs, spec = workload
    preempts = []
    for q in (0, 10, 50):
        cfg = SchedulerConfig(cpu_total=64, quantum=q, cr_overhead=1)
        m = _run("omfs", users, jobs, cfg, spec.horizon)
        preempts.append(m.preemptions)
    assert preempts[0] >= preempts[1] >= preempts[2]
    assert preempts[0] > preempts[2]


def test_beyond_paper_victim_filter_reduces_collateral(workload):
    """Our (default-off) over-entitlement victim filter must not evict
    under-entitlement users' jobs — fewer checkpoint events for the same
    utilization ballpark."""
    users, jobs, spec = workload
    base = _run("omfs", users, jobs,
                SchedulerConfig(cpu_total=64, quantum=20), spec.horizon)
    filt_cfg = SchedulerConfig(cpu_total=64, quantum=20,
                               victim_filter_over_entitlement=True)
    filt = _run("omfs", users, jobs, filt_cfg, spec.horizon)
    assert filt.preemptions <= base.preemptions
    assert filt.utilization > base.utilization - 0.05


def test_checkpointable_jobs_survive_preemption_preemptible_die(workload):
    users, jobs, spec = workload
    cfg = SchedulerConfig(cpu_total=64, quantum=10)
    res = simulate(users, [j.clone() for j in jobs], cfg, spec.horizon)
    killed = [j for j in res.state.jobs.values() if j.state == JobState.KILLED]
    assert all(j.job_class == JobClass.PREEMPTIBLE for j in killed)
    ck = [j for j in res.state.jobs.values() if j.n_checkpoints > 0]
    assert all(j.job_class == JobClass.CHECKPOINTABLE for j in ck)
