"""Tiered eviction placement (`core.crcost.TieredCRCostModel`): greedy
cheapest-feasible tier choice with durable spill, size-aware victim
selection (`omfs_cheap_victim`), the `update_state_mib` no-retrace hook,
and the checkpoint-service calibration bridge."""
import jax
import numpy as np
import pytest

from repro.core import engine, omfs_jax
from repro.core.crcost import UNBOUNDED, CRCostModel, TieredCRCostModel
from repro.core.types import Job, JobClass, SchedulerConfig, User
from repro.core.workload import thrashing_scenario

FAST = CRCostModel(save_mib_per_tick=16384, restore_mib_per_tick=32768)
DISK = CRCostModel(save_mib_per_tick=2048, restore_mib_per_tick=4096)


def _tiered(cap_mib: int) -> SchedulerConfig:
    return SchedulerConfig(
        cpu_total=64, quantum=5,
        cr_tiers=TieredCRCostModel(tiers=(FAST, DISK),
                                   capacity_mib=(cap_mib, UNBOUNDED)))


def _run(cfg, policy="omfs", backend="python", horizon=400, gibs=None):
    users, jobs = thrashing_scenario(64, quantum=5, state_gibs=gibs)
    return engine.simulate(users, jobs, cfg, horizon,
                           policy=policy, backend=backend)


# ---------------------------------------------------------------------------
# model semantics
# ---------------------------------------------------------------------------


def test_choose_tier_greedy_cheapest_feasible():
    m = TieredCRCostModel(tiers=(FAST, DISK), capacity_mib=(100, UNBOUNDED))
    # fits and fast is cheaper -> tier 0
    assert m.choose_tier(100, [0, 0]) == 0
    # fast tier full -> spill
    assert m.choose_tier(100, [1, 0]) == 1
    assert m.choose_tier(101, [0, 0]) == 1
    # an expensive "fast" tier loses to a cheaper durable one even with room
    costly = TieredCRCostModel(tiers=(DISK, FAST),
                               capacity_mib=(1 << 20, UNBOUNDED))
    assert costly.choose_tier(100 << 10, [0, 0]) == 1
    # equal cost ties break toward the faster (lower) tier
    tie = TieredCRCostModel(tiers=(FAST, FAST), capacity_mib=(10_000, UNBOUNDED))
    assert tie.choose_tier(100, [0, 0]) == 0


def test_tiered_model_invariants():
    with pytest.raises(AssertionError):
        TieredCRCostModel(tiers=(FAST, DISK), capacity_mib=(100, 100))
    with pytest.raises(AssertionError):
        TieredCRCostModel(tiers=(FAST,), capacity_mib=(100, UNBOUNDED))
    m = TieredCRCostModel(tiers=(FAST, DISK), capacity_mib=(100, UNBOUNDED))
    hash(m)                               # frozen: a valid static jit arg
    hash(SchedulerConfig(cr_tiers=m))


def test_tiered_from_stats_mem_disk_pair():
    class Mem:
        bytes_written = 4000 << 20
        bytes_read = 4000 << 20
        save_seconds = 1.0
        restore_seconds = 0.5

    class Disk:
        bytes_written = 500 << 20
        bytes_read = 500 << 20
        save_seconds = 1.0
        restore_seconds = 1.0

    m = TieredCRCostModel.from_stats([Mem(), Disk()], tick_seconds=0.1,
                                     capacity_mib=[8192, UNBOUNDED])
    assert m.n_tiers == 2
    assert m.capacity_mib == (8192, UNBOUNDED)
    # mem: 4000 MiB/s * 0.1 s/tick = 400 MiB/tick; disk: 50 MiB/tick
    assert m.tiers[0].save_cost(4000) == 10
    assert m.tiers[1].save_cost(4000) == 80
    # a tier with no measured traffic inherits the fastest measured model
    class Idle:
        bytes_written = 0
        save_seconds = 0.0

    m2 = TieredCRCostModel.from_stats([Mem(), Idle()], tick_seconds=0.1,
                                      capacity_mib=[8192, UNBOUNDED])
    assert m2.tiers[1] == m2.tiers[0]
    with pytest.raises(ValueError, match="no tier has measured save"):
        TieredCRCostModel.from_stats([Idle(), Idle()], tick_seconds=0.1,
                                     capacity_mib=[8192, UNBOUNDED])


# ---------------------------------------------------------------------------
# scheduling semantics, both backends
# ---------------------------------------------------------------------------


def test_zero_capacity_degenerates_to_single_durable_tier():
    """cap=0: every placement spills, so the schedule AND the charged
    overheads must be bit-identical to a single-tier model priced at the
    durable tier."""
    single = _run(SchedulerConfig(cpu_total=64, quantum=5, cr_cost=DISK))
    tiered = _run(_tiered(0))
    assert single.signature() == tiered.signature()
    assert [j.overhead for j in single.sim.job_table()] == \
        [j.overhead for j in tiered.sim.job_table()]
    spilled = [j for j in tiered.sim.job_table() if j.n_spills > 0]
    assert spilled, "every checkpoint should have spilled"


def test_unbounded_capacity_degenerates_to_single_fast_tier():
    single = _run(SchedulerConfig(cpu_total=64, quantum=5, cr_cost=FAST))
    tiered = _run(_tiered(UNBOUNDED))
    assert single.signature() == tiered.signature()
    assert all(j.n_spills == 0 for j in tiered.sim.job_table())


def test_placement_skip_fit_greedy():
    """A victim too big for the remaining fast capacity spills, but a
    LATER smaller victim may still claim the space — the sequential greedy,
    on both backends."""
    users = [User("A", 50.0), User("B", 50.0)]
    # three victims evicted in ONE pass (same priority, same run_start ->
    # id order): 8 GiB, 6 GiB, 2 GiB against a 10 GiB fast tier.  A
    # high-priority 32-CPU filler (admitted first, last in victim order)
    # keeps idle at 8, so the 32-CPU claim needs all three flood victims.
    flood = [Job(user="B", cpus=8, work=400, priority=0,
                 job_class=JobClass.CHECKPOINTABLE, submit_time=0,
                 state_bytes=gib << 30) for gib in (8, 6, 2)]
    filler = Job(user="B", cpus=32, work=400, priority=5,
                 job_class=JobClass.CHECKPOINTABLE, submit_time=0)
    claim = Job(user="A", cpus=32, work=8,
                job_class=JobClass.CHECKPOINTABLE, submit_time=10)
    cfg = SchedulerConfig(
        cpu_total=64, quantum=5,
        cr_tiers=TieredCRCostModel(tiers=(FAST, DISK),
                                   capacity_mib=(10 << 10, UNBOUNDED)))
    jobs = flood + [filler, claim]
    res = engine.simulate(users, [j.clone() for j in jobs], cfg, 12,
                          policy="omfs", backend="python")
    tiers = {j.id: j.ckpt_tier for j in res.sim.job_table()
             if j.n_checkpoints > 0}
    # 8 GiB fits (8<=10), 6 GiB spills (8+6>10), 2 GiB fits (8+2<=10)
    assert tiers[flood[0].id] == 0
    assert tiers[flood[1].id] == 1
    assert tiers[flood[2].id] == 0
    jx = engine.simulate(users, jobs, cfg, 12, policy="omfs", backend="jax")
    t = jax.device_get(jx.table)
    assert res.signature() == jx.signature()
    np.testing.assert_array_equal(
        t.ckpt_tier[:3], [tiers[f.id] for f in flood])
    np.testing.assert_array_equal(t.n_spill[:5], [0, 1, 0, 0, 0])


def test_capacity_frees_when_snapshot_restored():
    """A restore consumes the snapshot: after the ping-pong returns a
    victim to the machine, the next eviction can use the freed fast tier."""
    # fast tier fits exactly one 64 GiB snapshot; the thrashing scenario
    # evicts one victim at a time, so nothing should ever spill
    res = _run(_tiered(64 << 10))
    jobs = res.sim.job_table()
    assert sum(j.n_checkpoints for j in jobs) > 1
    assert sum(j.n_spills for j in jobs) == 0


def test_tiered_placement_recovers_goodput():
    """The bench headline as a test: fast-tier capacity only improves
    goodput over the all-spill (single slow tier) placement."""
    gibs = (128, 64, 32, 16)
    slow = _run(_tiered(0), gibs=gibs).summary()
    some = _run(_tiered(sum(g << 10 for g in gibs) // 2), gibs=gibs).summary()
    full = _run(_tiered(UNBOUNDED), gibs=gibs).summary()
    assert some["goodput"] >= slow["goodput"]
    assert full["goodput"] >= slow["goodput"]
    assert full["spills"] == 0 and slow["spills"] == slow["checkpoints"] > 0


# ---------------------------------------------------------------------------
# size-aware victim selection (omfs_cheap_victim)
# ---------------------------------------------------------------------------


def test_cheap_victim_prefers_cheapest_checkpoint():
    """Two equal-priority victims, one with a huge image: the faithful
    order evicts by (priority, run_start, id) — the big job first — while
    omfs_cheap_victim picks the small-image victim."""
    users = [User("A", 50.0), User("B", 50.0)]
    big = Job(user="B", cpus=16, work=400, job_class=JobClass.CHECKPOINTABLE,
              submit_time=0, state_bytes=64 << 30)
    small = Job(user="B", cpus=16, work=400,
                job_class=JobClass.CHECKPOINTABLE, submit_time=0,
                state_bytes=1 << 30)
    huge = Job(user="B", cpus=16, work=400,
               job_class=JobClass.CHECKPOINTABLE, submit_time=0,
               state_bytes=128 << 30)
    claim = Job(user="A", cpus=32, work=5,
                job_class=JobClass.CHECKPOINTABLE, submit_time=10)
    cfg = SchedulerConfig(cpu_total=64, quantum=5, cr_cost=DISK)

    def victims(policy):
        res = engine.simulate(users, [big.clone(), small.clone(),
                                      huge.clone(), claim.clone()], cfg, 12,
                              policy=policy, backend="python")
        return {j.id: j.n_checkpoints for j in res.sim.job_table()}

    faithful = victims("omfs")
    cheap = victims("omfs_cheap_victim")
    # the claim needs 32 CPUs: 16 idle + exactly one 16-CPU victim.
    # faithful order is (priority, run_start, id) -> big (lowest id);
    # cheap order is (save_cost, ...) -> small (1 GiB image)
    assert faithful[big.id] == 1 and faithful[small.id] == 0
    assert cheap[big.id] == 0 and cheap[small.id] == 1
    assert faithful[huge.id] == 0 and cheap[huge.id] == 0


def test_cheap_victim_changes_schedule_on_heterogeneous_flood():
    gibs = (128, 64, 32, 16)
    cfg = SchedulerConfig(cpu_total=64, quantum=5, cr_cost=DISK)
    a = _run(cfg, policy="omfs", gibs=gibs)
    b = _run(cfg, policy="omfs_cheap_victim", gibs=gibs)
    assert a.signature() != b.signature()
    assert b.summary()["goodput"] >= a.summary()["goodput"]


# ---------------------------------------------------------------------------
# the state_mib runtime-update hook
# ---------------------------------------------------------------------------


def _tick_setup(cfg):
    users, jobs = thrashing_scenario(64, quantum=5)
    tbl, ent = omfs_jax.table_from_jobs(jobs, users, cfg.cpu_total, cfg)

    @jax.jit
    def tick(tbl, ent, t):
        return engine.tick_jax(cfg, ent, tbl, t,
                               omfs_jax.make_omfs_pass())

    return tbl, ent, tick


def test_update_state_mib_recomputes_cost_columns():
    cfg = _tiered(64 << 10)
    users, jobs = thrashing_scenario(64, quantum=5)
    tbl, _ = omfs_jax.table_from_jobs(jobs, users, cfg.cpu_total, cfg)
    new = omfs_jax.update_state_mib(tbl, 0, 128 << 10, cfg)
    assert int(new.state_mib[0]) == 128 << 10
    assert int(new.cost_save[0]) == cfg.eviction_save_cost(128 << 10, 0)
    assert int(new.cost_save2[0]) == cfg.eviction_save_cost(128 << 10, 1)
    assert int(new.cost_restore2[0]) == cfg.restart_restore_cost(128 << 10, 1)
    # other rows untouched
    np.testing.assert_array_equal(np.asarray(new.cost_save[1:]),
                                  np.asarray(tbl.cost_save[1:]))


def test_update_state_mib_does_not_retrace():
    """The hook's contract: growing/shrinking a job's state between ticks
    must not invalidate the compiled tick (same shapes/dtypes)."""
    cfg = _tiered(64 << 10)
    tbl, ent, tick = _tick_setup(cfg)
    tbl = tick(tbl, ent, 0)
    n0 = tick._cache_size()
    assert n0 == 1
    tbl = omfs_jax.update_state_mib(tbl, 1, 4 << 10, cfg)
    tbl = tick(tbl, ent, 1)
    assert tick._cache_size() == n0, "update_state_mib caused a re-trace"


def test_update_state_mib_changes_schedule():
    """Shrinking a flood job's image mid-run (the quantized fast-tier save
    path) makes its C/R bounces cheaper, pulling its completion INTO the
    horizon — the schedule responds to the runtime size change without a
    rebuild (and growing it charges visibly more overhead)."""
    cfg = SchedulerConfig(cpu_total=64, quantum=5, cr_cost=DISK)
    tbl0, ent, tick = _tick_setup(cfg)

    def run(resize_to=None, at=2):
        tbl = tbl0
        for t in range(400):
            if resize_to is not None and t == at:
                tbl = omfs_jax.update_state_mib(tbl, 0, resize_to, cfg)
            tbl = tick(tbl, ent, t)
        return tbl

    base = run()
    shrunk = run(resize_to=1)            # 64 GiB -> 1 MiB before any evict
    grown = run(resize_to=512 << 10)
    assert omfs_jax.signature_from_table(base) != \
        omfs_jax.signature_from_table(shrunk)
    assert int(shrunk.overhead[0]) < int(base.overhead[0])
    assert int(grown.overhead[0]) > int(base.overhead[0])
    # cheaper bounces let the shrunk job finish within the horizon
    assert int(shrunk.finish[0]) >= 0
    assert int(base.finish[0]) < 0


# ---------------------------------------------------------------------------
# calibration bridge (checkpoint service -> scheduler)
# ---------------------------------------------------------------------------


def test_service_calibrate_tiered(tmp_path):
    from repro.checkpoint.manager import ManagerConfig
    from repro.checkpoint.service import CheckpointService

    svc = CheckpointService(ManagerConfig(root=tmp_path,
                                          mem_capacity_bytes=2 << 30,
                                          use_delta=False,
                                          async_durable=False))
    try:
        # deterministic measured traffic instead of real (flaky) timings
        mem, disk = svc.manager.mem.stats, svc.manager.disk.stats
        mem.bytes_written, mem.save_seconds = 8000 << 20, 1.0
        mem.bytes_read, mem.restore_seconds = 8000 << 20, 0.5
        disk.bytes_written, disk.save_seconds = 400 << 20, 1.0
        disk.bytes_read, disk.restore_seconds = 400 << 20, 1.0
        m = svc.calibrate_tiered(tick_seconds=0.1)
    finally:
        svc.close()
    assert isinstance(m, TieredCRCostModel)
    assert m.capacity_mib == (2 << 10, UNBOUNDED)
    # mem 800 MiB/tick vs disk 40 MiB/tick
    assert m.tiers[0].save_cost(8000) == 10
    assert m.tiers[1].save_cost(8000) == 200
    # the pair is a valid scheduler config end-to-end
    cfg = SchedulerConfig(cpu_total=64, quantum=5, cr_tiers=m)
    res = _run(cfg, horizon=100)
    jx = _run(cfg, backend="jax", horizon=100)
    assert res.signature() == jx.signature()
