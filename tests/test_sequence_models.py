"""Sequence-mixing equivalences: MLA absorbed==full, SSM scan==step,
mLSTM chunk==recurrence, sLSTM streaming, MoE reference properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MLAConfig, MoEConfig, SSMConfig, XLSTMConfig
from repro.models.layers import build_params
from repro.models.mla import mla_attention_decode, mla_attention_full, mla_params_spec
from repro.models.moe import moe_ffn, moe_params_spec, route_topk
from repro.models.ssm import SSMState, ssm_decode_step, ssm_forward, ssm_params_spec
from repro.models.xlstm import (
    MLSTMState,
    SLSTMState,
    mlstm_forward,
    mlstm_params_spec,
    slstm_forward,
    slstm_params_spec,
)

KEY = jax.random.PRNGKey(0)


def test_mla_absorbed_decode_equals_full_attention():
    mla = MLAConfig(q_lora_rank=12, kv_lora_rank=8, qk_nope_head_dim=6,
                    qk_rope_head_dim=4, v_head_dim=6)
    H, d, B, T = 3, 16, 2, 9
    params = build_params(mla_params_spec(d, H, mla, jnp.float32), KEY)
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (B, T, d))
    pos = jnp.broadcast_to(jnp.arange(T), (B, T))
    out_full, (ckv, kr) = mla_attention_full(mla, H, params, x, pos, 1e4,
                                             q_chunk=4, kv_chunk=4)
    out_dec = mla_attention_decode(mla, H, params, x[:, -1:], pos[:, -1:],
                                   ckv, kr, pos, 1e4)
    np.testing.assert_allclose(np.asarray(out_full[:, -1:]),
                               np.asarray(out_dec), atol=1e-4)


def test_ssm_scan_equals_stepwise_decode():
    ssm = SSMConfig(d_state=4, d_conv=3, expand=2)
    d, B, T = 8, 2, 11
    params = build_params(ssm_params_spec(d, ssm, jnp.float32), KEY)
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (B, T, d)) * 0.5
    st0 = SSMState.init(B, d, ssm)
    y_full, st_full = ssm_forward(ssm, params, x, st0, chunk=4)
    st = st0
    ys = []
    for i in range(T):
        y, st = ssm_decode_step(ssm, params, x[:, i : i + 1], st)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(y_full),
                               np.asarray(jnp.concatenate(ys, 1)), atol=1e-5)
    np.testing.assert_allclose(np.asarray(st_full.h), np.asarray(st.h), atol=1e-5)


@pytest.mark.parametrize("chunks", [(4, 1), (13, 4)])
def test_mlstm_chunk_sizes_agree(chunks):
    big, small = chunks
    xl = XLSTMConfig(conv_width=3)
    d, H, B, T = 8, 2, 2, 13
    params = build_params(mlstm_params_spec(d, H, xl, jnp.float32), KEY)
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (B, T, d)) * 0.5
    st0 = MLSTMState.init(B, d, H, xl)
    y_a, _ = mlstm_forward(xl, H, params, x, st0, chunk=big)
    y_b, _ = mlstm_forward(xl, H, params, x, st0, chunk=small)
    np.testing.assert_allclose(np.asarray(y_a), np.asarray(y_b), atol=1e-4)


def test_mlstm_streaming_equals_one_shot():
    xl = XLSTMConfig(conv_width=3)
    d, H, B, T = 8, 2, 2, 13
    params = build_params(mlstm_params_spec(d, H, xl, jnp.float32), KEY)
    x = jax.random.normal(jax.random.fold_in(KEY, 2), (B, T, d)) * 0.5
    st0 = MLSTMState.init(B, d, H, xl)
    y_ref, _ = mlstm_forward(xl, H, params, x, st0, chunk=4)
    y_a, st = mlstm_forward(xl, H, params, x[:, :7], st0, chunk=4)
    y_b, _ = mlstm_forward(xl, H, params, x[:, 7:], st, chunk=4)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([y_a, y_b], 1)), np.asarray(y_ref), atol=1e-4)


def test_slstm_streaming_equals_one_shot():
    xl = XLSTMConfig(conv_width=3)
    d, H, B, T = 8, 2, 2, 13
    params = build_params(slstm_params_spec(d, H, xl, jnp.float32), KEY)
    x = jax.random.normal(jax.random.fold_in(KEY, 3), (B, T, d)) * 0.5
    st0 = SLSTMState.init(B, d, xl)
    y_ref, _ = slstm_forward(xl, H, params, x, st0, chunk=4)
    y_a, st = slstm_forward(xl, H, params, x[:, :7], st0, chunk=4)
    y_b, _ = slstm_forward(xl, H, params, x[:, 7:], st, chunk=4)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([y_a, y_b], 1)), np.asarray(y_ref), atol=2e-5)


def test_moe_routing_properties():
    moe = MoEConfig(n_routed=8, top_k=2, d_expert=16, n_shared=1, d_shared=32)
    params = build_params(moe_params_spec(24, moe, jnp.float32), KEY)
    x = jax.random.normal(jax.random.fold_in(KEY, 4), (2, 5, 24))
    y, aux = jax.jit(lambda p, x: moe_ffn(moe, p, x))(params, x)
    assert y.shape == x.shape and jnp.isfinite(y).all()
    assert aux > 0
    # routing weights renormalized
    logits = jax.random.normal(KEY, (13, 8))
    w, ids, probs = route_topk(logits, 3)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, atol=1e-6)
    assert int(ids.max()) < 8


def test_moe_permutation_equivariance():
    """Permuting tokens permutes outputs (dispatch bookkeeping is sound)."""
    moe = MoEConfig(n_routed=4, top_k=2, d_expert=16)
    params = build_params(moe_params_spec(12, moe, jnp.float32), KEY)
    x = jax.random.normal(jax.random.fold_in(KEY, 5), (1, 9, 12))
    perm = jax.random.permutation(jax.random.fold_in(KEY, 6), 9)
    y, _ = moe_ffn(moe, params, x)
    y_p, _ = moe_ffn(moe, params, x[:, perm])
    np.testing.assert_allclose(np.asarray(y[:, perm]), np.asarray(y_p), atol=1e-5)
