"""Property tests: every registered policy's JAX pass is step-equivalent to
its Python twin through the unified engine, and the incremental-aggregate
OMFS pass is schedule-identical to the reference pass it optimizes — with
and without nonzero, heterogeneous size-aware C/R costs."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import engine, omfs_jax
from repro.core.crcost import UNBOUNDED, CRCostModel, TieredCRCostModel
from repro.core.simulator import simulate
from repro.core.types import SchedulerConfig
from repro.core.workload import WorkloadSpec, make_jobs, make_users

POLICY_NAMES = sorted(engine.POLICIES)


def _workload(seed, n_users, horizon=100, cpu_total=32):
    spec = WorkloadSpec(n_users=n_users, horizon=horizon, cpu_total=cpu_total,
                        seed=seed, arrival_rate=0.12, mean_work=30,
                        class_mix=(0.15, 0.35, 0.5))
    users = make_users(spec)
    jobs = make_jobs(spec, users)[:35]
    return users, jobs


@pytest.mark.parametrize("policy", POLICY_NAMES)
@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10_000), quantum=st.integers(0, 15),
       n_users=st.integers(2, 4))
def test_policy_python_jax_equivalence(policy, seed, quantum, n_users):
    users, jobs = _workload(seed, n_users)
    if not jobs:
        return
    cfg = SchedulerConfig(cpu_total=32, quantum=quantum, cr_overhead=2)
    py = engine.simulate(users, jobs, cfg, 100,
                         policy=policy, backend="python")
    jx = engine.simulate(users, jobs, cfg, 100, policy=policy, backend="jax")
    assert py.signature() == jx.signature()
    assert (py.busy_series() == jx.busy_series()).all()


@pytest.mark.parametrize("policy", POLICY_NAMES)
@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 10_000), quantum=st.integers(0, 12),
       save_bw=st.integers(64, 8192), restore_bw=st.integers(64, 8192),
       save_base=st.integers(0, 3), restore_base=st.integers(0, 3))
def test_policy_equivalence_heterogeneous_cr_costs(
        policy, seed, quantum, save_bw, restore_bw, save_base, restore_base):
    """Nonzero, per-job-heterogeneous C/R costs (lognormal state sizes from
    the workload generator x a randomized cost model): the JAX backend's
    precomputed cost columns must charge bit-identically to the Python
    backend's runtime model evaluation, for every registered policy."""
    users, jobs = _workload(seed, n_users=3)
    if not jobs:
        return
    assert any(j.state_bytes > 0 for j in jobs)
    model = CRCostModel(save_mib_per_tick=save_bw,
                        restore_mib_per_tick=restore_bw,
                        save_base=save_base, restore_base=restore_base,
                        compress_num=200, compress_den=256)
    cfg = SchedulerConfig(cpu_total=32, quantum=quantum, cr_overhead=1,
                          cr_cost=model)
    py = engine.simulate(users, jobs, cfg, 100,
                         policy=policy, backend="python")
    jx = engine.simulate(users, jobs, cfg, 100, policy=policy, backend="jax")
    assert py.signature() == jx.signature()
    assert (py.busy_series() == jx.busy_series()).all()


@pytest.mark.parametrize("policy", ["omfs", "omfs_cheap_victim",
                                    "backfill_cr"])
@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 10_000), quantum=st.integers(0, 12),
       # sampled (not free-range) so repeated examples share compiled scans
       cap_mib=st.sampled_from([0, 2_000, 50_000, 500_000, UNBOUNDED]),
       fast_bw=st.sampled_from([4096, 16384]),
       slow_bw=st.sampled_from([512, 2048]))
def test_policy_equivalence_tiered_placement(
        policy, seed, quantum, cap_mib, fast_bw, slow_bw):
    """Tiered eviction placement fuzz: heterogeneous lognormal state sizes
    competing for a capacity-bounded fast tier, durable spill — the JAX
    placement scan must produce bit-identical schedules (and spill counts)
    to the Python reference's sequential greedy, for the eviction-heavy
    policies."""
    users, jobs = _workload(seed, n_users=3)
    if not jobs:
        return
    tiers = TieredCRCostModel(
        tiers=(CRCostModel(save_mib_per_tick=fast_bw,
                           restore_mib_per_tick=2 * fast_bw),
               CRCostModel(save_mib_per_tick=slow_bw,
                           restore_mib_per_tick=2 * slow_bw, save_base=1)),
        capacity_mib=(cap_mib, UNBOUNDED))
    cfg = SchedulerConfig(cpu_total=32, quantum=quantum, cr_overhead=1,
                          cr_tiers=tiers)
    py = engine.simulate(users, jobs, cfg, 100,
                         policy=policy, backend="python")
    jx = engine.simulate(users, jobs, cfg, 100, policy=policy, backend="jax")
    assert py.signature() == jx.signature()
    assert (py.busy_series() == jx.busy_series()).all()
    assert py.summary()["spills"] == jx.summary()["spills"]


@pytest.mark.parametrize("policy", POLICY_NAMES)
@pytest.mark.parametrize("drop_killed", [True, False])
def test_policy_equivalence_kill_policies(policy, drop_killed):
    users, jobs = _workload(seed=7, n_users=3, horizon=120)
    cfg = SchedulerConfig(cpu_total=32, quantum=5, drop_killed=drop_killed)
    py = engine.simulate(users, jobs, cfg, 120,
                         policy=policy, backend="python")
    jx = engine.simulate(users, jobs, cfg, 120, policy=policy, backend="jax")
    assert py.signature() == jx.signature()


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000), quantum=st.integers(0, 15),
       cr=st.integers(0, 5))
def test_omfs_incremental_matches_reference(seed, quantum, cr):
    """The incremental-aggregate rewrite changes no schedule: bit-identical
    signature_from_table vs the reference O(J)-per-admission pass."""
    users, jobs = _workload(seed, n_users=3)
    if not jobs:
        return
    cfg = SchedulerConfig(cpu_total=32, quantum=quantum, cr_overhead=cr)
    tbl_ref, busy_ref = omfs_jax.simulate_jax(users, jobs, cfg, 100,
                                              incremental=False)
    tbl_inc, busy_inc = omfs_jax.simulate_jax(users, jobs, cfg, 100,
                                              incremental=True)
    assert omfs_jax.signature_from_table(tbl_ref) == \
        omfs_jax.signature_from_table(tbl_inc)
    assert (np.asarray(busy_ref) == np.asarray(busy_inc)).all()


@pytest.mark.parametrize("pass_depth", [4, 16, None])
def test_omfs_incremental_matches_reference_bounded_pass(pass_depth):
    users, jobs = _workload(seed=5, n_users=4, horizon=80, cpu_total=64)
    cfg = SchedulerConfig(cpu_total=64, quantum=4)
    tbl_ref, _ = omfs_jax.simulate_jax(users, jobs, cfg, 80, pass_depth,
                                       incremental=False)
    tbl_inc, _ = omfs_jax.simulate_jax(users, jobs, cfg, 80, pass_depth,
                                       incremental=True)
    assert omfs_jax.tables_equal(tbl_ref, tbl_inc)


def test_omfs_incremental_matches_reference_with_cost_model():
    """The incremental pass and the reference pass share the charging
    primitives, so a nonzero size-aware cost model must not split them."""
    users, jobs = _workload(seed=5, n_users=3)   # seed 5: >0 checkpoints
    cfg = SchedulerConfig(
        cpu_total=32, quantum=4,
        cr_cost=CRCostModel(save_mib_per_tick=256, restore_mib_per_tick=512,
                            save_base=2, restore_base=1))
    tbl_ref, _ = omfs_jax.simulate_jax(users, jobs, cfg, 100,
                                       incremental=False)
    tbl_inc, _ = omfs_jax.simulate_jax(users, jobs, cfg, 100,
                                       incremental=True)
    assert omfs_jax.tables_equal(tbl_ref, tbl_inc)
    assert int(np.asarray(tbl_inc.overhead).sum()) > 0, \
        "cost model never charged anything — scenario too tame to test"


def test_omfs_incremental_matches_reference_beyond_paper_flags():
    users, jobs = _workload(seed=11, n_users=3)
    cfg = SchedulerConfig(cpu_total=32, quantum=5,
                          victim_filter_over_entitlement=True,
                          avoid_self_eviction=True)
    tbl_ref, _ = omfs_jax.simulate_jax(users, jobs, cfg, 100,
                                       incremental=False)
    tbl_inc, _ = omfs_jax.simulate_jax(users, jobs, cfg, 100,
                                       incremental=True)
    assert omfs_jax.tables_equal(tbl_ref, tbl_inc)


def test_simulator_adapter_matches_engine():
    """core.simulator.simulate is a thin adapter: identical SimResult
    content to calling the engine's python backend directly."""
    users, jobs = _workload(seed=3, n_users=3)
    cfg = SchedulerConfig(cpu_total=32, quantum=10)
    res = simulate(users, [j.clone() for j in jobs], cfg, 100)
    eng = engine.simulate(users, jobs, cfg, 100,
                          policy="omfs", backend="python")
    assert res.schedule_signature() == eng.sim.schedule_signature()
    assert [t.busy for t in res.log] == [t.busy for t in eng.sim.log]


def test_simulate_matrix_matches_per_policy_simulate():
    """The shared lax.switch scan (one compile for every policy) must be
    bit-identical to compiling one scan per policy."""
    users, jobs = _workload(seed=9, n_users=3)
    cfg = SchedulerConfig(cpu_total=32, quantum=6, cr_overhead=1)
    matrix = engine.simulate_matrix(users, jobs, cfg, 100, POLICY_NAMES)
    assert [r.policy for r in matrix] == POLICY_NAMES
    for res in matrix:
        solo = engine.simulate(users, jobs, cfg, 100,
                               policy=res.policy, backend="jax")
        assert res.signature() == solo.signature(), res.policy
        assert (res.busy_series() == solo.busy_series()).all()


def test_simulate_matrix_rejects_unknown():
    users, jobs = _workload(seed=3, n_users=2)
    with pytest.raises(ValueError, match="unknown policies"):
        engine.simulate_matrix(users, jobs, SchedulerConfig(cpu_total=32),
                               10, ["omfs", "nope"])


def test_engine_rejects_unknown():
    users, jobs = _workload(seed=3, n_users=2)
    cfg = SchedulerConfig(cpu_total=32)
    with pytest.raises(ValueError, match="unknown policy"):
        engine.simulate(users, jobs, cfg, 10, policy="nope", backend="jax")
    with pytest.raises(ValueError, match="unknown policy"):
        engine.simulate(users, jobs, cfg, 10, policy="nope", backend="python")
    with pytest.raises(ValueError, match="unknown backend"):
        engine.simulate(users, jobs, cfg, 10, backend="tpu-pod")


def test_backfill_marks_and_reuses_backfilled_jobs():
    """backfill_cr's C/R preemption only ever targets jobs that were
    admitted by queue-jumping (Niu et al.) — on both backends."""
    users, jobs = _workload(seed=13, n_users=4, horizon=150)
    cfg = SchedulerConfig(cpu_total=32, quantum=3, cr_overhead=1)
    py = engine.simulate(users, jobs, cfg, 150,
                         policy="backfill_cr", backend="python")
    jx = engine.simulate(users, jobs, cfg, 150, policy="backfill_cr",
                         backend="jax")
    assert py.signature() == jx.signature()
    py_backfilled = {j.id for j in py.sim.job_table() if j.backfilled}
    jx_backfilled = set(np.flatnonzero(
        np.asarray(jx.table.backfilled) > 0).tolist())
    ids = sorted(j.id for j in py.sim.job_table())
    assert {ids.index(i) for i in py_backfilled} == jx_backfilled
