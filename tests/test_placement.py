"""Buddy-allocator placement properties (the TPU slice-shape layer)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.placement import BuddyAllocator, _round_pow2


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.booleans(), st.integers(1, 64)), min_size=1, max_size=60))
def test_alloc_release_invariants(ops):
    """Random alloc/release sequences: no overlap, conservation, coalescing."""
    total = 256
    alloc = BuddyAllocator(total)
    live = {}
    next_id = 0
    for is_alloc, cpus in ops:
        if is_alloc:
            got = alloc.place(next_id, cpus)
            if got is not None:
                off, size = got
                assert size >= cpus and size == _round_pow2(cpus)
                assert off % size == 0                    # buddy alignment
                live[next_id] = (off, size)
                next_id += 1
        elif live:
            jid = next(iter(live))
            alloc.release(jid)
            live.pop(jid)
        # invariants
        spans = sorted(live.values())
        for (o1, s1), (o2, _s2) in zip(spans, spans[1:]):
            assert o1 + s1 <= o2, "overlapping allocations"
        assert alloc.free_chips() == total - sum(s for _, s in live.values())
    # release everything -> coalesces back to one block
    for jid in list(live):
        alloc.release(jid)
    assert alloc.free_blocks[total] == {0}


def test_fragmentation_blocks_but_eviction_plan_unblocks():
    alloc = BuddyAllocator(16)
    assert alloc.place(1, 4) and alloc.place(2, 4) and alloc.place(3, 4) and alloc.place(4, 4)
    assert not alloc.can_place(4)
    # jobs 3 (@8) and 4 (@12) are buddies: releasing both coalesces to an
    # 8-block; jobs 2+3 (@4,@8) would NOT (buddy misalignment)
    assert alloc.victims_for_block(8, [(2, 0)]) is None
    plan = alloc.victims_for_block(8, [(3, 0), (4, 1)])
    assert plan == [3, 4]
    for jid in plan:
        alloc.release(jid)
    assert alloc.can_place(8)


def test_victims_for_block_returns_none_when_impossible():
    alloc = BuddyAllocator(16)
    for i in range(4):
        alloc.place(i, 4)
    assert alloc.victims_for_block(32, [(0, 0)]) is None


# ---------------------------------------------------------------------------
# BuddyAllocator invariants (alloc/free round-trips, conservation, oracle)
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.booleans(), st.integers(1, 64)),
                min_size=1, max_size=60))
def test_can_place_agrees_with_place(ops):
    """The feasibility oracle never lies: can_place(c) iff place(c) succeeds,
    at every reachable allocator state."""
    alloc = BuddyAllocator(256)
    live = []
    next_id = 0
    for is_alloc, cpus in ops:
        if is_alloc:
            oracle = alloc.can_place(cpus)
            got = alloc.place(next_id, cpus)
            assert oracle == (got is not None)
            if got is not None:
                live.append(next_id)
                next_id += 1
        elif live:
            alloc.release(live.pop(0))


def test_alloc_free_roundtrip_coalesces_buddies():
    """Releasing in any order coalesces back to the single full block."""
    import itertools
    sizes = [4, 8, 2, 16, 4, 2]
    for perm in itertools.permutations(range(len(sizes))):
        alloc = BuddyAllocator(64)
        for jid, c in enumerate(sizes):
            assert alloc.place(jid, c) is not None
        for jid in perm:
            alloc.release(jid)
        assert alloc.free_blocks[64] == {0}
        assert all(not offs for s, offs in alloc.free_blocks.items() if s != 64)
        assert alloc.free_chips() == 64


def test_free_chips_conserved_through_failures():
    """free_chips is conserved by successful ops and untouched by failed
    placements (no partial splits leak)."""
    alloc = BuddyAllocator(32)
    assert alloc.free_chips() == 32
    assert alloc.place(0, 10) is not None          # rounds to 16
    assert alloc.free_chips() == 16
    assert alloc.place(1, 16) is not None
    assert alloc.free_chips() == 0
    before = {s: set(o) for s, o in alloc.free_blocks.items()}
    assert alloc.place(2, 1) is None               # full: must not mutate
    assert alloc.free_chips() == 0
    assert {s: set(o) for s, o in alloc.free_blocks.items()} == before
    alloc.release(0)
    assert alloc.free_chips() == 16
    alloc.release(1)
    assert alloc.free_chips() == 32
    assert alloc.free_blocks[32] == {0}
