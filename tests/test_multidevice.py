"""Multi-device tests (8 virtual CPU devices via XLA_FLAGS, run in
subprocesses so the main pytest process keeps its single real device —
jax locks the device count at first init)."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]


def run_in_subprocess(body: str, timeout=420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(REPO / "src")
    code = textwrap.dedent(body)
    proc = subprocess.run(
        [sys.executable, "-c", code], env=env, timeout=timeout,
        capture_output=True, text=True,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr[-3000:]}"
    return proc.stdout


def test_moe_ep_equals_reference_and_grad():
    run_in_subprocess("""
        import jax, jax.numpy as jnp
        from repro.configs.base import MoEConfig
        from repro.models.moe import moe_ffn, moe_params_spec
        from repro.distributed.moe_ep import moe_ffn_ep
        from repro.models.layers import build_params
        mesh = jax.make_mesh((2,4), ("data","model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        moe = MoEConfig(n_routed=8, top_k=2, d_expert=16, n_shared=1, d_shared=32)
        params = build_params(moe_params_spec(24, moe, jnp.float32), jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 6, 24)) * 0.5
        y_ref, _ = jax.jit(lambda p, x: moe_ffn(moe, p, x))(params, x)
        with mesh:
            y_ep, _ = jax.jit(lambda p, x: moe_ffn_ep(moe, p, x, mesh,
                              capacity_factor=8.0))(params, x)
            g = jax.jit(jax.grad(lambda p: moe_ffn_ep(moe, p, x, mesh,
                        capacity_factor=8.0)[0].sum()))(params)
        err = float(jnp.abs(y_ep - y_ref).max())
        assert err < 1e-5, err
        assert all(bool(jnp.isfinite(l).all()) for l in jax.tree.leaves(g))
        print("EP == reference, grads finite; err:", err)
    """)


def test_moe_ep_capacity_drops_degrade_gracefully():
    run_in_subprocess("""
        import jax, jax.numpy as jnp
        from repro.configs.base import MoEConfig
        from repro.models.moe import moe_ffn, moe_params_spec
        from repro.distributed.moe_ep import moe_ffn_ep
        from repro.models.layers import build_params
        mesh = jax.make_mesh((2,4), ("data","model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        moe = MoEConfig(n_routed=8, top_k=2, d_expert=16)
        params = build_params(moe_params_spec(24, moe, jnp.float32), jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 24)) * 0.5
        y_ref, _ = jax.jit(lambda p, x: moe_ffn(moe, p, x))(params, x)
        errs = []
        with mesh:
            for cf in (0.5, 1.0, 8.0):
                y, _ = jax.jit(lambda p, x: moe_ffn_ep(moe, p, x, mesh,
                               capacity_factor=cf))(params, x)
                errs.append(float(jnp.abs(y - y_ref).mean()))
        assert errs[0] >= errs[1] >= errs[2], errs      # more capacity -> closer
        assert errs[2] < 1e-6
        print("capacity-drop degradation monotone:", errs)
    """)


def test_elastic_reshard_across_meshes():
    """Save sharded on a (4,2) mesh, restore on (2,4) and on 1 device —
    values identical (elastic restart / shrink-after-failure)."""
    run_in_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint.reshard import restore_resharded, save_global
        m1 = jax.make_mesh((4,2), ("data","model"),
                           axis_types=(jax.sharding.AxisType.Auto,)*2)
        m2 = jax.make_mesh((2,4), ("data","model"),
                           axis_types=(jax.sharding.AxisType.Auto,)*2)
        w = jax.random.normal(jax.random.PRNGKey(0), (16, 8))
        state = {"w": jax.device_put(w, NamedSharding(m1, P("data","model"))),
                 "b": jax.device_put(jnp.arange(8.0), NamedSharding(m1, P("model")))}
        leaves = save_global(state)
        template = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state)
        sh2 = {"w": NamedSharding(m2, P("data","model")),
               "b": NamedSharding(m2, P("model"))}
        restored = restore_resharded(leaves, template, sh2)
        assert (np.asarray(restored["w"]) == np.asarray(w)).all()
        assert restored["w"].sharding.mesh.shape["model"] == 4
        single = restore_resharded(leaves, template, None)
        assert (np.asarray(single["w"]) == np.asarray(w)).all()
        print("elastic reshard OK")
    """)


def test_train_step_compiles_and_runs_sharded():
    """A real (tiny) MoE train step executes on a 2x4 mesh with the
    production sharding rules and produces finite loss."""
    run_in_subprocess("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.base import ModelConfig, MoEConfig
        from repro.models.model import build_model
        from repro.train.state import init_train_state
        from repro.train.steps import TrainConfig, make_train_step
        from repro.distributed import sharding as shd
        mesh = jax.make_mesh((2,4), ("data","model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        cfg = ModelConfig(name="m", family="moe", n_layers=2, d_model=32,
                          n_heads=4, n_kv_heads=2, d_ff=48, vocab=128,
                          moe=MoEConfig(n_routed=8, top_k=2, d_expert=48))
        model = build_model(cfg, q_chunk=16, kv_chunk=16)
        step = make_train_step(model, TrainConfig(grad_accum=2))
        with jax.set_mesh(mesh):
            state = init_train_state(model.init(jax.random.PRNGKey(0)))
            p_sh = shd.param_shardings(cfg, state.params, mesh)
            state = state._replace(params=jax.device_put(state.params, p_sh))
            batch = {"tokens": jnp.zeros((8, 32), jnp.int32),
                     "labels": jnp.zeros((8, 32), jnp.int32)}
            state, metrics = jax.jit(step)(state, batch)
            loss = float(metrics["loss"])
        assert loss == loss and loss > 0
        print("sharded train step OK, loss", loss)
    """)


def test_sharded_kv_decode_equals_baseline():
    """Flash-decoding with sequence-sharded KV cache (the decode hillclimb)
    is numerically identical to the baseline decode attention."""
    run_in_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import ModelConfig
        from repro.models.model import build_model
        mesh = jax.make_mesh((2,4), ("data","model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        base = ModelConfig(name="m", family="dense", n_layers=2, d_model=32,
                           n_heads=4, n_kv_heads=2, d_ff=64, vocab=128,
                           compute_dtype="float32")
        key = jax.random.PRNGKey(0)
        B, S = 4, 16
        batch = {"tokens": jax.random.randint(key, (B, S), 0, 128)}
        tok = jax.random.randint(jax.random.fold_in(key, 1), (B, 1), 0, 128)
        outs = {}
        for name, flag in (("baseline", False), ("sharded", True)):
            cfg = base.replace(decode_kv_shard=flag)
            model = build_model(cfg, q_chunk=8, kv_chunk=8)
            params = model.init(key)
            with jax.set_mesh(mesh):
                cache = model.init_cache(B, S + 4, dtype=jnp.float32)
                cache, _ = jax.jit(model.prefill)(params, batch, cache)
                cache, _ = jax.jit(model.decode_step)(params, cache, tok)
                cache, logits = jax.jit(model.decode_step)(params, cache, tok)
            outs[name] = np.asarray(logits)
        err = np.abs(outs["baseline"] - outs["sharded"]).max()
        assert err < 1e-4, err
        print("sharded-KV decode == baseline, err", err)
    """)
