"""Test-suite bootstrap: fall back to the deterministic hypothesis stub.

`hypothesis` is a declared test dependency (pyproject.toml), but the suite
must still collect in hermetic containers where installing is impossible —
without this, every property-test module dies at import time.  The stub
(`tests/_hypothesis_stub.py`) draws a fixed seeded example set per test;
with the real package installed this file is a no-op.
"""
import os
import sys

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_stub

    _hyp, _st = _hypothesis_stub.as_modules()
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
