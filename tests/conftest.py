"""Test-suite bootstrap: hypothesis-stub fallback + known-failure xfails.

`hypothesis` is a declared test dependency (pyproject.toml), but the suite
must still collect in hermetic containers where installing is impossible —
without this, every property-test module dies at import time.  The stub
(`tests/_hypothesis_stub.py`) draws a fixed seeded example set per test;
with the real package installed this file is a no-op.

The collection hook applies ``tests/known_failures.toml`` (the triaged
kernel/multidevice gaps) as **strict** xfails: a listed test that starts
passing fails the run — stale entries cannot linger — and an unlisted test
that breaks fails normally.  The registry format itself is validated by
``python -m repro.analysis`` (rule: known-failures).
"""
import os
import sys
from pathlib import Path

import pytest

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_stub

    _hyp, _st = _hypothesis_stub.as_modules()
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st

_REPO_ROOT = Path(__file__).resolve().parent.parent


def _known_failures():
    from repro.analysis.known_failures import load_known_failures

    return load_known_failures(_REPO_ROOT)


def pytest_collection_modifyitems(config, items):
    try:
        known = _known_failures()
    except FileNotFoundError:
        return
    for item in items:
        nodeid = item.nodeid.replace("\\", "/")
        if not nodeid.startswith("tests/"):
            nodeid = "tests/" + nodeid.lstrip("./")
        reason = known.get(nodeid)
        if reason is not None:
            item.add_marker(pytest.mark.xfail(
                strict=True,
                reason=f"known failure (tests/known_failures.toml): {reason}"))
