"""[J, T] cost lattice + delta-aware recurrent saves (DESIGN.md §Cost
lattice): Python <-> JAX bit-equality for arbitrary tier counts on both
kernel backends, the T=2 degeneracy guarantee for every registered policy,
first-vs-recurrent pricing through evict -> restore -> evict cycles, and
the unified ``calibrate(tiers=...)`` entry with its deprecation shims."""
import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import engine
from repro.core.crcost import (
    MEASURED_DELTA_FRAC,
    MEASURED_DELTA_ZSTD,
    UNBOUNDED,
    CRCostModel,
    TieredCRCostModel,
    measured_delta_num,
)
from repro.core.types import SchedulerConfig
from repro.core.workload import (
    WorkloadSpec,
    make_jobs,
    make_users,
    thrashing_scenario,
)
from repro.obs.events import canonical_sort

POLICY_NAMES = sorted(engine.POLICIES)
BACKENDS = ("lax", "pallas_interpret")
DELTA = measured_delta_num()        # 182/256: the bench_cr_cost blend

#: per-tier save bandwidths, fastest first (HBM / DRAM / NVMe / object)
BWS = (16384, 4096, 1024, 128)


def _with_backend(cfg, backend):
    return cfg if backend == "lax" else dataclasses.replace(
        cfg, kernel_backend=backend)


def _lattice(n_tiers, cap0_mib, delta_num, delta_den=1):
    """A T-deep hierarchy: geometric capacities over a shared delta model."""
    if cap0_mib == UNBOUNDED:
        caps = (UNBOUNDED,) * n_tiers
    else:
        caps = tuple(cap0_mib * (k + 1)
                     for k in range(n_tiers - 1)) + (UNBOUNDED,)
    tiers = tuple(
        CRCostModel(save_mib_per_tick=BWS[k], restore_mib_per_tick=2 * BWS[k],
                    save_base=min(k, 2), delta_num=delta_num,
                    delta_den=delta_den)
        for k in range(n_tiers))
    return TieredCRCostModel(tiers=tiers, capacity_mib=caps)


def _workload(seed, n_users=3, horizon=100, cpu_total=32):
    spec = WorkloadSpec(n_users=n_users, horizon=horizon, cpu_total=cpu_total,
                        seed=seed, arrival_rate=0.12, mean_work=30,
                        class_mix=(0.15, 0.35, 0.5))
    users = make_users(spec)
    jobs = make_jobs(spec, users)[:35]
    return users, jobs


# ---------------------------------------------------------------------------
# model semantics: the two-coefficient (first, recurrent) pricing
# ---------------------------------------------------------------------------


def test_delta_model_semantics():
    m = CRCostModel(save_mib_per_tick=256, restore_mib_per_tick=256,
                    delta_num=DELTA, delta_den=256)
    assert m.recurrent_save_cost(1000) < m.save_cost(1000)
    # the delta image on the /256 integer grid: ceil(mib * 182 / 256)
    assert m.delta_mib(1000) == -(-1000 * DELTA // 256)
    # default coefficients (1, 1) are exact legacy pricing
    legacy = CRCostModel(save_mib_per_tick=256, restore_mib_per_tick=256)
    assert legacy.recurrent_save_cost(1000) == legacy.save_cost(1000)
    # the quantized bench_cr_cost blend: 0.64 * 0.549 + 0.36 ~= 182/256
    eff = MEASURED_DELTA_FRAC * MEASURED_DELTA_ZSTD + (1 - MEASURED_DELTA_FRAC)
    assert DELTA == round(eff * 256) == 182
    assert measured_delta_num(1.0, 0.0) == 256     # no delta savings
    assert CRCostModel.from_measured(
        save_bytes_per_s=256 << 20, restore_bytes_per_s=256 << 20,
        tick_seconds=1.0, delta_ratio=eff).delta_num == DELTA


def test_choose_tier_recurrent_uses_delta_costs():
    """The placement decision itself is delta-aware: a warm job shops with
    its real (delta) write in hand, which can flip the cheapest tier."""
    m = TieredCRCostModel(
        tiers=(CRCostModel(save_mib_per_tick=100, restore_mib_per_tick=100),
               CRCostModel(save_mib_per_tick=100, restore_mib_per_tick=100,
                           delta_num=64, delta_den=256)),
        capacity_mib=(UNBOUNDED, UNBOUNDED))
    # first save: equal full-image cost, tie breaks toward the faster tier
    assert m.choose_tier(400, [0, 0]) == 0
    # recurrent: tier 1 moves a 4x smaller delta image and wins
    assert m.choose_tier(400, [0, 0], recurrent=True) == 1


# ---------------------------------------------------------------------------
# cross-backend bit-equality, T in {2, 3, 4}
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_tiers", [2, 3, 4])
@settings(max_examples=3, deadline=None)
@given(seed=st.integers(0, 10_000),
       # sampled (not free-range) so repeated examples share compiled scans
       quantum=st.sampled_from([0, 3, 5]),
       cap0=st.sampled_from([0, 2_000, 50_000, UNBOUNDED]),
       delta=st.sampled_from([(1, 1), (141, 256), (DELTA, 256)]))
def test_lattice_fuzz_python_vs_jax(n_tiers, seed, quantum, cap0, delta):
    """Evict -> restore -> evict sequences over a T-deep lattice: the JAX
    backend's precomputed first/recurrent columns and T-tier placement scan
    must charge and place bit-identically to the Python model's runtime
    evaluation, on both kernel-dispatch paths."""
    users, jobs = _workload(seed)
    if not jobs:
        return
    cfg = SchedulerConfig(cpu_total=32, quantum=quantum, cr_overhead=1,
                          cr_tiers=_lattice(n_tiers, cap0, *delta))
    py = engine.simulate(users, [j.clone() for j in jobs], cfg, 100,
                         policy="omfs", backend="python")
    for backend in BACKENDS:
        jx = engine.simulate(users, jobs, _with_backend(cfg, backend), 100,
                             policy="omfs", backend="jax")
        assert py.signature() == jx.signature(), backend
        assert (py.busy_series() == jx.busy_series()).all(), backend
        assert py.summary()["spills"] == jx.summary()["spills"], backend


@pytest.mark.parametrize("policy", POLICY_NAMES)
def test_t2_lattice_degenerates_to_two_column(policy):
    """The T=2 lattice with default (1, 1) coefficients IS the legacy
    two-column model: bit-identical schedules for every registered policy
    on both kernel backends, and the legacy accessors are exact views over
    the lattice columns."""
    users, jobs = _workload(seed=7)
    cfg = SchedulerConfig(cpu_total=32, quantum=4, cr_overhead=1,
                          cr_tiers=_lattice(2, 2_000, 1, 1))
    py = engine.simulate(users, [j.clone() for j in jobs], cfg, 100,
                         policy=policy, backend="python")
    for backend in BACKENDS:
        jx = engine.simulate(users, jobs, _with_backend(cfg, backend), 100,
                             policy=policy, backend="jax")
        assert py.signature() == jx.signature(), backend
        assert (py.busy_series() == jx.busy_series()).all(), backend
        assert py.summary()["spills"] == jx.summary()["spills"], backend
    t = jx.table
    np.testing.assert_array_equal(np.asarray(t.cost_save),
                                  np.asarray(t.cost_save_lat[:, 0]))
    np.testing.assert_array_equal(np.asarray(t.cost_save2),
                                  np.asarray(t.cost_save_lat[:, -1]))
    np.testing.assert_array_equal(np.asarray(t.cost_restore),
                                  np.asarray(t.cost_restore_lat[:, 0]))
    np.testing.assert_array_equal(np.asarray(t.cost_restore2),
                                  np.asarray(t.cost_restore_lat[:, -1]))


def test_recurrent_saves_cheaper_and_bit_equal():
    """The thrashing ping-pong is the recurrent-save workload: the same
    victims bounce through evict -> restore -> evict, so every save after
    the first is priced at the measured delta — strictly cheaper than the
    delta-free twin, and bit-identical across all three backends."""
    users, jobs = thrashing_scenario(64, quantum=5)
    delta_cfg = SchedulerConfig(cpu_total=64, quantum=5, cr_overhead=1,
                                cr_tiers=_lattice(3, 64 << 10, DELTA, 256))
    flat_cfg = dataclasses.replace(delta_cfg,
                                   cr_tiers=_lattice(3, 64 << 10, 1, 1))
    py = engine.simulate(users, [j.clone() for j in jobs], delta_cfg, 400,
                         policy="omfs", backend="python")
    tab = py.sim.job_table()
    assert max(j.n_checkpoints for j in tab) >= 2, \
        "no job saved twice — scenario too tame to price recurrence"
    flat = engine.simulate(users, [j.clone() for j in jobs], flat_cfg, 400,
                           policy="omfs", backend="python")
    assert sum(j.overhead for j in tab) < \
        sum(j.overhead for j in flat.sim.job_table())
    for backend in BACKENDS:
        jx = engine.simulate(users, jobs, _with_backend(delta_cfg, backend),
                             400, policy="omfs", backend="jax")
        assert py.signature() == jx.signature(), backend
        assert int(np.asarray(jx.table.overhead).sum()) == \
            sum(j.overhead for j in tab), backend


def test_t4_hierarchy_acceptance():
    """ISSUE acceptance: a 4-deep HBM/DRAM/NVMe/object-store hierarchy runs
    on the JAX backend bit-identical to the Python `TieredCRCostModel` —
    schedules, spill counts, AND lifecycle events — on both `lax` and
    `pallas_interpret`, with recurrent saves measurably cheaper."""
    users, jobs = thrashing_scenario(64, quantum=5,
                                     state_gibs=(128, 64, 32, 16))
    hier = TieredCRCostModel(
        tiers=(CRCostModel(save_mib_per_tick=131072,       # HBM
                           restore_mib_per_tick=262144,
                           delta_num=DELTA, delta_den=256),
               CRCostModel(save_mib_per_tick=16384,        # DRAM
                           restore_mib_per_tick=32768,
                           delta_num=DELTA, delta_den=256),
               CRCostModel(save_mib_per_tick=2048,         # NVMe
                           restore_mib_per_tick=4096, save_base=1,
                           delta_num=DELTA, delta_den=256),
               CRCostModel(save_mib_per_tick=256,          # object store
                           restore_mib_per_tick=512,
                           save_base=2, restore_base=2,
                           delta_num=DELTA, delta_den=256)),
        capacity_mib=(16 << 10, 64 << 10, 160 << 10, UNBOUNDED))
    cfg = SchedulerConfig(cpu_total=64, quantum=5, cr_overhead=1,
                          cr_tiers=hier)
    py = engine.simulate(users, [j.clone() for j in jobs], cfg, 400,
                         policy="omfs", backend="python", record_events=True)
    tab = py.sim.job_table()
    assert max(j.n_checkpoints for j in tab) >= 2
    assert py.summary()["spills"] > 0, "the deep tiers never engaged"
    for backend in BACKENDS:
        jx = engine.simulate(users, jobs, _with_backend(cfg, backend), 400,
                             policy="omfs", backend="jax",
                             record_events=True)
        assert py.signature() == jx.signature(), backend
        assert (py.busy_series() == jx.busy_series()).all(), backend
        assert py.summary()["spills"] == jx.summary()["spills"], backend
        assert canonical_sort(py.events) == canonical_sort(jx.events), backend
        assert int(np.asarray(jx.table.n_ckpt).max()) >= 2
        assert int(np.asarray(jx.table.overhead).sum()) == \
            sum(j.overhead for j in tab), backend
    # pricing recurrence at the measured delta strictly reduces total C/R
    flat_tiers = TieredCRCostModel(
        tiers=tuple(dataclasses.replace(m, delta_num=1, delta_den=1)
                    for m in hier.tiers),
        capacity_mib=hier.capacity_mib)
    flat = engine.simulate(users, [j.clone() for j in jobs],
                           dataclasses.replace(cfg, cr_tiers=flat_tiers), 400,
                           policy="omfs", backend="python")
    assert sum(j.overhead for j in tab) < \
        sum(j.overhead for j in flat.sim.job_table())


# ---------------------------------------------------------------------------
# the unified calibrate(tiers=...) entry + deprecation shims
# ---------------------------------------------------------------------------


def test_service_calibrate_unified_and_shim(tmp_path, monkeypatch):
    from repro.checkpoint.manager import ManagerConfig
    from repro.checkpoint.service import CheckpointService

    svc = CheckpointService(ManagerConfig(root=tmp_path,
                                          mem_capacity_bytes=2 << 30,
                                          use_delta=False,
                                          async_durable=False))
    try:
        mem, disk = svc.manager.mem.stats, svc.manager.disk.stats
        mem.bytes_written, mem.save_seconds = 8000 << 20, 1.0
        mem.bytes_read, mem.restore_seconds = 8000 << 20, 0.5
        disk.bytes_written, disk.save_seconds = 400 << 20, 1.0
        disk.bytes_read, disk.restore_seconds = 400 << 20, 1.0
        # tiers=None: the flat model, delta-aware
        flat = svc.calibrate(tick_seconds=0.1, delta_ratio=0.71)
        assert isinstance(flat, CRCostModel)
        assert (flat.delta_num, flat.delta_den) == (round(0.71 * 256), 256)
        # tiers=(...): the lattice, same entry
        lat = svc.calibrate(tick_seconds=0.1, tiers=("mem", "disk"))
        assert isinstance(lat, TieredCRCostModel)
        assert lat.capacity_mib == (2 << 10, UNBOUNDED)
        # the shim warns and is pure delegation
        calls = []
        orig = CheckpointService.calibrate
        monkeypatch.setattr(
            CheckpointService, "calibrate",
            lambda self, *a, **kw: calls.append((a, kw)) or
            orig(self, *a, **kw))
        with pytest.warns(DeprecationWarning, match="calibrate_tiered"):
            m = svc.calibrate_tiered(tick_seconds=0.1)
        assert calls and calls[0][1]["tiers"] == ("mem", "disk")
        assert m == lat
    finally:
        svc.close()


def test_executor_calibrate_tiered_delegates(monkeypatch):
    from repro.cluster.executor import ClusterExecutor

    ex = ClusterExecutor.__new__(ClusterExecutor)   # the shim needs no state
    seen = {}
    monkeypatch.setattr(
        ClusterExecutor, "calibrate",
        lambda self, tick_seconds=None, **kw:
        seen.update(tick_seconds=tick_seconds, **kw) or "model")
    with pytest.warns(DeprecationWarning, match="calibrate_tiered"):
        out = ex.calibrate_tiered(0.2, compress_ratio=0.5)
    assert out == "model"
    assert seen["tiers"] == ("mem", "disk")
    assert seen["tick_seconds"] == 0.2 and seen["compress_ratio"] == 0.5
