"""Metrics registry: registry content, Prometheus text exposition,
cross-backend parity of the scrape, and the serve payloads."""
import json

import pytest

from repro.core import engine
from repro.core.types import SchedulerConfig
from repro.core.workload import WorkloadSpec, make_jobs, make_users
from repro.obs import MetricsRegistry, registry_from_result


def _workload(seed=7, horizon=120):
    spec = WorkloadSpec(n_users=3, horizon=horizon, cpu_total=32, seed=seed,
                        arrival_rate=0.12, mean_work=30,
                        class_mix=(0.15, 0.35, 0.5))
    users = make_users(spec)
    jobs = make_jobs(spec, users)[:30]
    cfg = SchedulerConfig(cpu_total=32, quantum=4, cr_overhead=2)
    return users, jobs, cfg


def _sim(backend, seed=7, policy="omfs", horizon=120):
    users, jobs, cfg = _workload(seed, horizon)
    res = engine.simulate(users, jobs, cfg, horizon, policy=policy,
                          backend=backend, record_events=True)
    return users, res


# ---------------------------------------------------------------------------
# registry primitives
# ---------------------------------------------------------------------------


def test_registry_counter_gauge_histogram_exposition():
    reg = MetricsRegistry()
    reg.counter("jobs_total", "jobs").inc(3, {"policy": "omfs"})
    reg.gauge("load", "load").set(0.5)
    h = reg.histogram("wait", "wait ticks", buckets=(1.0, 5.0))
    h.observe(0)
    h.observe(3)
    h.observe(99)
    text = reg.to_prometheus()
    assert '# TYPE jobs_total counter' in text
    assert 'jobs_total{policy="omfs"} 3' in text
    assert "load 0.5" in text
    assert 'wait_bucket{le="1"} 1' in text
    assert 'wait_bucket{le="5"} 2' in text
    assert 'wait_bucket{le="+Inf"} 3' in text
    assert "wait_sum 102" in text
    assert "wait_count 3" in text
    # JSON snapshot carries the same numbers
    js = reg.to_json()
    assert js["wait"]["series"]["{}"]["count"] == 3
    assert js["jobs_total"]["series"]['{policy="omfs"}'] == 3


def test_registry_kind_collision_raises():
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(ValueError):
        reg.gauge("x")


# ---------------------------------------------------------------------------
# registry_from_result
# ---------------------------------------------------------------------------


def test_registry_from_result_content():
    users, res = _sim("python")
    reg = registry_from_result(res, users=users)
    for name in ("sched_events_total", "sched_events_dropped_total",
                 "sched_wait_ticks", "sched_evictions_per_job",
                 "sched_ckpt_saves_total", "sched_spills_total",
                 "sched_user_share", "sched_user_cpu_ticks_total",
                 "sched_user_entitlement", "sched_utilization"):
        assert name in reg, name
    # event counters match the counts matrix exactly
    from repro.obs import EVENT_TYPE_NAMES
    per_type = res.event_counts.sum(axis=0)
    total = reg["sched_events_total"]
    for name, n in zip(EVENT_TYPE_NAMES, per_type):
        assert total.samples[(("type", name),)] == int(n)
    # realized shares are fractions of capacity; entitlements sum <= 1
    shares = reg["sched_user_share"].samples
    assert shares and all(0.0 <= v <= 1.0 for v in shares.values())
    ents = reg["sched_user_entitlement"].samples
    assert sum(ents.values()) <= 1.0 + 1e-9
    util = reg["sched_utilization"].samples[()]
    assert util == pytest.approx(res.utilization())


def test_registry_cross_backend_scrape_identical():
    """The Prometheus text is byte-identical across backends when the
    user list is supplied (labels resolve to the same names)."""
    users, jobs, cfg = _workload()
    py = engine.simulate(users, jobs, cfg, 120, policy="omfs",
                         backend="python", record_events=True)
    jx = engine.simulate(users, jobs, cfg, 120, policy="omfs",
                         backend="jax", record_events=True)
    txt_py = registry_from_result(py, users=users).to_prometheus()
    txt_jx = registry_from_result(jx, users=users).to_prometheus()
    assert txt_py == txt_jx


def test_registry_requires_events():
    users, res = _sim("python")
    res.events = None
    with pytest.raises(ValueError):
        registry_from_result(res)


def test_registry_wait_histogram_matches_first_start():
    users, res = _sim("python")
    reg = registry_from_result(res, users=users)
    jobs = res.sim.state.jobs.values()
    waits = sorted(j.first_start - j.submit_time
                   for j in jobs if j.first_start >= 0)
    _, total, n = reg["sched_wait_ticks"].hist[()]
    assert n == len(waits)
    assert total == sum(waits)


def test_registry_json_snapshot_round_trips():
    users, res = _sim("python")
    js = registry_from_result(res, users=users).to_json()
    assert json.loads(json.dumps(js)) == js


# ---------------------------------------------------------------------------
# serve payloads (no socket)
# ---------------------------------------------------------------------------


def test_serve_sched_status_payloads():
    import argparse

    from repro.launch import serve

    ns = argparse.Namespace(tenants=3, horizon=80, chips=32, seed=0,
                            arrival_rate=0.1, quantum=6, policy="omfs",
                            backend="python")
    payloads = serve.sched_status_payloads(ns)
    assert set(payloads) == {"/metrics", "/trace.json", "/healthz"}
    ctype, metrics = payloads["/metrics"]
    assert ctype.startswith("text/plain")
    assert b"sched_events_total" in metrics
    _, trace = payloads["/trace.json"]
    td = json.loads(trace)
    assert td["traceEvents"]
    _, health = payloads["/healthz"]
    hd = json.loads(health)
    assert hd["status"] == "ok"
    assert hd["events"] > 0 and hd["events_dropped"] == 0
    assert hd["summary"]["jobs_done"] >= 0
