"""Data pipeline: determinism, resumability, shape/dtype contracts."""
import numpy as np

from repro.data.pipeline import DataConfig, SyntheticLM


def test_batch_at_is_pure_function_of_cursor():
    cfg = DataConfig(vocab=512, seq_len=64, global_batch=4, seed=3)
    a = SyntheticLM(cfg)
    b = SyntheticLM(cfg)
    for cur in (0, 5, 1000):
        ba, bb = a.batch_at(cur), b.batch_at(cur)
        assert (ba["tokens"] == bb["tokens"]).all()
        assert (ba["labels"] == bb["labels"]).all()


def test_labels_are_next_tokens():
    cfg = DataConfig(vocab=512, seq_len=64, global_batch=2, seed=0)
    b = SyntheticLM(cfg).batch_at(0)
    assert (b["labels"][:, :-1] == b["tokens"][:, 1:]).all()


def test_resume_mid_stream_is_identical():
    cfg = DataConfig(vocab=128, seq_len=32, global_batch=2, seed=1)
    ds = SyntheticLM(cfg)
    full = [b["tokens"] for (_, b), _ in zip(ds.iterator(0), range(6))]
    resumed = [b["tokens"] for (_, b), _ in zip(ds.iterator(3), range(3))]
    for x, y in zip(full[3:], resumed):
        assert (x == y).all()


def test_different_cursors_differ_and_tokens_in_range():
    cfg = DataConfig(vocab=100, seq_len=128, global_batch=2, seed=1)
    ds = SyntheticLM(cfg)
    b0, b1 = ds.batch_at(0), ds.batch_at(1)
    assert not (b0["tokens"] == b1["tokens"]).all()
    for b in (b0, b1):
        assert b["tokens"].dtype == np.int32
        assert b["tokens"].min() >= 0 and b["tokens"].max() < 100


def test_stream_has_learnable_structure():
    """Motif reuse should make adjacent-token mutual information > noise:
    check that the bigram distribution is far from uniform."""
    cfg = DataConfig(vocab=64, seq_len=256, global_batch=8, seed=0,
                     n_patterns=16, pattern_len=8)
    b = SyntheticLM(cfg).batch_at(0)
    toks = b["tokens"].reshape(-1)
    pairs = toks[:-1] * 64 + toks[1:]
    counts = np.bincount(pairs, minlength=64 * 64).astype(np.float64)
    p = counts / counts.sum()
    entropy = -(p[p > 0] * np.log(p[p > 0])).sum()
    assert entropy < 0.8 * np.log(64 * 64)   # far from uniform bigrams
