"""The C/R cost model layer: integer determinism, scalar==vectorized,
calibration, goodput accounting, and the thrashing scenario where the cost
materially changes the schedule."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import engine
from repro.core.crcost import (
    DEFAULT_CAP_TICKS,
    MAX_STATE_MIB,
    MIB,
    CRCostModel,
    state_mib_of,
)
from repro.core.metrics import compute_metrics
from repro.core.types import Job, JobClass, SchedulerConfig, User
from repro.core.workload import thrashing_scenario


# ---------------------------------------------------------------------------
# model arithmetic
# ---------------------------------------------------------------------------


def test_default_model_is_free():
    m = CRCostModel()
    assert m.is_free
    for mib in (0, 1, 17, 4096, MAX_STATE_MIB):
        assert m.save_cost(mib) == 0
        assert m.restore_cost(mib) == 0


def test_costs_are_integer_piecewise_linear():
    m = CRCostModel(save_mib_per_tick=1024, restore_mib_per_tick=2048,
                    save_base=2, restore_base=1)
    assert m.save_cost(0) == 2                    # base only
    assert m.save_cost(1) == 3                    # ceil(1/1024) = 1
    assert m.save_cost(1024) == 3
    assert m.save_cost(1025) == 4
    assert m.restore_cost(4096) == 1 + 2
    # monotone in size
    costs = [m.save_cost(x) for x in range(0, 10_000, 97)]
    assert costs == sorted(costs)


def test_cost_saturates_at_cap():
    m = CRCostModel(save_mib_per_tick=1, cap_ticks=50)
    assert m.save_cost(10) == 10
    assert m.save_cost(1_000_000) == 50


def test_compression_ratio_is_rational():
    half = CRCostModel(save_mib_per_tick=1, compress_num=128, compress_den=256)
    full = CRCostModel(save_mib_per_tick=1)
    assert half.save_cost(1000) == 500
    assert full.save_cost(1000) == 1000


def test_state_mib_of_rounds_up_and_clamps():
    assert state_mib_of(0) == 0
    assert state_mib_of(1) == 1
    assert state_mib_of(MIB) == 1
    assert state_mib_of(MIB + 1) == 2
    assert state_mib_of(1 << 60) == MAX_STATE_MIB


@settings(max_examples=20, deadline=None)
@given(bw_s=st.integers(1, 8192), bw_r=st.integers(1, 8192),
       base_s=st.integers(0, 5), base_r=st.integers(0, 5),
       num=st.integers(1, 512))
def test_scalar_matches_vectorized(bw_s, bw_r, base_s, base_r, num):
    """The same expression must evaluate identically on Python ints and on
    jnp.int32 arrays — the property that keeps backends bit-identical."""
    m = CRCostModel(save_mib_per_tick=bw_s, restore_mib_per_tick=bw_r,
                    save_base=base_s, restore_base=base_r,
                    compress_num=num, compress_den=256)
    sizes = [0, 1, 2, 100, 1023, 1024, 1025, 65536, MAX_STATE_MIB]
    vec = jnp.asarray(sizes, jnp.int32)
    assert [int(x) for x in m.save_cost(vec)] == \
        [m.save_cost(s) for s in sizes]
    assert [int(x) for x in m.restore_cost(vec)] == \
        [m.restore_cost(s) for s in sizes]


def test_model_is_hashable_config_key():
    a = CRCostModel(save_mib_per_tick=8)
    b = CRCostModel(save_mib_per_tick=8)
    assert hash(a) == hash(b) and a == b
    cfg = SchedulerConfig(cr_cost=a)
    hash(cfg)   # SchedulerConfig stays a valid jit static arg / cache key


# ---------------------------------------------------------------------------
# calibration
# ---------------------------------------------------------------------------


class _FakeStats:
    bytes_written = 100 * MIB
    bytes_read = 200 * MIB
    save_seconds = 1.0
    restore_seconds = 1.0


def test_from_stats_converts_bandwidth_to_mib_per_tick():
    m = CRCostModel.from_stats(_FakeStats(), tick_seconds=0.5)
    # 100 MiB/s * 0.5 s/tick = 50 MiB/tick, on the /256 rational grid
    assert m.save_mib_per_tick / m.save_tick_den == 50
    assert m.restore_mib_per_tick / m.restore_tick_den == 100
    assert m.compress_num == 256 and m.compress_den == 256
    assert m.save_cost(100) == 2          # ceil(100/50)


def test_from_stats_restore_falls_back_to_save_bandwidth():
    class WriteOnly:
        bytes_written = 100 * MIB
        bytes_read = 0
        save_seconds = 1.0
        restore_seconds = 0.0

    m = CRCostModel.from_stats(WriteOnly(), tick_seconds=1.0)
    assert m.restore_mib_per_tick == m.save_mib_per_tick
    assert m.save_mib_per_tick / m.save_tick_den == 100


def test_from_measured_slow_tier_not_floored_to_one_mib():
    """A tier slower than 1 MiB/tick must charge its REAL cost: the /256
    rational grid prices 0.25 MiB/tick as 4 ticks/MiB instead of silently
    flooring the bandwidth to 1 MiB/tick."""
    m = CRCostModel.from_measured(save_bytes_per_s=0.25 * MIB,
                                  restore_bytes_per_s=0.25 * MIB,
                                  tick_seconds=1.0)
    assert m.save_mib_per_tick == 64 and m.save_tick_den == 256
    assert m.save_cost(100) == 400        # 100 MiB / 0.25 MiB/tick


def test_from_measured_min_representable_bandwidth():
    m = CRCostModel.from_measured(save_bytes_per_s=10.0,
                                  restore_bytes_per_s=10.0,
                                  tick_seconds=0.001)
    assert m.save_mib_per_tick == 1       # floor of the grid: 1/256 MiB/tick
    assert m.save_tick_den == 256
    assert m.save_cost(100) == 25600


@settings(max_examples=20, deadline=None)
@given(mib_per_s=st.floats(1.0, 4000.0), tick_s=st.sampled_from([0.05, 0.1, 0.5, 1.0]),
       image_mib=st.integers(1, 1 << 18))
def test_from_stats_durable_tier_round_trip(mib_per_s, tick_s, image_mib):
    """Measured durable-tier TierStats -> model -> predicted ticks stays on
    the /256 rational grid: the bandwidth quantizes round-to-nearest
    (within half a grid step of the true rate) and the prediction is
    EXACTLY the integer ceil on that grid, saturated at ``cap_ticks``.
    This is the disk-tier calibration the tiered placement model feeds on."""
    from repro.checkpoint.tiers import TierStats

    stats = TierStats(saves=3, restores=2,
                      bytes_written=int(mib_per_s * 4) * MIB,
                      bytes_read=int(mib_per_s * 4) * MIB,
                      save_seconds=4.0, restore_seconds=4.0)
    if stats.bytes_written == 0:
        return
    m = CRCostModel.from_stats(stats, tick_seconds=tick_s)
    true_mib_per_tick = stats.bytes_written / 4.0 * tick_s / MIB
    predicted = m.save_cost(image_mib)
    # round-to-nearest quantization: within half a /256 grid step
    q = m.save_mib_per_tick / m.save_tick_den
    assert abs(q - true_mib_per_tick) <= 1 / 512 + 1e-9
    # the prediction is the exact integer ceil on the quantized grid,
    # saturated at the cap — nothing cheaper, nothing float-drifted
    assert predicted == min(-((-image_mib * 256) // m.save_mib_per_tick),
                            m.cap_ticks)
    # and never materially cheaper than the true transfer time (half a
    # grid step of bandwidth is the worst-case rounding in its favor)
    floor_bound = image_mib / (true_mib_per_tick + 1 / 512)
    assert predicted >= min(floor_bound, m.cap_ticks) - 1


def test_ticks_from_seconds():
    assert CRCostModel.ticks_from_seconds(0.0, 0.1) == 0
    assert CRCostModel.ticks_from_seconds(0.05, 0.1) == 1
    assert CRCostModel.ticks_from_seconds(0.25, 0.1) == 3


# ---------------------------------------------------------------------------
# scheduling semantics
# ---------------------------------------------------------------------------


def _eviction_setup(model, state_gib=4):
    """B holds the machine with a big-state job; A's entitled claim evicts
    it.  Returns the final python EngineResult and the victim job id."""
    users = [User("A", 50.0), User("B", 50.0)]
    victim = Job(user="B", cpus=24, work=500,
                 job_class=JobClass.CHECKPOINTABLE, submit_time=0,
                 state_bytes=state_gib << 30)
    claim = Job(user="A", cpus=16, work=5,
                job_class=JobClass.CHECKPOINTABLE, submit_time=10)
    cfg = SchedulerConfig(cpu_total=32, quantum=5, cr_cost=model)
    res = engine.simulate(users, [victim, claim], cfg, 200,
                          policy="omfs", backend="python")
    return res, victim.id


def test_save_charged_at_eviction_restore_at_restart():
    """One eviction ping-pong, fully deterministic: B's 4 GiB job is
    checkpointed exactly once (A's claim) and restarts exactly once after
    A's 5-tick job finishes — so its overhead is one save + one restore."""
    gib = 4
    model = CRCostModel(save_mib_per_tick=1024, restore_mib_per_tick=2048,
                        save_base=1, restore_base=1)
    res, vid = _eviction_setup(model, state_gib=gib)
    v = res.sim.state.jobs[vid]
    mib = gib << 10                      # 4096 MiB
    assert model.save_cost(mib) == 5     # 1 + 4096/1024
    assert model.restore_cost(mib) == 3  # 1 + 4096/2048
    assert v.n_checkpoints == 1
    assert v.overhead == 5 + 3
    assert v.state.name == "RUNNING"     # restarted and still finishing


def test_free_model_preserves_legacy_cr_overhead_semantics():
    """cr_overhead alone must behave exactly as before the cost model:
    a flat charge per checkpoint, nothing at restart."""
    res, vid = _eviction_setup(CRCostModel())
    v_free = res.sim.state.jobs[vid]
    assert v_free.overhead == 0

    users = [User("A", 50.0), User("B", 50.0)]
    victim = Job(user="B", cpus=24, work=500,
                 job_class=JobClass.CHECKPOINTABLE, submit_time=0)
    claim = Job(user="A", cpus=16, work=5,
                job_class=JobClass.CHECKPOINTABLE, submit_time=10)
    cfg = SchedulerConfig(cpu_total=32, quantum=5, cr_overhead=7)
    res = engine.simulate(users, [victim, claim], cfg, 200,
                          policy="omfs", backend="python")
    v = res.sim.state.jobs[victim.id]
    assert v.n_checkpoints >= 1
    assert v.overhead == 7 * v.n_checkpoints


def test_thrashing_scenario_cost_changes_schedule_and_goodput():
    """The point of the whole layer: with a slow tier the SCHEDULE (not
    just the metrics) diverges, goodput drops, wasted work appears, while
    the free model reproduces the legacy schedule bit-for-bit."""
    users, jobs = thrashing_scenario(64, quantum=5)
    free = SchedulerConfig(cpu_total=64, quantum=5)
    slow = SchedulerConfig(
        cpu_total=64, quantum=5,
        cr_cost=CRCostModel(save_mib_per_tick=2048, restore_mib_per_tick=4096))
    r_free = engine.simulate(users, [j.clone() for j in jobs], free, 400,
                             policy="omfs", backend="python")
    r_slow = engine.simulate(users, [j.clone() for j in jobs], slow, 400,
                             policy="omfs", backend="python")
    assert r_free.signature() != r_slow.signature()
    m_free = compute_metrics(r_free.sim)
    m_slow = compute_metrics(r_slow.sim)
    assert m_slow.goodput < m_free.goodput
    assert m_slow.wasted_work_frac > m_free.wasted_work_frac
    assert m_slow.cr_overhead_units > 0
    # goodput never exceeds utilization; with nothing wasted it only trails
    # by the final tick's not-yet-accrued progress
    assert m_free.wasted_work_frac == 0.0
    assert m_free.goodput <= m_free.utilization
    assert m_free.goodput == pytest.approx(m_free.utilization, abs=5e-3)
    assert m_slow.goodput < m_slow.utilization - 0.02


def test_workload_jobs_carry_state_sizes():
    from repro.core.workload import WorkloadSpec, make_jobs, make_users

    spec = WorkloadSpec(n_users=3, horizon=200, seed=5)
    users = make_users(spec)
    jobs = make_jobs(spec, users)
    assert jobs and all(j.state_bytes >= MIB for j in jobs)
    sizes = {j.state_bytes for j in jobs}
    assert len(sizes) > 1, "state sizes must be heterogeneous"
