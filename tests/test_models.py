"""Per-architecture smoke tests (reduced same-family configs, real CPU run):
one train step (loss + grads finite), prefill + decode consistency."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models.model import build_model, count_params


def _batch(cfg, key, b=2, s=12):
    batch = {
        "tokens": jax.random.randint(key, (b, s), 0, cfg.vocab),
        "labels": jax.random.randint(key, (b, s), 0, cfg.vocab),
    }
    if cfg.family == "vlm":
        batch["frontend"] = jax.random.normal(
            key, (b, cfg.vision.n_patches, cfg.vision.vision_dim), jnp.float32)
    if cfg.family == "audio":
        batch["frontend"] = jax.random.normal(
            key, (b, cfg.audio.n_audio_ctx, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg, q_chunk=8, kv_chunk=8)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = _batch(cfg, key)
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert jnp.isfinite(loss), arch
    assert loss.shape == ()
    grads = jax.jit(jax.grad(lambda p: model.loss(p, batch)[0]))(params)
    gnorm = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree.leaves(grads))
    assert jnp.isfinite(gnorm), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg, q_chunk=8, kv_chunk=8)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    b, s = 2, 12
    batch = _batch(cfg, key, b, s)
    cache = model.init_cache(b, s + 4)
    cache, logits = jax.jit(model.prefill)(params, batch, cache)
    assert logits.shape == (b, 1, cfg.vocab)
    assert jnp.isfinite(logits).all(), arch
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    cache, logits2 = jax.jit(model.decode_step)(params, cache, tok)
    assert logits2.shape == (b, 1, cfg.vocab)
    assert jnp.isfinite(logits2).all(), arch


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "minicpm3-4b", "xlstm-350m",
                                  "hymba-1.5b", "whisper-base"])
def test_prefill_decode_matches_teacher_forcing(arch):
    """Decoding token t with a cache must equal position t of a full
    forward pass — serve path == train path.  fp32 compute so the check
    exercises logic, not bf16 reduction noise."""
    cfg = get_smoke_config(arch).replace(compute_dtype="float32")
    model = build_model(cfg, q_chunk=8, kv_chunk=8)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    b, s = 2, 10
    batch = _batch(cfg, key, b, s)

    # full prefill over all s tokens gives last logits
    cache = model.init_cache(b, s + 2, dtype=jnp.float32)
    cache_full, logits_full = jax.jit(model.prefill)(params, batch, cache)

    # prefill s-1 tokens then decode token s-1
    batch_prefix = dict(batch, tokens=batch["tokens"][:, : s - 1])
    cache = model.init_cache(b, s + 2, dtype=jnp.float32)
    cache_p, _ = jax.jit(model.prefill)(params, batch_prefix, cache)
    cache_p, logits_dec = jax.jit(model.decode_step)(
        params, cache_p, batch["tokens"][:, s - 1 :])
    err = float(jnp.abs(logits_full - logits_dec).max())
    assert err < 1e-4, f"{arch}: prefill/decode mismatch {err}"


def test_full_configs_match_assignment():
    """The full-size configs carry the exact assigned hyperparameters."""
    spec = {
        "deepseek-moe-16b": (28, 2048, 16, 16, 102400),
        "dbrx-132b": (40, 6144, 48, 8, 100352),
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 128256),
        "hymba-1.5b": (32, 1600, 25, 5, 32001),
        "glm4-9b": (40, 4096, 32, 2, 151552),
        "minicpm3-4b": (62, 2560, 40, 40, 73448),
        "internlm2-1.8b": (24, 2048, 16, 8, 92544),
        "mistral-nemo-12b": (40, 5120, 32, 8, 131072),
        "xlstm-350m": (24, 1024, 4, 4, 50304),
        "whisper-base": (6, 512, 8, 8, 51865),
    }
    for arch, (nl, d, h, kv, v) in spec.items():
        cfg = get_config(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.vocab) \
            == (nl, d, h, kv, v), arch


def test_param_counts_are_in_the_right_ballpark():
    """Total params should be within ~35% of the nameplate size."""
    expect = {
        "deepseek-moe-16b": 16.4e9,
        "dbrx-132b": 132e9,
        "llama-3.2-vision-11b": 10.6e9,
        "hymba-1.5b": 1.5e9,
        "glm4-9b": 9.4e9,
        "minicpm3-4b": 4.0e9,
        "internlm2-1.8b": 1.9e9,
        "mistral-nemo-12b": 12.2e9,
        "xlstm-350m": 0.35e9,
        "whisper-base": 0.072e9,
    }
    for arch, n in expect.items():
        got = count_params(get_config(arch))["total"]
        ratio = got / n
        assert 0.6 < ratio < 1.5, f"{arch}: {got/1e9:.2f}B vs nameplate {n/1e9:.1f}B"
