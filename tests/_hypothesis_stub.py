"""Minimal deterministic stand-in for `hypothesis` when it isn't installed.

The real dependency is declared in pyproject.toml (``pip install -e
.[test]``); this stub only exists so the suite still *collects and runs*
in environments where installing is impossible.  It covers exactly the
surface this repo's tests use — ``given`` (keyword strategies only),
``settings(max_examples=..., deadline=...)``, and the ``integers`` /
``booleans`` / ``sampled_from`` / ``floats`` / ``tuples`` / ``lists``
strategies — drawing a fixed, seeded set of examples per test (no
shrinking, no database).  `tests/conftest.py` installs it into
``sys.modules`` only when ``import hypothesis`` fails.
"""
from __future__ import annotations

import functools
import inspect
import random
from types import ModuleType


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example_from(self, rng: random.Random):
        return self._draw(rng)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda r: r.randint(min_value, max_value))


def booleans() -> _Strategy:
    return _Strategy(lambda r: bool(r.getrandbits(1)))


def sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(lambda r: elements[r.randrange(len(elements))])


def floats(min_value: float = 0.0, max_value: float = 1.0, **_kw) -> _Strategy:
    return _Strategy(lambda r: r.uniform(min_value, max_value))


def tuples(*strategies) -> _Strategy:
    return _Strategy(lambda r: tuple(s.example_from(r) for s in strategies))


def lists(elements: _Strategy, min_size: int = 0, max_size: int = 10,
          **_kw) -> _Strategy:
    return _Strategy(
        lambda r: [elements.example_from(r)
                   for _ in range(r.randint(min_size, max_size))])


class _Unsatisfied(Exception):
    pass


def assume(condition) -> bool:
    if not condition:
        raise _Unsatisfied
    return True


_DEFAULT_MAX_EXAMPLES = 10


def given(*strategy_args, **strategy_kwargs):
    def decorate(fn):
        # hypothesis semantics: positional strategies fill the RIGHTMOST
        # parameters of the test function
        sig = inspect.signature(fn)
        names = list(sig.parameters)
        strategies = dict(strategy_kwargs)
        if strategy_args:
            for name, strat in zip(names[len(names) - len(strategy_args):],
                                   strategy_args):
                strategies[name] = strat

        @functools.wraps(fn)
        def wrapper(*a, **kw):
            n = getattr(wrapper, "_stub_max_examples", _DEFAULT_MAX_EXAMPLES)
            seed_base = hash(fn.__qualname__) & 0xFFFF
            for i in range(n):
                rng = random.Random(seed_base * 1009 + i)
                drawn = {k: s.example_from(rng)
                         for k, s in strategies.items()}
                try:
                    fn(*a, **kw, **drawn)
                except _Unsatisfied:
                    continue

        # hide the drawn parameters from pytest's fixture resolution
        params = [p for name, p in sig.parameters.items()
                  if name not in strategies]
        wrapper.__signature__ = inspect.Signature(params)
        if hasattr(wrapper, "__wrapped__"):
            del wrapper.__wrapped__
        return wrapper

    return decorate


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, **_ignored):
    def decorate(fn):
        fn._stub_max_examples = max_examples
        return fn
    return decorate


class HealthCheck:
    too_slow = "too_slow"
    filter_too_much = "filter_too_much"
    data_too_large = "data_too_large"


def as_modules():
    """Build (hypothesis, hypothesis.strategies) module objects."""
    st = ModuleType("hypothesis.strategies")
    for name in ("integers", "booleans", "sampled_from", "floats", "tuples",
                 "lists"):
        setattr(st, name, globals()[name])
    hyp = ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.assume = assume
    hyp.HealthCheck = HealthCheck
    hyp.strategies = st
    hyp.__stub__ = True
    return hyp, st
