"""Roofline machinery: the HLO collective-byte parser and term math."""
import pytest

from repro.roofline.analysis import _bytes_of_type, collective_bytes

HLO_SAMPLE = """
HloModule jit_step

fused_computation {
  p0 = bf16[128,256]{1,0} parameter(0)
  ROOT add = bf16[128,256]{1,0} add(p0, p0)
}

ENTRY main {
  %p = bf16[128,256]{1,0} parameter(0)
  %ag = bf16[2048,256]{1,0} all-gather(%p), replica_groups={{0,1}}, dimensions={0}
  %ar = f32[1024]{0} all-reduce(%x), to_apply=%add
  %ars = f32[512]{0} reduce-scatter(%y), dimensions={0}
  %a2a = (bf16[64,32]{1,0}, bf16[64,32]{1,0}) all-to-all(%q, %r)
  %cp = u8[16]{0} collective-permute(%z), source_target_pairs={{0,1}}
  %ag2 = bf16[99]{0} all-gather-start(%w), dimensions={0}
  %agd = bf16[99]{0} all-gather-done(%ag2)
  ROOT %t = tuple()
}
"""


def test_bytes_of_type():
    assert _bytes_of_type("bf16[128,256]{1,0}") == 128 * 256 * 2
    assert _bytes_of_type("f32[1024]{0}") == 4096
    assert _bytes_of_type("(bf16[2,2]{1,0}, f32[3]{0})") == 8 + 12
    assert _bytes_of_type("pred[]") == 1


def test_collective_bytes_parses_and_weights():
    got = collective_bytes(HLO_SAMPLE)
    assert got["all-gather"] == 2048 * 256 * 2 + 99 * 2  # -start counted, -done not
    assert got["all-reduce"] == 1024 * 4 * 2             # x2 ring RS+AG
    assert got["reduce-scatter"] == 512 * 4
    assert got["all-to-all"] == 64 * 32 * 2 * 2          # tuple elements summed
    assert got["collective-permute"] == 16


def test_roofline_terms_and_bottleneck():
    from repro.roofline.analysis import Roofline, analyze

    class FakeCompiled:
        def cost_analysis(self):
            return {"flops": 197e12, "bytes accessed": 819e9 / 2}

        def as_text(self):
            return HLO_SAMPLE

    rf = analyze(FakeCompiled(), n_devices=4, model_flops=197e12 * 2)
    assert abs(rf.compute_s - 1.0) < 1e-9
    assert abs(rf.memory_s - 0.5) < 1e-9
    assert rf.bottleneck == "compute"
    assert abs(rf.model_flops_ratio - 0.5) < 1e-9


def test_cost_scale_applies_to_all_terms():
    from repro.roofline.analysis import analyze

    class FakeCompiled:
        def cost_analysis(self):
            return {"flops": 1e12, "bytes accessed": 1e9}

        def as_text(self):
            return HLO_SAMPLE

    r1 = analyze(FakeCompiled(), n_devices=1)
    r4 = analyze(FakeCompiled(), n_devices=1, cost_scale=4.0)
    assert abs(r4.compute_s / r1.compute_s - 4.0) < 1e-9
    assert abs(r4.collective_s / r1.collective_s - 4.0) < 1e-9
