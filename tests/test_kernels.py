"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracle,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ckpt_codec.ops import (
    dequantize_array,
    quantize_array,
    roundtrip_error,
)
from repro.kernels.ckpt_codec.ref import quantize_ref
from repro.kernels.flash_attention.ops import (
    flash_attention,
    flash_attention_reference,
)
from repro.kernels.mlstm_scan.ops import mlstm_chunked, mlstm_reference
from repro.kernels.moe_gmm.ops import expert_swiglu, expert_swiglu_ref
from repro.kernels.ssm_scan.ops import selective_scan, selective_scan_ref

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # B, S, H, KVH, D, causal, window, meta, bq, bk, dtype
    (2, 128, 4, 2, 64, True, 0, 0, 64, 64, jnp.float32),
    (1, 200, 4, 4, 32, True, 0, 0, 64, 64, jnp.float32),
    (2, 256, 8, 2, 64, False, 0, 0, 128, 128, jnp.float32),
    (1, 256, 4, 1, 64, True, 64, 16, 64, 64, jnp.float32),
    (1, 72, 2, 2, 16, True, 0, 0, 64, 64, jnp.float32),
    (2, 96, 4, 2, 128, True, 48, 8, 32, 32, jnp.float32),
    (1, 128, 4, 2, 64, True, 0, 0, 64, 64, jnp.bfloat16),
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_attention_vs_oracle(case):
    B, S, H, KVH, D, causal, win, meta, bq, bk, dtype = case
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, D)).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, KVH, D)).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, KVH, D)).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, window=win, n_meta=meta,
                          block_q=bq, block_k=bk, interpret=True)
    ref = flash_attention_reference(q, k, v, causal=causal, window=win, n_meta=meta)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol)


# ---------------------------------------------------------------------------
# moe grouped matmul
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(4, 96, 160, 224), (2, 128, 64, 64),
                                   (8, 32, 48, 96), (1, 256, 512, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_moe_gmm_vs_oracle(shape, dtype):
    E, C, d, f = shape
    ks = jax.random.split(KEY, 4)
    x = (jax.random.normal(ks[0], (E, C, d)) * 0.3).astype(dtype)
    wg = (jax.random.normal(ks[1], (E, d, f)) * 0.05).astype(dtype)
    wu = (jax.random.normal(ks[2], (E, d, f)) * 0.05).astype(dtype)
    wd = (jax.random.normal(ks[3], (E, f, d)) * 0.05).astype(dtype)
    out = expert_swiglu(x, wg, wu, wd, interpret=True)
    ref = expert_swiglu_ref(x, wg, wu, wd)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol)


# ---------------------------------------------------------------------------
# mamba selective scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cfg", [(2, 100, 64, 8, 32, 32), (1, 64, 32, 16, 16, 32),
                                 (3, 33, 16, 4, 16, 16)])
def test_ssm_scan_vs_oracle(cfg):
    B, S, di, ds, chunk, bd = cfg
    ks = jax.random.split(KEY, 6)
    delta = jax.nn.softplus(jax.random.normal(ks[0], (B, S, di))) * 0.1
    b = jax.random.normal(ks[1], (B, S, ds))
    c = jax.random.normal(ks[2], (B, S, ds))
    x = jax.random.normal(ks[3], (B, S, di))
    a = -jnp.exp(jax.random.normal(ks[4], (di, ds)) * 0.3)
    h0 = jax.random.normal(ks[5], (B, di, ds)) * 0.1
    y, hf = selective_scan(delta, b, c, x, a, h0, chunk=chunk, block_d=bd,
                           interpret=True)
    yr, hr = selective_scan_ref(delta, b, c, x, a, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-5)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(hr), atol=1e-5)


# ---------------------------------------------------------------------------
# mLSTM chunked scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cfg", [(3, 80, 32, 32), (1, 64, 16, 32),
                                 (2, 100, 64, 64), (1, 37, 16, 16)])
def test_mlstm_vs_sequential_oracle(cfg):
    BH, S, dh, chunk = cfg
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (BH, S, dh))
    k = jax.random.normal(ks[1], (BH, S, dh)) / np.sqrt(dh)
    v = jax.random.normal(ks[2], (BH, S, dh))
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[3], (BH, S)) + 3)
    li = jax.random.normal(ks[4], (BH, S))
    h, (c, n, m) = mlstm_chunked(q, k, v, lf, li, chunk=chunk, interpret=True)
    hr, (cr, nr, mr) = mlstm_reference(q, k, v, lf, li)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), atol=2e-4)
    np.testing.assert_allclose(np.asarray(c), np.asarray(cr), atol=1e-5)
    np.testing.assert_allclose(np.asarray(m), np.asarray(mr), atol=1e-5)


# ---------------------------------------------------------------------------
# checkpoint codec
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(1000, 33), (128,), (7, 5, 9), (2048, 128)])
def test_ckpt_codec_matches_ref_and_bounds_error(shape):
    x = jax.random.normal(KEY, shape) * 3.0
    q, s = quantize_array(x, interpret=True)
    flat = jnp.pad(x.reshape(-1), (0, q.size - x.size)).reshape(-1, 128)
    qr, sr = quantize_ref(flat)
    assert (np.asarray(q) == np.asarray(qr)).all()
    y = dequantize_array(q, s, shape=shape)
    # per-block absmax int8: error <= scale/2 <= absmax/254
    err = np.abs(np.asarray(y - x))
    bound = np.abs(np.asarray(x)).max() / 127.0
    assert err.max() <= bound + 1e-6
    assert roundtrip_error(x) < 1e-2
