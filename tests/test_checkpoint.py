"""Checkpoint substrate: roundtrip bitwiseness, tiers, delta, async, manager."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import delta as delta_mod
from repro.checkpoint import serialize
from repro.checkpoint.async_writer import AsyncCheckpointer
from repro.checkpoint.manager import CheckpointManager, ManagerConfig
from repro.checkpoint.reshard import restore_resharded, save_global
from repro.checkpoint.tiers import DiskTier, MemTier, TieredStore


def _state(key=0):
    k = jax.random.PRNGKey(key)
    return {
        "params": {"w": jax.random.normal(k, (16, 8)),
                   "b": jnp.zeros((8,), jnp.bfloat16)},
        "opt": {"m": jax.random.normal(jax.random.fold_in(k, 1), (16, 8)),
                "step": jnp.int32(7)},
    }


def _template(state):
    return jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state)


def test_serialize_roundtrip_bitwise(tmp_path):
    state = _state()
    serialize.save_tree(state, tmp_path / "ck")
    leaves = serialize.load_leaves(tmp_path / "ck")
    rebuilt = serialize.fill_template(_template(state), leaves)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(rebuilt)):
        assert a.dtype == b.dtype
        assert (np.asarray(a) == np.asarray(b)).all()


def test_serialize_detects_corruption(tmp_path):
    state = _state()
    m = serialize.save_tree(state, tmp_path / "ck")
    victim = next(iter(m["leaves"].values()))["file"]
    p = tmp_path / "ck" / victim
    raw = bytearray(p.read_bytes())
    raw[0] ^= 0xFF
    p.write_bytes(bytes(raw))
    with pytest.raises(IOError, match="corruption"):
        serialize.load_leaves(tmp_path / "ck")


def test_compressed_roundtrip(tmp_path):
    state = _state()
    serialize.save_tree(state, tmp_path / "ckz", compress=3)
    leaves = serialize.load_leaves(tmp_path / "ckz")
    rebuilt = serialize.fill_template(_template(state), leaves)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(rebuilt)):
        assert (np.asarray(a) == np.asarray(b)).all()


def test_mem_tier_lru_eviction():
    tier = MemTier(capacity_bytes=3000)
    big = {"x": np.ones((300,), np.float32)}     # 1200 bytes each
    tier.save_leaves("a", dict(big))
    tier.save_leaves("b", dict(big))
    tier.save_leaves("c", dict(big))             # evicts "a"
    assert "a" not in tier and "b" in tier and "c" in tier
    assert tier.stats.evictions == 1


def test_tiered_store_promotion(tmp_path):
    store = TieredStore(MemTier(1 << 20), DiskTier(tmp_path / "disk"))
    state = _state()
    leaves = save_global(state)
    store.mem.save_leaves("s1", leaves)
    store.promote("s1")
    assert "s1" in store.disk
    got = store.disk.restore("s1")
    assert set(got) == set(leaves)
    for k in leaves:
        assert (got[k] == leaves[k]).all()


def test_delta_roundtrip_and_compression_win():
    base = {"w": np.random.default_rng(0).normal(size=4096).astype(np.float32)}
    new = {"w": base["w"].copy()}
    new["w"][:100] += 1e-3                        # tiny change
    blobs, sizes = delta_mod.encode_snapshot(new, base)
    meta = {"w": ("float32", (4096,))}
    out = delta_mod.decode_snapshot(blobs, base, meta)
    assert (out["w"] == new["w"]).all()
    assert blobs["w"].is_delta
    full, _ = delta_mod.encode_snapshot(new, None)
    assert sizes["w"] < len(full["w"].data)       # delta strictly smaller


def test_async_writer_overlap_and_barrier(tmp_path):
    tier = DiskTier(tmp_path / "d")
    ck = AsyncCheckpointer(tier.save_leaves)
    state = _state()
    fut = ck.save("s1", state)
    ck.wait()
    assert fut.done() and "s1" in tier
    ck.close()


def test_manager_policy_and_restore(tmp_path):
    mgr = CheckpointManager(ManagerConfig(
        root=tmp_path / "ck", durable_every=2, keep_last=2, async_durable=True))
    states = [_state(i) for i in range(5)]
    for i, s in enumerate(states):
        mgr.save(i, s)
    mgr._async.wait()
    # saves 0..4 -> durable at i=1 and i=3 (every 2nd); keep_last=2
    assert len(mgr.disk.names()) == 2
    restored, name = mgr.restore(_template(states[-1]))
    assert name == "step_00000004"
    for a, b in zip(jax.tree.leaves(states[-1]), jax.tree.leaves(restored)):
        assert (np.asarray(a) == np.asarray(b)).all()
    mgr.close()


def test_manager_restore_from_disk_after_mem_loss(tmp_path):
    """Node failure: the fast tier dies with the host; restore falls back
    to the durable tier."""
    mgr = CheckpointManager(ManagerConfig(
        root=tmp_path / "ck", durable_every=1, keep_last=3, async_durable=False))
    s = _state(3)
    mgr.save(11, s)
    mgr.mem = MemTier(1 << 20)                    # fresh process: empty fast tier
    restored, name = mgr.restore(_template(s))
    assert name == "step_00000011"
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(restored)):
        assert (np.asarray(a) == np.asarray(b)).all()
    mgr.close()
