"""Checkpoint substrate: roundtrip bitwiseness, tiers, delta, async, manager."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import delta as delta_mod
from repro.checkpoint import serialize
from repro.checkpoint.async_writer import AsyncCheckpointer
from repro.checkpoint.manager import CheckpointManager, ManagerConfig
from repro.checkpoint.reshard import restore_resharded, save_global
from repro.checkpoint.tiers import DiskTier, MemTier, TieredStore


def _state(key=0):
    k = jax.random.PRNGKey(key)
    return {
        "params": {"w": jax.random.normal(k, (16, 8)),
                   "b": jnp.zeros((8,), jnp.bfloat16)},
        "opt": {"m": jax.random.normal(jax.random.fold_in(k, 1), (16, 8)),
                "step": jnp.int32(7)},
    }


def _template(state):
    return jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state)


def test_serialize_roundtrip_bitwise(tmp_path):
    state = _state()
    serialize.save_tree(state, tmp_path / "ck")
    leaves = serialize.load_leaves(tmp_path / "ck")
    rebuilt = serialize.fill_template(_template(state), leaves)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(rebuilt)):
        assert a.dtype == b.dtype
        assert (np.asarray(a) == np.asarray(b)).all()


def test_serialize_detects_corruption(tmp_path):
    state = _state()
    m = serialize.save_tree(state, tmp_path / "ck")
    victim = next(iter(m["leaves"].values()))["file"]
    p = tmp_path / "ck" / victim
    raw = bytearray(p.read_bytes())
    raw[0] ^= 0xFF
    p.write_bytes(bytes(raw))
    with pytest.raises(IOError, match="corruption"):
        serialize.load_leaves(tmp_path / "ck")


def test_compressed_roundtrip(tmp_path):
    state = _state()
    serialize.save_tree(state, tmp_path / "ckz", compress=3)
    leaves = serialize.load_leaves(tmp_path / "ckz")
    rebuilt = serialize.fill_template(_template(state), leaves)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(rebuilt)):
        assert (np.asarray(a) == np.asarray(b)).all()


def test_mem_tier_lru_eviction():
    tier = MemTier(capacity_bytes=3000)
    big = {"x": np.ones((300,), np.float32)}     # 1200 bytes each
    tier.save_leaves("a", dict(big))
    tier.save_leaves("b", dict(big))
    tier.save_leaves("c", dict(big))             # evicts "a"
    assert "a" not in tier and "b" in tier and "c" in tier
    assert tier.stats.evictions == 1


def test_tiered_store_promotion(tmp_path):
    store = TieredStore(MemTier(1 << 20), DiskTier(tmp_path / "disk"))
    state = _state()
    leaves = save_global(state)
    store.mem.save_leaves("s1", leaves)
    store.promote("s1")
    assert "s1" in store.disk
    got = store.disk.restore("s1")
    assert set(got) == set(leaves)
    for k in leaves:
        assert (got[k] == leaves[k]).all()


def test_delta_roundtrip_and_compression_win():
    base = {"w": np.random.default_rng(0).normal(size=4096).astype(np.float32)}
    new = {"w": base["w"].copy()}
    new["w"][:100] += 1e-3                        # tiny change
    blobs, sizes = delta_mod.encode_snapshot(new, base)
    meta = {"w": ("float32", (4096,))}
    out = delta_mod.decode_snapshot(blobs, base, meta)
    assert (out["w"] == new["w"]).all()
    assert blobs["w"].is_delta
    full, _ = delta_mod.encode_snapshot(new, None)
    assert sizes["w"] < len(full["w"].data)       # delta strictly smaller


def test_async_writer_overlap_and_barrier(tmp_path):
    tier = DiskTier(tmp_path / "d")
    ck = AsyncCheckpointer(tier.save_leaves)
    state = _state()
    fut = ck.save("s1", state)
    ck.wait()
    assert fut.done() and "s1" in tier
    ck.close()


def test_manager_policy_and_restore(tmp_path):
    mgr = CheckpointManager(ManagerConfig(
        root=tmp_path / "ck", durable_every=2, keep_last=2, async_durable=True))
    states = [_state(i) for i in range(5)]
    for i, s in enumerate(states):
        mgr.save(i, s)
    mgr._async.wait()
    # saves 0..4 -> durable at i=1 and i=3 (every 2nd); keep_last=2
    assert len(mgr.disk.names()) == 2
    restored, name = mgr.restore(_template(states[-1]))
    assert name == "step_00000004"
    for a, b in zip(jax.tree.leaves(states[-1]), jax.tree.leaves(restored)):
        assert (np.asarray(a) == np.asarray(b)).all()
    mgr.close()


def test_mem_tier_oversized_snapshot_rejected_store_intact():
    """A snapshot larger than capacity used to evict EVERYTHING and then be
    admitted anyway, silently blowing the bound; now it is rejected with
    the store untouched (regression)."""
    tier = MemTier(capacity_bytes=3000)
    tier.save_leaves("a", {"x": np.ones((300,), np.float32)})   # 1200 B
    with pytest.raises(ValueError, match="exceeds MemTier capacity"):
        tier.save_leaves("big", {"x": np.ones((2000,), np.float32)})
    assert "a" in tier and "big" not in tier
    assert tier.stats.evictions == 0


def test_tiered_store_oversized_writes_through_to_disk(tmp_path):
    store = TieredStore(MemTier(capacity_bytes=100),
                        DiskTier(tmp_path / "disk"))
    state = {"w": np.arange(1024, dtype=np.float32)}            # 4 KiB > 100 B
    store.save("big", state)
    assert "big" not in store.mem and "big" in store.disk
    got = store.restore_leaves("big")
    (arr,) = got.values()               # keys are keystr tree paths
    assert (arr == state["w"]).all()


def test_manager_oversized_snapshot_writes_through(tmp_path):
    mgr = CheckpointManager(ManagerConfig(
        root=tmp_path / "ck", mem_capacity_bytes=100, durable_every=100))
    s = _state(1)
    mgr.save(3, s)
    assert mgr.mem.names() == [] and mgr.disk.names() == ["step_00000003"]
    restored, name = mgr.restore(_template(s))
    assert name == "step_00000003"
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(restored)):
        assert (np.asarray(a) == np.asarray(b)).all()
    mgr.close()


def test_tiered_store_restore_prefers_fastest_tier(tmp_path):
    store = TieredStore(MemTier(1 << 20), DiskTier(tmp_path / "disk"))
    leaves = save_global(_state(2))
    store.mem.save_leaves("s", leaves)
    store.promote("s")
    assert "s" in store.mem and "s" in store.disk
    before = store.disk.stats.restores
    got = store.restore_leaves("s")
    assert store.mem.stats.restores >= 1
    assert store.disk.stats.restores == before     # disk never touched
    for k in leaves:
        assert (got[k] == leaves[k]).all()


def test_tiered_store_promote_idempotent(tmp_path):
    store = TieredStore(MemTier(1 << 20), DiskTier(tmp_path / "disk"))
    store.mem.save_leaves("s", save_global(_state(0)))
    store.promote("s")
    store.promote("s")          # second promote must be a no-op
    assert store.disk.stats.saves == 1


def test_tier_stats_byte_accounting(tmp_path):
    """bytes_written / bytes_read against known array sizes."""
    a = np.ones((256,), np.float32)      # 1024 B
    b = np.ones((128,), np.float64)      # 1024 B
    expected = a.nbytes + b.nbytes
    mem = MemTier(1 << 20)
    mem.save_leaves("s", {"a": a, "b": b})
    assert mem.stats.bytes_written == expected
    mem.restore("s")
    assert mem.stats.bytes_read == expected

    disk = DiskTier(tmp_path / "d", compress=None)
    disk.save_leaves("s", {"a": a, "b": b})
    assert disk.stats.bytes_written == expected    # raw: stored == nbytes
    disk.restore("s")
    assert disk.stats.bytes_read == expected


def test_manager_delta_chain_bounded(tmp_path):
    mgr = CheckpointManager(ManagerConfig(
        root=tmp_path / "ck", durable_every=100, delta_keep_last=4,
        use_delta=True, async_durable=False))
    for i in range(12):
        mgr.save(i, _state(i))
    assert len(mgr._delta_chain) == 4      # bounded, oldest GC'd
    assert list(mgr._delta_chain) == [f"step_{i:08d}" for i in (8, 9, 10, 11)]
    mgr.close()


def test_manager_restore_after_many_evictions_decodes_chain(tmp_path):
    """The fast tier forgets (LRU), the durable tier holds sparse fulls —
    a mid-chain snapshot is rebuilt by XOR-decoding forward from the
    nearest durable full snapshot."""
    states = [_state(i) for i in range(6)]
    snap_bytes = sum(np.asarray(a).nbytes
                     for a in jax.tree.leaves(states[0]))
    mgr = CheckpointManager(ManagerConfig(
        root=tmp_path / "ck",
        mem_capacity_bytes=snap_bytes + 16,    # fast tier holds ONE snapshot
        durable_every=2, keep_last=2, delta_keep_last=8,
        use_delta=True, async_durable=False))
    for i, s in enumerate(states):
        mgr.save(i, s)
    # steps 1,3,5 went durable (every 2nd save); keep_last=2 -> disk {3,5};
    # mem only holds step 5; step 4 lives in no tier but the delta chain
    assert mgr.mem.names() == ["step_00000005"]
    assert mgr.disk.names() == ["step_00000003", "step_00000005"]
    restored, name = mgr.restore(_template(states[4]), name="step_00000004")
    assert name == "step_00000004"
    for a, b in zip(jax.tree.leaves(states[4]), jax.tree.leaves(restored)):
        assert (np.asarray(a) == np.asarray(b)).all()
    # a snapshot whose chain base was GC'd everywhere raises cleanly
    with pytest.raises(FileNotFoundError):
        mgr.restore(_template(states[2]), name="step_00000002")
    mgr.close()


def test_manager_restore_from_disk_after_mem_loss(tmp_path):
    """Node failure: the fast tier dies with the host; restore falls back
    to the durable tier."""
    mgr = CheckpointManager(ManagerConfig(
        root=tmp_path / "ck", durable_every=1, keep_last=3, async_durable=False))
    s = _state(3)
    mgr.save(11, s)
    mgr.mem = MemTier(1 << 20)                    # fresh process: empty fast tier
    restored, name = mgr.restore(_template(s))
    assert name == "step_00000011"
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(restored)):
        assert (np.asarray(a) == np.asarray(b)).all()
    mgr.close()
