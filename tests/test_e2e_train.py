"""End-to-end: OMFS preempting real JAX training jobs, transparently.

The paper's headline property — preemption via transparent C/R changes
*nothing* about the job's computation — is asserted bitwise on loss curves.
"""
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager, ManagerConfig
from repro.cluster.executor import ClusterExecutor, ManagedJob, small_train_job
from repro.configs import get_smoke_config
from repro.core.types import Job, JobClass, JobState, SchedulerConfig, User


@pytest.fixture(scope="module")
def arch_cfg():
    return get_smoke_config("internlm2-1.8b")


def _mk(tmp, cfg, seed):
    return small_train_job(tmp, arch_cfg=cfg, seq=32, batch=4, seed=seed)


def test_preempted_run_is_bitwise_transparent(tmp_path, arch_cfg):
    users = [User("A", 50.0), User("B", 50.0)]
    ex = ClusterExecutor(users, SchedulerConfig(cpu_total=16, quantum=3),
                         steps_per_tick=2)
    jb = Job(user="B", cpus=12, work=30, job_class=JobClass.CHECKPOINTABLE,
             submit_time=0)
    ja = Job(user="A", cpus=8, work=6, job_class=JobClass.CHECKPOINTABLE,
             submit_time=5)
    mb = ManagedJob(jb, _mk(tmp_path, arch_cfg, 1),
                    CheckpointManager(ManagerConfig(root=tmp_path / "b",
                                                    durable_every=100)))
    ma = ManagedJob(ja, _mk(tmp_path, arch_cfg, 2),
                    CheckpointManager(ManagerConfig(root=tmp_path / "a",
                                                    durable_every=100)))
    ex.submit(mb)
    ex.submit(ma)
    ex.run(80)

    assert jb.state == JobState.DONE and ja.state == JobState.DONE
    assert mb.checkpoints >= 1 and mb.restores >= 1, ex.events

    # uninterrupted twin of job B
    ref = _mk(tmp_path, arch_cfg, 1)
    ref.cold_start()
    ref_losses = [ref.run_step() for _ in range(len(mb.train_job.losses))]
    assert (np.asarray(ref_losses) == np.asarray(mb.train_job.losses)).all(), \
        "preempted run diverged from the uninterrupted run"


def test_loss_decreases_on_synthetic_data(tmp_path, arch_cfg):
    job = _mk(tmp_path, arch_cfg, 0)
    job.cold_start()
    losses = [job.run_step() for _ in range(30)]
    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    assert last < first - 0.2, (first, last)


def test_node_failure_recovery_from_durable_tier(tmp_path, arch_cfg):
    """Kill the job (and its fast tier) mid-run; restart resumes from the
    durable tier at the last durable step."""
    mgr = CheckpointManager(ManagerConfig(root=tmp_path / "ck",
                                          durable_every=1, async_durable=False))
    job = _mk(tmp_path, arch_cfg, 5)
    job.cold_start()
    for _ in range(4):
        job.run_step()
    mgr.save(int(job.state.step), job.snapshot_state())
    losses_before_crash = [job.run_step() for _ in range(3)]

    # simulated node failure: new process = new manager over the same root
    mgr2 = CheckpointManager(ManagerConfig(root=tmp_path / "ck",
                                           durable_every=1, async_durable=False))
    job2 = _mk(tmp_path, arch_cfg, 5)
    from repro.train.state import train_state_shapes
    template = train_state_shapes(job2.model, job2.seed)
    state, name = mgr2.restore(template)
    job2.restore_state(state)
    losses_after_restart = [job2.run_step() for _ in range(3)]
    assert (np.asarray(losses_before_crash) == np.asarray(losses_after_restart)).all()
    mgr.close(); mgr2.close()
