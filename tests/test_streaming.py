"""Streaming-engine tests (`engine.simulate_stream`): a fixed-capacity
JobTable fed by an arrival iterator, run in jitted segments with host-side
compaction between them, must reproduce the monolithic whole-table run
bit-for-bit whenever every arrival finds a slot — including under
eviction churn, where queue/victim tie-breaks ride the ``jid`` column
through recycled slots — and must degrade to deferred (late) arrivals,
not errors, when capacity runs out.
"""
import itertools

import numpy as np

from repro.core import engine, omfs_jax
from repro.core.crcost import UNBOUNDED, CRCostModel, TieredCRCostModel
from repro.core.types import Job, JobClass, SchedulerConfig, User
from repro.core.workload import (WorkloadSpec, arrival_stream,
                                 endless_arrivals, make_users)

CAPACITY = 12
N_JOBS = 10 * CAPACITY


def _conveyor_jobs():
    """Deterministic conveyor: ten× more jobs than table slots, arrivals
    paced so the live set stays well under CAPACITY, plus periodic entitled
    claims from user A that land when B's flood holds >half the machine —
    each claim goes through the evict path (slot-recycling under C/R
    churn)."""
    users = [User("A", 50.0), User("B", 50.0)]
    jobs = [Job(user="B", cpus=4, work=8, priority=i % 4,
                job_class=JobClass.CHECKPOINTABLE,
                submit_time=3 * i, state_bytes=(64 + i % 5) << 20)
            for i in range(N_JOBS)]
    for k in range(10):
        jobs.append(Job(user="A", cpus=8, work=6,
                        job_class=JobClass.CHECKPOINTABLE,
                        submit_time=25 + 30 * k, state_bytes=32 << 20))
    horizon = 3 * N_JOBS + 60
    return users, jobs, horizon


def _cfg(tiered=False):
    if not tiered:
        return SchedulerConfig(cpu_total=16, quantum=2, cr_overhead=1)
    tiers = TieredCRCostModel(
        tiers=(CRCostModel(save_mib_per_tick=256, restore_mib_per_tick=256),
               CRCostModel(save_mib_per_tick=32, restore_mib_per_tick=32,
                           save_base=1, restore_base=1)),
        capacity_mib=(64, UNBOUNDED))
    return SchedulerConfig(cpu_total=16, quantum=2, cr_overhead=1,
                           cr_tiers=tiers)


def test_stream_matches_monolithic_at_10x_capacity():
    users, jobs, horizon = _conveyor_jobs()
    cfg = _cfg()
    mono = engine.simulate(users, jobs, cfg, horizon,
                           policy="omfs", backend="jax")
    res = engine.simulate_stream(users, arrival_stream(jobs), cfg, horizon,
                                 capacity=CAPACITY, segment_len=16)
    stats = res.stream_stats
    # the bounded-memory premise actually held: never more live jobs than
    # slots, nothing deferred, every job flowed through the small table
    assert stats["deferrals"] == 0 and stats["dropped"] == 0
    assert stats["peak_live"] <= CAPACITY
    assert stats["inserted"] == len(jobs) >= 10 * CAPACITY
    assert res.table.cpus.shape[0] == len(jobs)
    assert int(np.asarray(mono.table.n_preempt).sum()) > 0, \
        "fixture must exercise eviction under slot recycling"
    # ...and the merged result is the monolithic run, bit for bit
    assert omfs_jax.tables_equal(res.table, mono.table)
    assert np.array_equal(np.asarray(res.table.n_spill),
                          np.asarray(mono.table.n_spill))
    assert np.array_equal(res.busy_series(), mono.busy_series())
    assert res.signature() == mono.signature()
    assert res.summary()["goodput"] == mono.summary()["goodput"]


def test_stream_eviction_churn_tiered_costs():
    """Eviction/restart churn with tiered snapshot placement: recycled
    slots must not perturb victim ordering (jid tie-break) or spill
    accounting."""
    users, jobs, horizon = _conveyor_jobs()
    cfg = _cfg(tiered=True)
    mono = engine.simulate(users, jobs, cfg, horizon,
                           policy="omfs_cheap_victim", backend="jax")
    assert int(np.asarray(mono.table.n_preempt).sum()) > 0, \
        "fixture must actually evict"
    assert int(np.asarray(mono.table.n_spill).sum()) > 0, \
        "fixture must actually spill"
    # tiered C/R overhead stretches slot residency; 16 slots keep the
    # live set inside capacity (deferrals==0 is this test's precondition)
    res = engine.simulate_stream(users, arrival_stream(jobs), cfg, horizon,
                                 "omfs_cheap_victim",
                                 capacity=16, segment_len=16)
    assert res.stream_stats["deferrals"] == 0
    assert omfs_jax.tables_equal(res.table, mono.table)
    assert np.array_equal(np.asarray(res.table.n_spill),
                          np.asarray(mono.table.n_spill))
    assert np.array_equal(res.busy_series(), mono.busy_series())


def test_stream_compiles_one_segment_program():
    """N segments, ONE compiled scan: the segment start tick is traced, so
    `_cache_size()` stays 1 however long the stream runs (the acceptance
    criterion the jaxpr/retrace audit re-checks)."""
    users, jobs, horizon = _conveyor_jobs()
    cfg = _cfg()
    res = engine.simulate_stream(users, arrival_stream(jobs), cfg, horizon,
                                 capacity=CAPACITY, segment_len=32)
    assert res.stream_stats["segments"] >= 8
    pass_fn = engine.POLICIES["omfs"].jax_factory(None)
    runner = engine._jitted_segment_runner(cfg, pass_fn, 32)
    assert runner._cache_size() == 1


def test_stream_capacity_exhaustion_defers_not_crashes():
    """More live jobs than slots: surplus arrivals are deferred to later
    boundaries (counted), the run completes, and accounting stays
    consistent."""
    users, jobs, horizon = _conveyor_jobs()
    cfg = _cfg()
    res = engine.simulate_stream(users, arrival_stream(jobs), cfg, horizon,
                                 capacity=4, segment_len=32)
    stats = res.stream_stats
    assert stats["deferrals"] > 0
    assert stats["peak_live"] <= 4
    assert res.table.cpus.shape[0] == stats["inserted"]
    assert stats["inserted"] + stats["dropped"] <= len(jobs)
    assert res.busy_series().shape == (horizon,)


def test_endless_arrivals_feed_contract_and_bounded_memory():
    """The unbounded generator yields sorted arrivals forever; the stream
    consumes exactly the prefix due before the horizon and holds at most
    `capacity` rows."""
    spec = WorkloadSpec(n_users=3, horizon=120, cpu_total=32, seed=13,
                        arrival_rate=0.05, mean_work=10)
    users = make_users(spec)
    feed = endless_arrivals(spec, users)
    peek = list(itertools.islice(endless_arrivals(spec, users), 300))
    submits = [j.submit_time for j in peek]
    assert submits == sorted(submits), "endless_arrivals must be sorted"
    assert submits[-1] > spec.horizon, "must cross epoch boundaries"
    cfg = SchedulerConfig(cpu_total=32, quantum=3)
    horizon = 3 * spec.horizon          # several generator epochs
    res = engine.simulate_stream(users, feed, cfg, horizon,
                                 capacity=64, segment_len=40)
    stats = res.stream_stats
    assert stats["peak_live"] <= 64
    # every inserted job is accounted for in the merged table
    assert res.table.cpus.shape[0] == stats["inserted"] > 0
    # arrivals stopped at the horizon even though the feed is infinite
    assert int(np.asarray(res.table.submit).max()) < horizon
