"""Property tests for the batched sweep engine (`engine.simulate_batch`):
ONE vmapped compiled program must be bit-identical, cell by cell, to the
sequential per-cell `simulate(..., backend="jax")` loop — for every
registered policy, under tiered C/R costs (spill counts included), and
across traced quantum/pass-depth knob grids.  Plus the empty-batch /
empty-table corner contract shared with `simulate` / `simulate_matrix`.
"""
import numpy as np
import pytest

from repro.core import engine, omfs_jax
from repro.core.crcost import UNBOUNDED, CRCostModel, TieredCRCostModel
from repro.core.types import SchedulerConfig
from repro.core.workload import WorkloadSpec, make_jobs, make_users

POLICY_NAMES = sorted(engine.POLICIES)
HORIZON = 80


def _workload(seed, n_users=3, cpu_total=32):
    spec = WorkloadSpec(n_users=n_users, horizon=HORIZON, cpu_total=cpu_total,
                        seed=seed, arrival_rate=0.15, mean_work=20,
                        class_mix=(0.15, 0.35, 0.5))
    users = make_users(spec)
    jobs = make_jobs(spec, users)[:30]
    return users, jobs


def _tiered_cfg(quantum=3):
    tiers = TieredCRCostModel(
        tiers=(CRCostModel(save_mib_per_tick=256, restore_mib_per_tick=256),
               CRCostModel(save_mib_per_tick=32, restore_mib_per_tick=32,
                           save_base=1, restore_base=1)),
        capacity_mib=(64, UNBOUNDED))
    return SchedulerConfig(cpu_total=32, quantum=quantum, cr_overhead=1,
                           cr_tiers=tiers)


def _assert_cell_equal(batch_res, seq_res):
    assert omfs_jax.tables_equal(batch_res.table, seq_res.table)
    assert np.array_equal(batch_res.busy_series(), seq_res.busy_series())


def test_batch_matches_sequential_every_policy_tiered():
    """All 7 policies in one batch, tiered C/R costs live (spills happen),
    vs the sequential per-cell loop."""
    users, jobs = _workload(seed=11)
    cfg = _tiered_cfg()
    cells = [engine.BatchCell(users=users, jobs=jobs, policy=p)
             for p in POLICY_NAMES]
    batch = engine.simulate_batch(cells, cfg, HORIZON)
    spills = 0
    for res, name in zip(batch, POLICY_NAMES):
        seq = engine.simulate(users, jobs, cfg, HORIZON,
                              policy=name, backend="jax")
        _assert_cell_equal(res, seq)
        assert np.array_equal(np.asarray(res.table.n_spill),
                              np.asarray(seq.table.n_spill))
        spills += int(np.asarray(res.table.n_spill).sum())
    assert spills > 0, "fixture must exercise tiered spill accounting"


def test_batch_matches_sequential_across_seeds_and_scenarios():
    """Heterogeneous cells — different workloads (seeds/user counts) padded
    to a common table size — each equal to its own sequential run."""
    cfg = SchedulerConfig(cpu_total=32, quantum=4, cr_overhead=2)
    wl = [_workload(seed=s, n_users=u) for s, u in
          [(0, 2), (1, 3), (2, 4), (3, 3)]]
    cells = [engine.BatchCell(users=us, jobs=js, policy=p)
             for us, js in wl for p in ("omfs", "backfill_cr")]
    batch = engine.simulate_batch(cells, cfg, HORIZON)
    for cell, res in zip(cells, batch):
        seq = engine.simulate(cell.users, cell.jobs, cfg, HORIZON,
                              policy=cell.policy, backend="jax")
        _assert_cell_equal(res, seq)


def test_knob_grid_matches_static_configs():
    """Traced quantum/pass_depth knobs vs baking the same values into the
    config / factory — the sweep grid semantics of bench_sweep."""
    users, jobs = _workload(seed=5)
    base = _tiered_cfg(quantum=1)  # cell knobs override cfg.quantum
    grid = [(q, d, p) for q in (0, 3, 9) for d in (2, None)
            for p in ("omfs", "omfs_cheap_victim")]
    cells = [engine.BatchCell(users=users, jobs=jobs, policy=p,
                              quantum=q, pass_depth=d)
             for q, d, p in grid]
    batch = engine.simulate_batch(cells, base, HORIZON)
    for (q, d, p), res in zip(grid, batch):
        cfg_q = _tiered_cfg(quantum=q)
        seq = engine.simulate(users, jobs, cfg_q, HORIZON, policy=p,
                              backend="jax", pass_depth=d)
        _assert_cell_equal(res, seq)


def test_batch_runner_compiles_once_for_the_grid():
    """The whole knob grid must ride ONE compiled program (that is the
    entire point of traced knobs) — and repeat sweeps must reuse it."""
    users, jobs = _workload(seed=7)
    cfg = SchedulerConfig(cpu_total=32, quantum=2)
    cells = [engine.BatchCell(users=users, jobs=jobs, policy="omfs",
                              quantum=q, pass_depth=d)
             for q in (1, 2, 5, 8) for d in (3, 7, None)]
    engine.simulate_batch(cells, cfg, HORIZON)
    engine.simulate_batch(cells[::-1], cfg, HORIZON)
    runner = engine._jitted_batch_runner(
        cfg, (engine.POLICIES["omfs"].jax_factory(None),), HORIZON, 1)
    assert runner._cache_size() == 1


def test_batch_rejects_unknown_policy():
    users, jobs = _workload(seed=0)
    with pytest.raises(ValueError, match="unknown policies"):
        engine.simulate_batch(
            [engine.BatchCell(users=users, jobs=jobs, policy="nope")],
            SchedulerConfig(cpu_total=32), HORIZON)


# ---------------------------------------------------------------------------
# Empty-batch / empty-table corners (regression: the early-return and the
# jitted path must agree — see ISSUE 7 bugfix satellite)
# ---------------------------------------------------------------------------


def test_empty_batch_returns_empty_list():
    assert engine.simulate_batch([], SchedulerConfig(cpu_total=32),
                                 HORIZON) == []


def test_all_empty_tables_match_simulate_matrix_early_return():
    users, _ = _workload(seed=0)
    cfg = SchedulerConfig(cpu_total=32)
    batch = engine.simulate_batch(
        [engine.BatchCell(users=users, jobs=[], policy="omfs")],
        cfg, HORIZON)
    matrix = engine.simulate_matrix(users, [], cfg, HORIZON, ["omfs"])
    single = engine.simulate(users, [], cfg, HORIZON,
                             policy="omfs", backend="jax")
    for res in (batch[0], matrix[0], single):
        assert res.table.cpus.shape[0] == 0
        assert np.array_equal(res.busy_series(), np.zeros(HORIZON, np.int32))
        assert res.summary()["utilization"] == 0.0


def test_mixed_batch_keeps_empty_cell_on_the_jitted_path():
    """An empty cell inside a non-empty batch rides the jitted path as an
    all-pad table; its result must equal the early-return result."""
    users, jobs = _workload(seed=9)
    cfg = SchedulerConfig(cpu_total=32, quantum=3)
    mixed = engine.simulate_batch(
        [engine.BatchCell(users=users, jobs=[], policy="omfs"),
         engine.BatchCell(users=users, jobs=jobs, policy="omfs")],
        cfg, HORIZON)
    empty, full = mixed
    assert empty.table.cpus.shape[0] == 0
    assert np.array_equal(empty.busy_series(), np.zeros(HORIZON, np.int32))
    _assert_cell_equal(
        full, engine.simulate(users, jobs, cfg, HORIZON,
                              policy="omfs", backend="jax"))
