"""Baseline scheduling policies vectorized in JAX over the shared JobTable.

Twins of `core.baselines` (static_partition / capping / fcfs / backfill /
backfill_cr) for the engine's "jax" backend, built from the same JobTable
primitives as the OMFS pass (`core.omfs_jax`: queue_order, admit_job,
select_victims, apply_evictions) so every policy runs at fleet scale on the
same representation.  Property tests (tests/test_policies_equivalence.py)
assert each produces bit-identical schedules to its Python twin on
randomized workloads, exactly like the OMFS equivalence suite.

All passes share the engine's policy contract — ``pass_fn(cfg, ent, t, tbl,
knobs=None) -> tbl``, where ``knobs`` carries the traced per-cell
quantum/pass-depth overrides of `engine.simulate_batch` — and thread their
admission aggregates (per-user usage, busy,
head reservation) through the ``fori_loop`` carry: O(1) per queue position
for everything but backfill's once-per-tick reservation sort.

Size-aware C/R costs come for free: the shared `admit_job` /
`apply_evictions` primitives charge the JobTable's precomputed ``[J, T]``
cost lattice (``cost_save_lat`` / ``cost_rsave_lat`` / ``cost_restore_lat``,
`core.crcost`), so backfill_cr's preemptions and every restart pay the same
size- and delta-dependent overhead as the Python twins (first saves price
the full image, recurrent saves the measured delta).  The same holds for
tiered eviction placement (``cfg.cr_tiers``): `apply_evictions` places each
backfill_cr victim's snapshot (cheapest feasible tier across the whole
hierarchy, in the standard victim order — the same order
`baselines.make_backfill` walks `sorted_victims`) and `admit_job` charges
the placed tier's restore cost, with no baseline-specific code here.
"""
from __future__ import annotations

from functools import lru_cache
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.omfs_jax import (
    BIG,
    NONP,
    PENDING,
    RUNNING,
    JobTable,
    Knobs,
    admit_job,
    apply_evictions,
    plan_evictions,
    queue_order,
    running_usage,
)
from repro.core.types import SchedulerConfig


def _depth(n: int, pass_depth: Optional[int]) -> int:
    return n if pass_depth is None else min(pass_depth, n)


def _mask_depth(elig: jax.Array, i, knobs: Optional[Knobs]) -> jax.Array:
    """Batched pass-depth bound: mask queue positions past ``knobs.depth``.

    Result-identical to the static ``_depth`` loop truncation — a masked
    iteration admits nothing and updates no aggregate — but keeps the trip
    count static so one compiled program serves every depth in a sweep
    (`engine.simulate_batch`)."""
    return elig if knobs is None else elig & (i < knobs.depth)


def _est_remaining(work, overhead, progress, error: float):
    """baselines._estimated_remaining: true remaining inflated by ``error``."""
    rem = work + overhead - progress
    if error:
        rem = jnp.ceil(rem.astype(jnp.float32) * (1.0 + error)).astype(jnp.int32)
    return jnp.maximum(rem, 1)


# ---------------------------------------------------------------------------


@lru_cache(maxsize=None)
def make_static_partition_pass(pass_depth: Optional[int] = None):
    """Hard divisions: user blocks sized by entitlement; no pooling at all."""

    def pass_fn(cfg: SchedulerConfig, ent, t, tbl: JobTable,
                knobs: Optional[Knobs] = None) -> JobTable:
        n = tbl.cpus.shape[0]
        order, eligible = queue_order(tbl)
        usage0, _, _ = running_usage(tbl, ent.shape[0])

        def body(i, carry):
            tbl, usage = carry
            idx = order[i]
            ju, jc = tbl.user[idx], tbl.cpus[idx]
            admit = (_mask_depth(eligible[idx], i, knobs)
                     & (tbl.state[idx] == PENDING)
                     & (usage[ju] + jc <= ent[ju]))
            tbl = admit_job(tbl, idx, t, admit)
            usage = usage.at[ju].add(jnp.where(admit, jc, 0))
            return tbl, usage

        tbl, _ = jax.lax.fori_loop(0, _depth(n, pass_depth), body, (tbl, usage0))
        return tbl

    return pass_fn


@lru_cache(maxsize=None)
def make_capping_pass(pass_depth: Optional[int] = None):
    """Pooled CPUs + per-user cap at the entitlement (no over-subscription)."""

    def pass_fn(cfg: SchedulerConfig, ent, t, tbl: JobTable,
                knobs: Optional[Knobs] = None) -> JobTable:
        n = tbl.cpus.shape[0]
        order, eligible = queue_order(tbl)
        usage0, _, busy0 = running_usage(tbl, ent.shape[0])

        def body(i, carry):
            tbl, usage, busy = carry
            idx = order[i]
            ju, jc = tbl.user[idx], tbl.cpus[idx]
            admit = (_mask_depth(eligible[idx], i, knobs)
                     & (tbl.state[idx] == PENDING)
                     & (usage[ju] + jc <= ent[ju])
                     & (cfg.cpu_total - busy >= jc))
            tbl = admit_job(tbl, idx, t, admit)
            grant = jnp.where(admit, jc, 0)
            return tbl, usage.at[ju].add(grant), busy + grant

        tbl, _, _ = jax.lax.fori_loop(
            0, _depth(n, pass_depth), body, (tbl, usage0, busy0))
        return tbl

    return pass_fn


@lru_cache(maxsize=None)
def make_fcfs_pass(pass_depth: Optional[int] = None):
    """Strict first-come-first-served: the queue head blocks everyone."""

    def pass_fn(cfg: SchedulerConfig, ent, t, tbl: JobTable,
                knobs: Optional[Knobs] = None) -> JobTable:
        n = tbl.cpus.shape[0]
        order, eligible = queue_order(tbl)
        _, _, busy0 = running_usage(tbl, ent.shape[0])

        def body(i, carry):
            tbl, busy, blocked = carry
            idx = order[i]
            jc = tbl.cpus[idx]
            elig = _mask_depth(eligible[idx], i, knobs) & (
                tbl.state[idx] == PENDING)
            fits = cfg.cpu_total - busy >= jc
            admit = elig & ~blocked & fits
            blocked = blocked | (elig & ~fits)   # head blocked: noone overtakes
            tbl = admit_job(tbl, idx, t, admit)
            return tbl, busy + jnp.where(admit, jc, 0), blocked

        tbl, _, _ = jax.lax.fori_loop(
            0, _depth(n, pass_depth), body,
            (tbl, busy0, jnp.asarray(False)))
        return tbl

    return pass_fn


@lru_cache(maxsize=None)
def make_backfill_pass(estimate_error: float = 0.0, with_cr: bool = False,
                       pass_depth: Optional[int] = None):
    """Conservative backfill; optionally with C/R preemption (Niu et al.).

    The head job's reservation is computed once per tick from estimated
    remaining runtimes (sort + cumsum over running jobs); the rest of the
    queue is a fori_loop with the (busy, reservation) carry."""

    def pass_fn(cfg: SchedulerConfig, ent, t, tbl: JobTable,
                knobs: Optional[Knobs] = None) -> JobTable:
        n = tbl.cpus.shape[0]
        quantum = cfg.quantum if knobs is None else knobs.quantum
        order, eligible = queue_order(tbl)
        any_pending = jnp.any(eligible)
        running = tbl.state == RUNNING
        busy = jnp.sum(jnp.where(running, tbl.cpus, 0))
        idle = cfg.cpu_total - busy
        head = order[0]
        head_cpus = tbl.cpus[head]
        est = _est_remaining(tbl.work, tbl.overhead, tbl.progress,
                             estimate_error)

        head_fits = any_pending & (idle >= head_cpus)

        # Reservation: earliest tick the head fits, assuming running jobs end
        # at their estimates (baselines._reservation_time).  Computed from the
        # pre-eviction state; only consumed when the head ends up waiting.
        # tie-break by true job id (not row position): order-isomorphic to
        # arange on monolithic tables (rows sorted by id) and stable when the
        # streaming engine recycles slots out of id order
        key = jnp.where(running, est, BIG)
        ordr = jnp.lexsort((tbl.jid, key))
        cum = idle + jnp.cumsum(jnp.where(running[ordr], tbl.cpus[ordr], 0))
        crossed = cum >= head_cpus
        reservation = jnp.where(
            jnp.any(crossed),
            t + est[ordr][jnp.argmax(crossed)],
            t + jnp.sum(jnp.where(running, est, 0)) + 1)

        head_admit = head_fits
        if with_cr:
            # Niu et al.: preempt checkpointable *backfilled* jobs to start
            # the head now instead of waiting for the reservation.
            evictable = (running & (tbl.jclass != NONP)
                         & ((t - tbl.run_start) >= quantum)
                         & (tbl.backfilled > 0))
            # plan_evictions dispatches lax/pallas and hands back the
            # victim order (or fused placement) so apply_evictions never
            # recomputes the lexsort
            planned, enough, vorder, placement = plan_evictions(
                cfg, tbl, evictable, idle, head_cpus)
            do_cr = any_pending & ~head_fits & enough
            planned = planned & do_cr
            busy = busy - jnp.sum(jnp.where(planned, tbl.cpus, 0))
            tbl = apply_evictions(cfg, t, tbl, planned, vorder, placement)
            head_admit = head_fits | do_cr

        tbl = admit_job(tbl, head, t, head_admit)
        busy = busy + jnp.where(head_admit, head_cpus, 0)
        head_start = jnp.where(any_pending & ~head_admit, reservation, BIG)

        def body(i, carry):
            tbl, busy = carry
            idx = order[i]
            jc = tbl.cpus[idx]
            elig = _mask_depth(eligible[idx], i, knobs) & (
                tbl.state[idx] == PENDING)
            cur_idle = cfg.cpu_total - busy
            fits = cur_idle >= jc
            # conservative: only backfill if the head reservation is kept
            no_delay = ((t + est[idx] <= head_start)
                        | (cur_idle - jc >= head_cpus))
            admit = elig & fits & no_delay
            tbl = admit_job(tbl, idx, t, admit)
            tbl = tbl._replace(backfilled=tbl.backfilled.at[idx].set(
                jnp.where(admit, 1, tbl.backfilled[idx])))
            return tbl, busy + jnp.where(admit, jc, 0)

        tbl, _ = jax.lax.fori_loop(1, _depth(n, pass_depth), body, (tbl, busy))
        return tbl

    return pass_fn


JAX_BASELINES = {
    "static_partition": make_static_partition_pass,
    "capping": make_capping_pass,
    "fcfs": make_fcfs_pass,
    "backfill": lambda pass_depth=None: make_backfill_pass(
        pass_depth=pass_depth),
    "backfill_cr": lambda pass_depth=None: make_backfill_pass(
        with_cr=True, pass_depth=pass_depth),
}
