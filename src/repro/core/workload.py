"""Synthetic workload generation for scheduler benchmarks.

Models the regimes the paper cares about: bursty per-user demand (a user
suddenly needs its entitlement back), long-tailed job durations, mixed job
classes, jobs larger than their owner's whole entitlement (§II: "an
entity can use it to run a single job that is larger than its whole
entitlement"), and — the C/R cost axis — heterogeneous lognormal
checkpoint image sizes plus `thrashing_scenario`, where the size-aware
cost model materially changes the schedule.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Iterable, Iterator, List, Optional, Sequence

import numpy as np

from repro.core.crcost import MAX_STATE_MIB, MIB
from repro.core.types import Job, JobClass, User


@dataclass(frozen=True)
class WorkloadSpec:
    n_users: int = 4
    horizon: int = 2_000
    cpu_total: int = 256
    arrival_rate: float = 0.05       # jobs per tick per user
    burstiness: float = 0.0          # 0 = Poisson; >0 = on/off bursts
    mean_work: float = 120.0         # mean job duration in ticks (lognormal)
    sigma_work: float = 1.0
    max_cpu_frac: float = 0.5        # max job size as a fraction of cpu_total
    oversub_prob: float = 0.02       # prob. a job exceeds its user entitlement
    class_mix: Sequence[float] = (0.2, 0.2, 0.6)  # non-preempt, preempt, ckpt
    equal_shares: bool = True
    seed: int = 0
    # checkpoint image sizes (heterogeneous C/R cost axis): lognormal MiB
    mean_state_mib: float = 512.0
    sigma_state: float = 1.2


def make_users(spec: WorkloadSpec, rng: Optional[np.random.Generator] = None) -> List[User]:
    rng = rng or np.random.default_rng(spec.seed)
    if spec.equal_shares:
        share = 100.0 / spec.n_users
        return [User(f"u{i}", share) for i in range(spec.n_users)]
    raw = rng.dirichlet(np.ones(spec.n_users) * 2.0) * 100.0
    return [User(f"u{i}", float(p)) for i, p in enumerate(raw)]


def make_jobs(spec: WorkloadSpec, users: List[User]) -> List[Job]:
    rng = np.random.default_rng(spec.seed + 1)
    jobs: List[Job] = []
    classes = [JobClass.NON_PREEMPTIBLE, JobClass.PREEMPTIBLE, JobClass.CHECKPOINTABLE]
    for u in users:
        entitled = max(1, int(u.percent / 100.0 * spec.cpu_total))
        # on/off burst modulation of the Poisson rate
        t = 0
        phase_on = True
        while t < spec.horizon:
            rate = spec.arrival_rate * (1 + spec.burstiness if phase_on else
                                        1 / (1 + spec.burstiness))
            gap = max(1, int(rng.exponential(1.0 / max(rate, 1e-9))))
            t += gap
            if t >= spec.horizon:
                break
            if rng.random() < 0.02:
                phase_on = not phase_on
            work = max(1, int(rng.lognormal(np.log(spec.mean_work), spec.sigma_work)))
            if rng.random() < spec.oversub_prob:
                # a job larger than the user's whole entitlement (paper §II)
                cpus = int(min(spec.cpu_total * spec.max_cpu_frac, entitled * 2))
            else:
                cpus = int(2 ** rng.integers(0, max(1, int(np.log2(entitled)) + 1)))
            cpus = max(1, min(cpus, int(spec.cpu_total * spec.max_cpu_frac)))
            job_class = classes[rng.choice(3, p=np.asarray(spec.class_mix))]
            jobs.append(Job(
                user=u.name, cpus=cpus, work=work,
                priority=int(rng.integers(0, 4)),
                job_class=job_class, submit_time=t,
            ))
    # Checkpoint image sizes, long-tailed like real training jobs.  Drawn
    # from a SEPARATE stream so the arrival/size/class draws above — and
    # therefore every schedule under a free cost model — stay bit-identical
    # to pre-cost-model workloads.
    rng_state = np.random.default_rng(spec.seed + 2)
    for job in jobs:
        mib = rng_state.lognormal(np.log(spec.mean_state_mib),
                                  spec.sigma_state)
        job.state_bytes = int(min(max(mib, 1.0), MAX_STATE_MIB)) * MIB
    return jobs


def arrival_stream(jobs: Iterable[Job]) -> Iterator[Job]:
    """Yield ``jobs`` in ascending ``(submit_time, id)`` order — the feed
    contract of `core.engine.simulate_stream` (the streaming engine pulls
    arrivals due before each segment's end, so the feed must be sorted)."""
    yield from sorted(jobs, key=lambda j: (j.submit_time, j.id))


def endless_arrivals(spec: WorkloadSpec,
                     users: Optional[List[User]] = None) -> Iterator[Job]:
    """Unbounded arrival stream for the streaming engine: epoch ``e`` draws
    a fresh `make_jobs` batch (seed ``spec.seed + 1000 * e``) and shifts its
    submit times by ``e * spec.horizon``, so arrivals flow forever in sorted
    order while only one epoch of Job objects is materialized at a time —
    the generator side of the bounded-memory story (the table side is
    `simulate_stream`'s fixed capacity)."""
    users = users if users is not None else make_users(spec)
    epoch = 0
    while True:
        batch = make_jobs(replace(spec, seed=spec.seed + 1000 * epoch), users)
        shift = epoch * spec.horizon
        for job in sorted(batch, key=lambda j: (j.submit_time, j.id)):
            job.submit_time += shift
            yield job
        epoch += 1


def reclaim_scenario(cpu_total: int = 256, quantum: int = 10):
    """The paper's headline scenario: user A idles while user B floods the
    machine with checkpointable jobs; A then submits an entitled job and
    must get its CPUs back ~immediately (memorylessness).

    Returns (users, jobs, the reclaiming job id)."""
    users = [User("A", 50.0), User("B", 50.0)]
    jobs = [
        Job(user="B", cpus=cpu_total // 4, work=10_000, priority=0,
            job_class=JobClass.CHECKPOINTABLE, submit_time=0)
        for _ in range(4)
    ]
    # NOTE: the claim is CHECKPOINTABLE, not NON_PREEMPTIBLE: Algorithm 1
    # line 23 uses ``>=``, so a non-preemptible job *exactly* equal to the
    # entitlement is always rejected (quirk kept faithfully; see DESIGN.md
    # and tests/test_omfs.py::test_line23_exact_entitlement_quirk).
    claim = Job(user="A", cpus=cpu_total // 2, work=200, priority=0,
                job_class=JobClass.CHECKPOINTABLE, submit_time=quantum + 50)
    jobs.append(claim)
    return users, jobs, claim.id


def oversub_scenario(cpu_total: int = 256):
    """A single job larger than its owner's whole entitlement must run when
    the machine is otherwise idle (paper §II, line 26)."""
    users = [User("A", 25.0), User("B", 75.0)]
    big = Job(user="A", cpus=int(cpu_total * 0.75), work=300,
              job_class=JobClass.CHECKPOINTABLE, submit_time=1)
    return users, [big], big.id


def thrashing_scenario(cpu_total: int = 64, quantum: int = 5,
                       n_claims: int = 12, state_gib: int = 64,
                       state_gibs: Optional[Sequence[int]] = None):
    """C/R cost materially changes the schedule (paper §III thrashing).

    User B fills the machine with long checkpointable jobs carrying *huge*
    checkpoint images; user A submits a periodic stream of short entitled
    claims, each of which evicts B's jobs.  Under a free cost model the
    eviction ping-pong is harmless; under a calibrated model every bounce
    charges B save+restore work proportional to ``state_gib``, so B's
    completions slide, later admissions see a different machine, and
    goodput drops — the schedules (not just the metrics) diverge.

    ``state_gibs`` (one GiB size per flood job, default four equal
    ``state_gib`` jobs) makes the flood heterogeneous — the regime where
    tiered eviction placement (snapshots compete for fast-tier capacity)
    and size-aware victim selection (`omfs_cheap_victim` prefers the
    cheap-to-checkpoint victims) change the schedule.

    Deterministic by construction (no RNG).  Returns ``(users, jobs)``;
    B's flood jobs are the ones with ``state_bytes > 0``."""
    users = [User("A", 50.0), User("B", 50.0)]
    if state_gibs is None:
        state_gibs = (state_gib,) * 4
    jobs = [
        Job(user="B", cpus=cpu_total // 4, work=300,
            job_class=JobClass.CHECKPOINTABLE, submit_time=0,
            state_bytes=gib << 30)
        for gib in state_gibs
    ]
    period = max(2 * quantum, 4)
    for i in range(n_claims):
        jobs.append(Job(
            user="A", cpus=cpu_total // 2, work=max(quantum, 4),
            job_class=JobClass.CHECKPOINTABLE,
            submit_time=quantum + 1 + i * period,
        ))
    return users, jobs
