"""Core scheduler types: users, jobs, job classes, events.

Terminology follows the paper: the resource unit is a "CPU" (for the TPU
adaptation read "chip"; `core.placement` adds slice-shape constraints on
top of the counts — Algorithm 1 itself only sees counts).
"""
from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional

from repro.core.crcost import CRCostModel, TieredCRCostModel, state_mib_of


class JobClass(enum.IntEnum):
    """Paper §II: non-preemptible jobs run only within the entitlement;
    preemptible (killable) and checkpointable (C/R-able) jobs may exceed it."""

    NON_PREEMPTIBLE = 0
    PREEMPTIBLE = 1        # may be killed on eviction
    CHECKPOINTABLE = 2     # transparently checkpointed on eviction (DMTCP)

    @property
    def is_preemptable(self) -> bool:
        return self != JobClass.NON_PREEMPTIBLE


class JobState(enum.IntEnum):
    UNSUBMITTED = 0
    PENDING = 1
    RUNNING = 2
    DONE = 3
    KILLED = 4             # evicted non-checkpointable job, dropped (line 34)


@dataclass(frozen=True)
class User:
    """An entity with a CPU entitlement expressed in percent (lines 7-9)."""

    name: str
    percent: float

    def entitled_cpus(self, cpu_total: int) -> int:
        # line 22: floor((percent / 100) * CPU_Total)
        return int((self.percent / 100.0) * cpu_total)


_job_ids = itertools.count()


@dataclass
class Job:
    """A job and its mutable runtime bookkeeping (lines 10-13 + our state)."""

    user: str
    cpus: int                      # j.CPU_Count
    work: int                      # total work units (ticks x its CPUs held)
    priority: int = 0              # j.priority — among the *user's* jobs
    job_class: JobClass = JobClass.CHECKPOINTABLE
    submit_time: int = 0
    state_bytes: int = 0           # checkpoint image size (C/R cost driver)
    id: int = field(default_factory=lambda: next(_job_ids))

    # runtime state
    state: JobState = JobState.UNSUBMITTED
    progress: int = 0              # work units completed
    run_start: int = -1            # tick the current run segment started
    first_start: int = -1
    finish_time: int = -1
    n_preemptions: int = 0
    n_checkpoints: int = 0
    overhead: int = 0              # extra work units added by C/R cost
    backfilled: bool = False       # admitted by jumping the queue (backfill)
    ckpt_tier: int = -1            # tier holding the latest snapshot (-1: none)
    n_spills: int = 0              # checkpoints placed beyond the fast tier

    @property
    def remaining(self) -> int:
        return self.work + self.overhead - self.progress

    @property
    def state_mib(self) -> int:
        return state_mib_of(self.state_bytes)

    def clone(self) -> "Job":
        return replace(self)


@dataclass(frozen=True)
class SchedulerConfig:
    """Policy knobs.  Defaults are paper-faithful; flags marked (beyond
    paper) are extensions measured separately in the benchmarks."""

    cpu_total: int = 256
    quantum: int = 30              # minimal uninterrupted run before evictable
    cr_overhead: int = 0           # legacy flat work units per checkpoint
    cr_cost: CRCostModel = CRCostModel()   # size-aware save/restore costs
    # per-tier cost models + eviction placement; takes precedence over
    # cr_cost when set (the flat cr_overhead still applies at every save)
    cr_tiers: Optional[TieredCRCostModel] = None
    drop_killed: bool = True       # line 34: non-checkpointable victims are dropped
    # ---- beyond-paper extensions (all default OFF for fidelity) ----
    victim_filter_over_entitlement: bool = False   # only evict over-entitlement users
    avoid_self_eviction: bool = False              # never evict the requester's jobs
    elastic_shrink: bool = False                   # shrink instead of full eviction

    # Which implementation serves the eviction machinery (victim sort,
    # capacity cutoff, tier placement) inside every C/R-aware pass:
    #   "lax"              — jnp.lexsort + lax.scan (default; best on CPU)
    #   "pallas"           — fused `kernels.sched_select`; interprets off-TPU
    #   "pallas_interpret" — same kernel, interpret forced (CI / tests)
    # The flag rides every lru-cached runner key (the config is the key), so
    # toggling it selects a separately cached runner — never a retrace.
    kernel_backend: str = "lax"

    # -- the one cost expression both backends share (DESIGN.md §Tier
    # placement): the JAX backend precomputes these per JobTable column with
    # Python-int arithmetic, the Python backend evaluates them at runtime —
    # bit-equality across backends holds because it is the same function.
    def tier_model(self, tier: int) -> CRCostModel:
        if self.cr_tiers is not None:
            return self.cr_tiers.tiers[tier]
        return self.cr_cost

    @property
    def n_cost_tiers(self) -> int:
        """Number of cost-lattice columns T (1 when untiered)."""
        return self.cr_tiers.n_tiers if self.cr_tiers is not None else 1

    def eviction_save_cost(self, state_mib: int, tier: int = 0,
                           recurrent: bool = False) -> int:
        """Work units charged when a checkpointable victim lands on ``tier``
        (legacy flat cr_overhead + the tier's size-dependent save cost).
        ``recurrent`` prices a re-eviction of a job that already saved a
        snapshot once — only the delta moves."""
        model = self.tier_model(tier)
        cost = model.recurrent_save_cost if recurrent else model.save_cost
        return self.cr_overhead + cost(state_mib)

    def restart_restore_cost(self, state_mib: int, tier: int = 0) -> int:
        """Work units charged when a checkpointed job restarts from ``tier``."""
        return self.tier_model(tier).restore_cost(state_mib)


@dataclass
class ClusterState:
    """The scheduler-visible state (System Init, lines 1-9)."""

    config: SchedulerConfig
    users: Dict[str, User]
    jobs: Dict[int, Job] = field(default_factory=dict)
    time: int = 0

    def __post_init__(self):
        total = sum(u.percent for u in self.users.values())
        assert total <= 100.0 + 1e-9, f"entitlements sum to {total} > 100 (line 9)"

    # -- queries used by the runner (lines 19-22) --------------------------
    def running_jobs(self) -> List[Job]:
        return [j for j in self.jobs.values() if j.state == JobState.RUNNING]

    def pending_jobs(self) -> List[Job]:
        return [j for j in self.jobs.values() if j.state == JobState.PENDING]

    def cpu_busy(self) -> int:
        return sum(j.cpus for j in self.running_jobs())

    @property
    def cpu_idle(self) -> int:
        return self.config.cpu_total - self.cpu_busy()

    def user_usage(self, user: str) -> Dict[str, int]:
        p_able = sum(
            j.cpus for j in self.running_jobs()
            if j.user == user and j.job_class.is_preemptable
        )
        non_p = sum(
            j.cpus for j in self.running_jobs()
            if j.user == user and not j.job_class.is_preemptable
        )
        return {"preemptable": p_able, "non_preemptable": non_p, "total": p_able + non_p}

    def entitled(self, user: str) -> int:
        return self.users[user].entitled_cpus(self.config.cpu_total)
