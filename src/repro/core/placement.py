"""Topology-aware slice placement (TPU adaptation of j.CPU_Count).

Algorithm 1 treats CPUs as fungible counts; TPU jobs need *contiguous*
slices of the torus.  A buddy allocator over the flattened chip space keeps
allocations power-of-two sized and aligned, which preserves torus locality
(standard practice for TPU slice scheduling).  The scheduler consults this
as a pluggable feasibility oracle: ``counting`` (paper-faithful) or
``buddy`` (gang placement with fragmentation).

Fragmentation is the interesting failure mode: the counting policy may admit
a job the buddy policy cannot place; benchmarks/bench_utilization.py reports
the utilization gap, and eviction picks victims that actually free a usable
block (`victims_for_block`).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple


def _round_pow2(n: int) -> int:
    p = 1
    while p < n:
        p <<= 1
    return p


@dataclass
class BuddyAllocator:
    """Buddy allocation over ``total`` chips (power of two)."""

    total: int
    free_blocks: Dict[int, Set[int]] = field(default_factory=dict)  # size -> offsets
    allocated: Dict[int, Tuple[int, int]] = field(default_factory=dict)  # job -> (off, size)

    def __post_init__(self):
        assert self.total & (self.total - 1) == 0, "total must be a power of two"
        if not self.free_blocks:
            self.free_blocks = {self.total: {0}}

    # -- queries -------------------------------------------------------------
    def can_place(self, cpus: int) -> bool:
        size = _round_pow2(max(cpus, 1))
        return any(s >= size and offs for s, offs in self.free_blocks.items())

    def largest_free(self) -> int:
        return max((s for s, offs in self.free_blocks.items() if offs), default=0)

    def free_chips(self) -> int:
        return sum(s * len(offs) for s, offs in self.free_blocks.items())

    # -- mutation --------------------------------------------------------------
    def place(self, job_id: int, cpus: int) -> Optional[Tuple[int, int]]:
        """First-fit smallest sufficient block; splits buddies as needed."""
        size = _round_pow2(max(cpus, 1))
        cand = sorted(s for s, offs in self.free_blocks.items() if s >= size and offs)
        if not cand:
            return None
        s = cand[0]
        off = min(self.free_blocks[s])
        self.free_blocks[s].discard(off)
        while s > size:  # split down to fit
            s //= 2
            self.free_blocks.setdefault(s, set()).add(off + s)
        self.allocated[job_id] = (off, size)
        return (off, size)

    def release(self, job_id: int) -> None:
        off, size = self.allocated.pop(job_id)
        # coalesce with buddy blocks as far as possible
        while size < self.total:
            buddy = off ^ size
            peers = self.free_blocks.get(size, set())
            if buddy in peers:
                peers.discard(buddy)
                off = min(off, buddy)
                size *= 2
            else:
                break
        self.free_blocks.setdefault(size, set()).add(off)

    # -- eviction planning ------------------------------------------------------
    def victims_for_block(self, cpus: int, candidates: List[Tuple[int, int]]) -> Optional[List[int]]:
        """Smallest set of candidate jobs [(job_id, victim_rank), ...] whose
        release (in rank order) makes a ``cpus`` block placeable.  Simulates
        releases on a copy; returns job ids or None."""
        sim = BuddyAllocator(
            self.total,
            {s: set(o) for s, o in self.free_blocks.items()},
            dict(self.allocated),
        )
        chosen: List[int] = []
        for job_id, _rank in candidates:
            if sim.can_place(cpus):
                break
            if job_id in sim.allocated:
                sim.release(job_id)
                chosen.append(job_id)
        return chosen if sim.can_place(cpus) else None
