"""Tick-based cluster simulator driving any scheduling policy.

Each tick:
  1. arrivals   — jobs with ``submit_time == t`` become PENDING,
  2. progress   — every running job accrues one work unit; completed jobs
                  free their CPUs,
  3. scheduling — one policy pass over the pending queue,
  4. metrics    — per-tick accounting (busy CPUs, per-user usage).

Tick-based (rather than event-driven) on purpose: the JAX fleet simulator
(`core.omfs_jax`) implements the *same* per-tick semantics with vectorized
ops, and property tests assert the two produce identical schedules.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.omfs import Decision, scheduler_pass
from repro.core.types import ClusterState, Job, JobState, SchedulerConfig, User

Policy = Callable[[ClusterState], List[Decision]]


@dataclass
class TickLog:
    time: int
    busy: int
    pending: int
    running: int
    per_user_cpus: Dict[str, int]
    decisions: List[Decision]


@dataclass
class SimResult:
    state: ClusterState
    log: List[TickLog]

    # -- headline metrics (see core.metrics for derived scores) ------------
    def utilization(self) -> float:
        cfg = self.state.config
        if not self.log:
            return 0.0
        return float(np.mean([t.busy for t in self.log]) / cfg.cpu_total)

    def job_table(self) -> List[Job]:
        return sorted(self.state.jobs.values(), key=lambda j: j.id)

    def schedule_signature(self):
        """Hashable summary used by the Python-vs-JAX equivalence tests."""
        return tuple(
            (j.id, int(j.state), j.first_start, j.finish_time, j.progress,
             j.n_preemptions, j.n_checkpoints)
            for j in self.job_table()
        )


def simulate(
    users: List[User],
    jobs: List[Job],
    config: SchedulerConfig,
    horizon: int,
    policy: Policy = scheduler_pass,
) -> SimResult:
    state = ClusterState(config=config, users={u.name: u for u in users})
    for j in jobs:
        j = j.clone()
        j.state = JobState.UNSUBMITTED
        state.jobs[j.id] = j

    log: List[TickLog] = []
    for t in range(horizon):
        state.time = t
        # 1. arrivals
        for j in state.jobs.values():
            if j.state == JobState.UNSUBMITTED and j.submit_time <= t:
                j.state = JobState.PENDING
        # 2. progress + completions (jobs that ran during the previous tick)
        for j in state.running_jobs():
            j.progress += 1
            if j.progress >= j.work + j.overhead:
                j.state = JobState.DONE
                j.finish_time = t
        # 3. scheduling
        decisions = policy(state)
        # 4. metrics
        per_user = {u: 0 for u in state.users}
        for j in state.running_jobs():
            per_user[j.user] += j.cpus
        log.append(TickLog(
            time=t, busy=state.cpu_busy(), pending=len(state.pending_jobs()),
            running=len(state.running_jobs()), per_user_cpus=per_user,
            decisions=decisions,
        ))
    return SimResult(state=state, log=log)
