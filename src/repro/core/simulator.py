"""Tick-based cluster simulator — thin adapter over `core.engine`.

The tick protocol (arrivals -> progress/completions -> policy pass ->
metrics) lives in `core.engine.tick_python`; this module keeps the
historical ``simulate(...) -> SimResult`` entry point and re-exports
`SimResult`/`TickLog` for existing imports (e.g. `core.metrics`).

Tick-based (rather than event-driven) on purpose: the JAX fleet backend
(`core.engine.tick_jax` + `core.omfs_jax`) implements the *same* per-tick
semantics with vectorized ops, and property tests assert the two produce
identical schedules for every registered policy.
"""
from __future__ import annotations

from typing import Callable, List

from repro.core import engine
from repro.core.engine import SimResult, TickLog  # noqa: F401  (re-exported)
from repro.core.omfs import Decision, scheduler_pass
from repro.core.types import ClusterState, Job, SchedulerConfig, User

Policy = Callable[[ClusterState], List[Decision]]


def simulate(
    users: List[User],
    jobs: List[Job],
    config: SchedulerConfig,
    horizon: int,
    policy: Policy = scheduler_pass,
) -> SimResult:
    res = engine.simulate(users, jobs, config, horizon,
                          policy=policy, backend="python")
    return res.sim
