"""Size-aware C/R cost model: the paper's thrashing-cost term, first-class.

The paper's argument is that transparent checkpoint-restart preemption is
cheap *because* the C/R cost is driven down by fast persistent-memory tiers
(SplitFS/NOVA over DCPMM, §III).  That cost is therefore not a constant: it
scales with the job's checkpoint image size and the tier's read/write
bandwidth, modulated by compression (delta/zstd/quantization, see
`checkpoint/`).  `CRCostModel` makes that relationship a deterministic,
integer-valued function every scheduler layer shares:

* ``save_cost(state_mib)``    — work units charged when a checkpointable
  victim is evicted (the snapshot write);
* ``restore_cost(state_mib)`` — work units charged when a previously
  checkpointed job is (re)started (the snapshot read).

Both are piecewise-linear — ``base + ceil(compressed_mib / mib_per_tick)``,
saturated at ``cap_ticks`` — so the same expression evaluates on Python
ints and on ``jnp.int32`` arrays, which is what keeps the Python reference
and the vectorized JAX backend bit-identical (DESIGN.md §C/R cost model).

The model is **delta-aware** (two-coefficient ``(first, recurrent)``): the
FIRST save of a job prices the full compressed image; every subsequent
save of the same job prices the *delta* against the previous snapshot —
``recurrent_save_cost`` moves ``ceil(c(m) * delta_num / delta_den)`` MiB
instead of ``c(m)``.  The coefficient lives on the same /256 rational grid
as compression; the default ``(1, 1)`` makes recurrent saves identical to
first saves (exact legacy behaviour).  `measured_delta_num` quantizes the
coefficient measured by ``benchmarks/bench_cr_cost.py``.

Determinism rules (load-bearing for cross-backend equality):

* all arithmetic is integer; ``ceil`` is ``(a + b - 1) // b``;
* sizes enter in MiB (``state_mib_of``), clamped to ``MAX_STATE_MIB`` so
  every intermediate fits int32 on the JAX side;
* the compression ratio is a rational ``compress_num / compress_den``
  (never a float) — ``from_stats`` quantizes measured ratios to /256ths.

``from_stats`` calibrates a model from measured tier statistics (bytes and
wall seconds — `checkpoint.tiers.TierStats` or the `CheckpointService`
aggregate), converting bandwidth to MiB per scheduler tick.  That is the
bridge from `benchmarks/bench_cr_cost.py`'s real measurements to a number
the jitted scheduling tick can consume.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Sequence, Tuple

MIB = 1 << 20
#: Largest checkpoint image the model distinguishes (1 TiB).  Beyond this
#: the cost saturates; the clamp keeps ``state_mib * compress_num`` inside
#: int32 for the JAX backend (2**20 MiB * num<=1024 < 2**31).
MAX_STATE_MIB = 1 << 20
#: Default cost saturation: no single C/R event is charged more than this.
DEFAULT_CAP_TICKS = 1 << 20


def _ceil_div(a, b):
    """Integer ceil-division that works on Python ints and jnp arrays."""
    return (a + b - 1) // b


def _saturate(v, cap: int):
    """min(v, cap) for Python ints and jnp arrays alike."""
    if isinstance(v, int):
        return min(v, cap)
    import jax.numpy as jnp

    return jnp.minimum(v, cap)


def state_mib_of(state_bytes: int) -> int:
    """Checkpoint image size in whole MiB (ceil), clamped to MAX_STATE_MIB.

    0 bytes -> 0 MiB (a job that declared no state is free to C/R under a
    pure-bandwidth model; the ``*_base`` terms still apply)."""
    if state_bytes <= 0:
        return 0
    return min(_ceil_div(int(state_bytes), MIB), MAX_STATE_MIB)


@dataclass(frozen=True)
class CRCostModel:
    """Deterministic integer C/R cost as a function of checkpoint size.

    ``save_cost(m)    = min(save_base    + ceil(c(m) * save_tick_den / save_mib_per_tick),       cap_ticks)``
    ``restore_cost(m) = min(restore_base + ceil(c(m) * restore_tick_den / restore_mib_per_tick), cap_ticks)``
    with ``c(m) = ceil(m * compress_num / compress_den)`` the compressed
    image size.  Bandwidth is the RATIONAL ``save_mib_per_tick /
    save_tick_den`` MiB per tick (den=1 for hand-written models; calibration
    quantizes to /256ths so tiers slower than 1 MiB/tick are still priced
    correctly instead of floored to 1).  ``save_mib_per_tick <= 0`` means
    "free transfer" (only the base term is charged).  The all-defaults
    model charges nothing — legacy ``SchedulerConfig.cr_overhead``
    behaviour is exactly preserved.

    Hashable (frozen) on purpose: it rides `SchedulerConfig`, which is a
    static jit argument and an `lru_cache` key for the compiled tick scans.
    """

    save_mib_per_tick: int = 0       # fast-tier write bandwidth numerator
    restore_mib_per_tick: int = 0    # fast-tier read bandwidth numerator
    save_base: int = 0               # fixed per-checkpoint work units
    restore_base: int = 0            # fixed per-restore work units
    compress_num: int = 1            # effective bytes = raw * num / den
    compress_den: int = 1
    save_tick_den: int = 1           # bandwidth = mib_per_tick / tick_den
    restore_tick_den: int = 1
    cap_ticks: int = DEFAULT_CAP_TICKS
    delta_num: int = 1               # recurrent save moves c(m) * num / den
    delta_den: int = 1

    def __post_init__(self):
        assert self.compress_num >= 0 and self.compress_den >= 1
        # int32 safety on the JAX side: compressed mib <= 4 * MAX_STATE_MIB
        # = 2**22, times tick_den <= 256 stays under 2**31
        assert self.compress_num <= 4 * self.compress_den, \
            "compression ratio must be <= 4 (quantize to num/den)"
        assert self.compress_num <= 1024 and self.compress_den <= 256, \
            "keep num/den small: state_mib * num must fit int32"
        assert 1 <= self.save_tick_den <= 256
        assert 1 <= self.restore_tick_den <= 256
        assert self.cap_ticks >= 0
        # a delta can never move more than the full image, and the /256 cap
        # keeps compressed_mib * delta_num inside int32 (2**22 * 256 = 2**30)
        assert 1 <= self.delta_den <= 256
        assert 0 <= self.delta_num <= self.delta_den, \
            "recurrent saves move at most the full image (num <= den)"

    # -- the model ----------------------------------------------------------
    def compressed_mib(self, state_mib):
        """Effective MiB moved after compression (int or jnp array)."""
        return _ceil_div(state_mib * self.compress_num, self.compress_den)

    def delta_mib(self, state_mib):
        """Effective MiB a RECURRENT save moves: the delta against the
        previous snapshot, ``ceil(c(m) * delta_num / delta_den)``."""
        return _ceil_div(self.compressed_mib(state_mib) * self.delta_num,
                         self.delta_den)

    def _cost(self, moved, mib_per_tick: int, tick_den: int, base: int):
        if mib_per_tick > 0:
            var = _ceil_div(moved * tick_den, mib_per_tick)
        else:
            var = moved * 0                      # free transfer, keep shape
        return _saturate(base + var, self.cap_ticks)

    def save_cost(self, state_mib):
        """Work units charged at a job's FIRST eviction-checkpoint (full
        image); int in, int out — or elementwise over a jnp int32 array."""
        return self._cost(self.compressed_mib(state_mib),
                          self.save_mib_per_tick,
                          self.save_tick_den, self.save_base)

    def recurrent_save_cost(self, state_mib):
        """Work units charged when a job that already holds a previous
        snapshot is evicted again — only the delta is moved."""
        return self._cost(self.delta_mib(state_mib),
                          self.save_mib_per_tick,
                          self.save_tick_den, self.save_base)

    def restore_cost(self, state_mib):
        """Work units charged at restart-restore (same polymorphism)."""
        return self._cost(self.compressed_mib(state_mib),
                          self.restore_mib_per_tick,
                          self.restore_tick_den, self.restore_base)

    @property
    def is_free(self) -> bool:
        """True iff the model never charges anything (the legacy default)."""
        return (self.save_base == 0 and self.restore_base == 0
                and self.save_mib_per_tick <= 0
                and self.restore_mib_per_tick <= 0) or self.cap_ticks == 0

    # -- calibration --------------------------------------------------------
    @classmethod
    def from_measured(
        cls,
        *,
        save_bytes_per_s: float,
        restore_bytes_per_s: float,
        tick_seconds: float,
        compress_ratio: float = 1.0,
        save_base: int = 0,
        restore_base: int = 0,
        cap_ticks: int = DEFAULT_CAP_TICKS,
        delta_ratio: float = 1.0,
    ) -> "CRCostModel":
        """Build a model from measured bandwidths.

        ``tick_seconds`` is the wall-clock length of one scheduler tick —
        the single unit conversion between the real executor and the
        simulator.  Bandwidths quantize to /256ths of a MiB per tick
        (floor of the representable grid, min 1/256), so tiers slower than
        1 MiB/tick are charged their real cost instead of being flattened
        to 1 MiB/tick; ``compress_ratio`` (stored/raw) quantizes to
        /256ths too.  NOTE: pass ``compress_ratio`` only when the measured
        bandwidth was taken on *raw* traffic that will additionally be
        compressed — stats whose wall time already includes compression
        (e.g. `CheckpointService` save timings) are an *effective* raw
        bandwidth and want the default 1.0.  ``delta_ratio`` is the
        measured recurrent-save fraction (delta bytes / full image bytes,
        see `measured_delta_num`); it quantizes to /256ths as well.
        """
        def mib_per_tick(bps: float):
            if bps <= 0:
                return 0
            return max(1, int(round(bps * tick_seconds / MIB * 256)))

        num = max(0, min(1024, int(round(compress_ratio * 256))))
        dnum = max(0, min(256, int(round(delta_ratio * 256))))
        return cls(
            save_mib_per_tick=mib_per_tick(save_bytes_per_s),
            restore_mib_per_tick=mib_per_tick(restore_bytes_per_s),
            save_base=save_base,
            restore_base=restore_base,
            compress_num=num,
            compress_den=256,
            save_tick_den=256,
            restore_tick_den=256,
            cap_ticks=cap_ticks,
            delta_num=dnum,
            delta_den=256,
        )

    @classmethod
    def from_stats(cls, stats: Any, *, tick_seconds: float,
                   compress_ratio: float = 1.0, save_base: int = 0,
                   restore_base: int = 0,
                   cap_ticks: int = DEFAULT_CAP_TICKS,
                   delta_ratio: float = 1.0) -> "CRCostModel":
        """Calibrate from measured tier statistics.

        ``stats`` is anything exposing bytes/seconds counters —
        `checkpoint.tiers.TierStats` (``bytes_written``/``bytes_read``,
        ``save_seconds``/``restore_seconds``) or the `CheckpointService`
        aggregate (``bytes_saved``/``bytes_restored``).  Missing restore
        traffic falls back to the save-side bandwidth (write-limited tiers).
        """
        saved = getattr(stats, "bytes_saved", None)
        if saved is None:
            saved = getattr(stats, "bytes_written", 0)
        restored = getattr(stats, "bytes_restored", None)
        if restored is None:
            restored = getattr(stats, "bytes_read", 0)
        t_save = getattr(stats, "save_seconds", 0.0)
        t_rest = getattr(stats, "restore_seconds", 0.0)

        save_bps = saved / t_save if (saved and t_save > 0) else 0.0
        restore_bps = restored / t_rest if (restored and t_rest > 0) else 0.0
        if restore_bps <= 0:
            restore_bps = save_bps
        return cls.from_measured(
            save_bytes_per_s=save_bps, restore_bytes_per_s=restore_bps,
            tick_seconds=tick_seconds, compress_ratio=compress_ratio,
            save_base=save_base, restore_base=restore_base,
            cap_ticks=cap_ticks, delta_ratio=delta_ratio)

    # -- executor accounting -------------------------------------------------
    @staticmethod
    def ticks_from_seconds(seconds: float, tick_seconds: float) -> int:
        """Measured wall time -> whole scheduler ticks (ceil, >= 0).

        The real executor charges *measured* C/R overhead through this so
        simulation (predicted, via save/restore_cost) and execution agree
        on units."""
        if seconds <= 0 or tick_seconds <= 0:
            return 0
        return int(math.ceil(seconds / tick_seconds))


#: `TieredCRCostModel.capacity_mib` convention: a negative capacity means
#: "unbounded" (the durable/spill tier); 0 means the tier holds nothing.
UNBOUNDED = -1

#: Measured recurrent-save coefficients from `benchmarks/bench_cr_cost.py`:
#: a delta-chunk zstd-compresses to 0.549 of its raw size, and on average
#: 0.64 of a recurrent image is dirty (the rest dedups against the previous
#: snapshot).  The blended per-image coefficient is
#: ``frac * ratio + (1 - frac)`` — dirty chunks move at the delta ratio,
#: clean chunks still cost their (tiny) dedup-index entry ~ full weight.
MEASURED_DELTA_ZSTD = 0.549
MEASURED_DELTA_FRAC = 0.64


def measured_delta_num(ratio: float = MEASURED_DELTA_ZSTD,
                       frac: float = MEASURED_DELTA_FRAC) -> int:
    """Quantize the blended recurrent-save coefficient to the /256 grid.

    With the measured defaults: 0.64 * 0.549 + 0.36 = 0.71136 -> 182.
    Pass the result as ``CRCostModel(delta_num=..., delta_den=256)``.
    This is a float->grid calibration boundary like `from_measured`; the
    models themselves stay integer-only.
    """
    eff = frac * ratio + (1.0 - frac)
    return max(0, min(256, int(round(eff * 256))))


@dataclass(frozen=True)
class TieredCRCostModel:
    """A bank of per-tier C/R cost models with capacities — mem vs. disk.

    Mirrors the real checkpoint subsystem (`checkpoint.manager`): tier 0 is
    the fast tier (MemTier, capacity-bounded like DCPMM), the last tier is
    the durable spill target (DiskTier, unbounded).  Each eviction *places*
    the victim's snapshot on a tier — greedy cheapest-feasible, see
    ``choose_tier`` — and the chosen tier prices both the save (charged at
    eviction) and the later restore (charged at restart).  This replaces
    the single-tier assumption of `SchedulerConfig.cr_cost` when set as
    ``SchedulerConfig.cr_tiers`` (which then takes precedence).

    Determinism rules (cross-backend bit-equality, same as `CRCostModel`):

    * ``capacity_mib`` entries are integers on the same whole-MiB grid as
      ``state_mib_of``; negative = ``UNBOUNDED``, 0 = holds nothing;
    * occupancy of a tier is the sum of ``state_mib`` over jobs currently
      *holding* a snapshot there (evicted-and-pending); a restore consumes
      the snapshot (the slot frees when the job restarts);
    * placement is greedy in victim order: earlier victims claim capacity
      first, later ones spill — both backends walk victims in the same
      order, so placements agree by construction.

    Hashable (frozen, tuple fields) on purpose: it rides `SchedulerConfig`,
    a static jit argument and compilation-cache key.
    """

    tiers: Tuple[CRCostModel, ...]
    capacity_mib: Tuple[int, ...]

    def __post_init__(self):
        assert len(self.tiers) >= 1
        assert len(self.tiers) == len(self.capacity_mib), \
            "one capacity per tier"
        assert all(isinstance(m, CRCostModel) for m in self.tiers)
        assert self.capacity_mib[-1] < 0, \
            "the last tier is the spill target and must be UNBOUNDED (<0)"

    @property
    def n_tiers(self) -> int:
        return len(self.tiers)

    def save_cost(self, tier: int, state_mib):
        return self.tiers[tier].save_cost(state_mib)

    def recurrent_save_cost(self, tier: int, state_mib):
        return self.tiers[tier].recurrent_save_cost(state_mib)

    def restore_cost(self, tier: int, state_mib):
        return self.tiers[tier].restore_cost(state_mib)

    def feasible(self, tier: int, state_mib: int, occupied_mib: int) -> bool:
        cap = self.capacity_mib[tier]
        return cap < 0 or occupied_mib + state_mib <= cap

    def choose_tier(self, state_mib: int, occupied_mib: Sequence[int],
                    recurrent: bool = False) -> int:
        """Greedy cheapest-feasible placement for one eviction.

        Among tiers with room for ``state_mib`` on top of ``occupied_mib``,
        pick the one with the lowest save cost (ties break toward the
        lower/faster tier index).  If nothing fits, spill to the last tier
        (always feasible by the UNBOUNDED invariant).  ``recurrent`` prices
        the placement with the delta coefficient — a warm job shops for a
        tier with its real (smaller) write in hand."""
        cost = (self.recurrent_save_cost if recurrent else self.save_cost)
        best = self.n_tiers - 1
        best_cost = cost(best, state_mib)
        for k in range(self.n_tiers - 1):
            if not self.feasible(k, state_mib, occupied_mib[k]):
                continue
            c = cost(k, state_mib)
            if c < best_cost or (c == best_cost and k < best):
                best, best_cost = k, c
        return best

    @classmethod
    def from_stats(cls, tier_stats: Sequence[Any], *, tick_seconds: float,
                   capacity_mib: Sequence[int],
                   compress_ratio: float = 1.0,
                   cap_ticks: int = DEFAULT_CAP_TICKS,
                   delta_ratio: float = 1.0) -> "TieredCRCostModel":
        """Calibrate one model per measured tier (mirrors
        `CheckpointManager`'s MemTier/DiskTier stats pair).

        ``tier_stats`` is a sequence of TierStats-shaped objects, fastest
        tier first; a tier with no measured save traffic inherits the
        fastest *measured* tier's model (conservative: never prices an
        unmeasured tier as free).  ``capacity_mib[-1]`` is forced to
        UNBOUNDED — the durable tier is the spill target."""
        models = []
        fallback = None
        for st in tier_stats:
            saved = getattr(st, "bytes_saved", None)
            if saved is None:
                saved = getattr(st, "bytes_written", 0)
            if saved and getattr(st, "save_seconds", 0.0) > 0:
                m = CRCostModel.from_stats(
                    st, tick_seconds=tick_seconds,
                    compress_ratio=compress_ratio, cap_ticks=cap_ticks,
                    delta_ratio=delta_ratio)
                if fallback is None:
                    fallback = m
            else:
                m = None
            models.append(m)
        if fallback is None:
            raise ValueError("no tier has measured save traffic")
        tiers = tuple(m if m is not None else fallback for m in models)
        caps = tuple(int(c) for c in capacity_mib[:-1]) + (UNBOUNDED,)
        return cls(tiers=tiers, capacity_mib=caps)
