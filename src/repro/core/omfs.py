"""OMFS: the paper's Algorithm 1, line-for-line Python reference.

``runner`` is MEMORYLESS FAIR-SHARE RUNNER (lines 18-38); ``scheduler_pass``
is one sweep of MEMORYLESS FAIR-SHARE SCHEDULER (lines 14-17) adapted to
discrete-event form: the paper's infinite dequeue loop becomes "try every
submitted job once per event, in queue order" (re-enqueued jobs wait for the
next event, exactly like line 24/29 re-enqueues).

Paper quirks preserved deliberately (validated by tests, discussed in
DESIGN.md):
* line 23 uses ``>=``: a non-preemptible job that would *exactly* fill the
  user's entitlement is rejected.
* line 26 uses ``>`` (strictly more idle CPUs than requested); the
  equal-idle case falls through to the entitlement check.
* lines 32-36 evict the least-prioritized running jobs regardless of owner;
  the ``victim_filter_over_entitlement`` / ``avoid_self_eviction`` flags are
  our (beyond-paper, default-off) refinements.
* line 34: evicted non-checkpointable jobs are dropped (killed), unless
  ``drop_killed=False`` (restart-from-zero re-queue).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

from repro.core.queues import cheap_victim_key, sorted_pending, sorted_victims
from repro.core.types import ClusterState, Job, JobClass, JobState


@dataclass
class Decision:
    """Outcome of one runner invocation, for logging/testing."""

    job_id: int
    admitted: bool
    reason: str
    evicted: List[int] = field(default_factory=list)
    checkpointed: List[int] = field(default_factory=list)
    killed: List[int] = field(default_factory=list)


def _start(state: ClusterState, job: Job) -> None:
    if job.n_checkpoints > 0:
        # transparent restore from the latest snapshot: charge the
        # size-dependent read cost of the tier the snapshot was PLACED on
        # at eviction (restart after a kill with drop_killed=False restarts
        # from scratch -> n_checkpoints == 0, nothing to restore)
        tier = max(job.ckpt_tier, 0)
        job.overhead += state.config.restart_restore_cost(job.state_mib, tier)
    # the restore consumes the snapshot: its tier slot frees for the next
    # victim (matches omfs_jax.admit_job clearing ckpt_tier)
    job.ckpt_tier = -1
    job.state = JobState.RUNNING
    job.run_start = state.time
    if job.first_start < 0:
        job.first_start = state.time


def _tier_occupancy(state: ClusterState) -> List[int]:
    """MiB of snapshot state currently held per tier: evicted-and-pending
    jobs whose latest checkpoint was placed there.  Recomputed per eviction
    in this reference backend (O(J)); the JAX twin folds the same sum into
    the eviction branch (`omfs_jax.apply_evictions`)."""
    occ = [0] * state.config.cr_tiers.n_tiers
    for j in state.jobs.values():
        if j.state == JobState.PENDING and j.ckpt_tier >= 0:
            occ[j.ckpt_tier] += j.state_mib
    return occ


def _evict(state: ClusterState, victim: Job, dec: Decision) -> None:
    """Lines 33-36: checkpoint (or drop) the victim and free its CPUs."""
    dec.evicted.append(victim.id)
    victim.n_preemptions += 1
    if victim.job_class == JobClass.CHECKPOINTABLE:
        # delta-aware: a job that already checkpointed once only writes the
        # delta on every later save — decide BEFORE bumping the counter.
        recurrent = victim.n_checkpoints > 0
        victim.n_checkpoints += 1
        # snapshot write: place the image on a tier (greedy cheapest-
        # feasible, spilling past full tiers), then charge the legacy flat
        # term + that tier's size-dependent save cost.  Victims evicted
        # earlier in the same pass already occupy their tier (they are
        # PENDING by now), so placement is sequential-greedy by construction.
        tiers = state.config.cr_tiers
        if tiers is not None:
            tier = tiers.choose_tier(victim.state_mib, _tier_occupancy(state),
                                     recurrent=recurrent)
        else:
            tier = 0
        victim.ckpt_tier = tier
        if tier > 0:
            victim.n_spills += 1
        victim.overhead += state.config.eviction_save_cost(
            victim.state_mib, tier, recurrent=recurrent)
        victim.state = JobState.PENDING          # line 35: back to Jobs_Submitted
        # memoryless: re-queued with its original priority; progress is kept
        # (transparent C/R) — the whole point of the paper.
        dec.checkpointed.append(victim.id)
    else:
        # line 34: "if it is not checkpointable, drop it"
        if state.config.drop_killed:
            victim.state = JobState.KILLED
            victim.finish_time = state.time
        else:
            victim.state = JobState.PENDING
            victim.progress = 0                  # restart from scratch
        dec.killed.append(victim.id)
    victim.run_start = -1


def runner(state: ClusterState, job: Job, *,
           cheap_victims: bool = False) -> Decision:
    """MEMORYLESS FAIR-SHARE RUNNER (lines 18-38) for one submitted job.

    ``cheap_victims`` (beyond paper, the `omfs_cheap_victim` policy) orders
    victims by ``(save_cost, priority, run_start, id)`` instead of the
    paper's ``(priority, run_start, id)`` — prefer the victims whose
    checkpoints are cheapest to write."""
    cfg = state.config
    dec = Decision(job_id=job.id, admitted=False, reason="")

    usage = state.user_usage(job.user)                        # lines 19-21
    entitled = state.entitled(job.user)                       # line 22

    # line 23: non-preemptible jobs must stay strictly inside the entitlement
    if (not job.job_class.is_preemptable) and (
        usage["non_preemptable"] + job.cpus >= entitled
    ):
        dec.reason = "non-preemptible exceeds entitlement (line 23)"
        return dec                                            # lines 24-25

    # line 26: enough idle resources -> run anyways (even over entitlement)
    if state.cpu_idle > job.cpus:
        _start(state, job)
        dec.admitted, dec.reason = True, "idle resources (line 26)"
        return dec                                            # line 27 (goto 37)

    # line 28: does the request fit in the user's unused entitlement?
    if job.cpus > entitled - usage["total"]:
        dec.reason = "exceeds unused entitlement, no idle (line 28)"
        return dec                                            # lines 29-30

    # lines 31-36: user is entitled; make room by evicting running jobs
    victims = sorted_victims(
        state, key=cheap_victim_key(state) if cheap_victims else None)
    if cfg.victim_filter_over_entitlement:                    # beyond paper
        victims = [
            v for v in victims
            if state.user_usage(v.user)["total"] > state.entitled(v.user)
        ]
    if cfg.avoid_self_eviction:                               # beyond paper
        victims = [v for v in victims if v.user != job.user]

    freed = 0
    planned: List[Job] = []
    for v in victims:                                         # line 32 loop
        if state.cpu_idle + freed >= job.cpus:
            break
        planned.append(v)
        freed += v.cpus
    if state.cpu_idle + freed < job.cpus:
        # not enough evictable capacity (all within quantum): wait
        dec.reason = "insufficient evictable capacity (quantum)"
        return dec

    for v in planned:
        _evict(state, v, dec)                                 # lines 33-36
    _start(state, job)                                        # lines 37-38
    dec.admitted = True
    dec.reason = "entitled, evicted to fit (lines 31-38)" if planned else \
        "entitled, idle exactly sufficient (lines 31-38)"
    return dec


def scheduler_pass(state: ClusterState, *,
                   cheap_victims: bool = False) -> List[Decision]:
    """One sweep of the MEMORYLESS FAIR-SHARE SCHEDULER (lines 14-17).

    Tries each pending job once, in submitted-queue order.  Jobs admitted
    earlier in the pass change the state seen by later jobs (CPU counts,
    running queue) — same as the paper's sequential dequeue loop.
    """
    decisions = []
    for job in sorted_pending(state):
        if job.state != JobState.PENDING:      # may have been evicted/killed
            continue
        decisions.append(runner(state, job, cheap_victims=cheap_victims))
    return decisions


def cheap_victim_pass(state: ClusterState) -> List[Decision]:
    """`omfs_cheap_victim`: Algorithm 1 with size-aware victim selection."""
    return scheduler_pass(state, cheap_victims=True)
