"""Scheduler metrics: utilization, fairness, reclaim latency, C/R overhead.

These quantify the paper's qualitative claims (it has no tables of its own):
utilization vs. the capping-style baselines, entitlement fairness as
"no justified complaints" (a user with pending demand and usage below its
entitlement), and the thrashing cost of recurrent C/R.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, List

import numpy as np

from repro.core.simulator import SimResult
from repro.core.types import JobState


@dataclass
class Metrics:
    utilization: float
    jain_fairness: float                 # over per-user normalized usage
    mean_wait: float
    p95_wait: float
    mean_slowdown: float
    throughput: float                    # done jobs / horizon
    killed_jobs: int
    preemptions: int
    checkpoints: int
    spilled_checkpoints: int             # placed beyond the fast tier (cr_tiers)
    cr_overhead_units: int               # work units burned by C/R
    goodput: float                       # useful cpu-ticks / machine capacity
    wasted_work_frac: float              # executed cpu-ticks lost to C/R + kills
    violation_ticks: float               # mean ticks/user with a justified complaint
    reclaim_latency: Dict[int, int]      # job id -> ticks from submit to first start

    def row(self) -> Dict[str, float]:
        d = self.__dict__.copy()
        d.pop("reclaim_latency")
        return d


def compute_metrics(result: SimResult) -> Metrics:
    state = result.state
    cfg = state.config
    horizon = len(result.log)
    jobs = result.job_table()

    util = result.utilization()

    # Jain index over sum of per-user cpu-ticks, normalized by entitlement.
    per_user = {u: 0.0 for u in state.users}
    for tick in result.log:
        for u, c in tick.per_user_cpus.items():
            per_user[u] += c
    norm = np.array([
        per_user[u] / max(state.entitled(u), 1) for u in state.users
    ])
    if norm.sum() <= 0:
        jain = 1.0
    else:
        jain = float(norm.sum() ** 2 / (len(norm) * (norm ** 2).sum() + 1e-12))

    waits, slowdowns = [], []
    reclaim = {}
    for j in jobs:
        if j.first_start >= 0:
            waits.append(j.first_start - j.submit_time)
            reclaim[j.id] = j.first_start - j.submit_time
        if j.state == JobState.DONE:
            span = max(j.finish_time - j.submit_time, 1)
            slowdowns.append(span / max(j.work, 1))

    # "justified complaint": at tick t, user has pending jobs that would fit
    # inside its unused entitlement, yet is below its entitlement.
    violations = np.zeros(horizon)
    pending_by_tick: Dict[int, List] = {}
    for t, tick in enumerate(result.log):
        v = 0
        for u in state.users:
            used = tick.per_user_cpus[u]
            ent = state.entitled(u)
            if used < ent and tick.pending > 0:
                # approximation at log granularity; exact per-user pending
                # sizes are checked in the property tests instead
                v += 1 if any(
                    d.job_id in state.jobs
                    and state.jobs[d.job_id].user == u
                    and not d.admitted
                    and state.jobs[d.job_id].cpus <= ent - used
                    for d in tick.decisions
                ) else 0
        violations[t] = v

    # goodput / wasted work (the paper's thrashing-cost term): progress
    # toward `work` is useful; overhead units and killed jobs' progress are
    # cpu-ticks the machine executed but the users never benefit from
    useful = sum(
        min(j.progress, j.work) * j.cpus
        for j in jobs if j.state != JobState.KILLED
    )
    executed = sum(j.progress * j.cpus for j in jobs)
    goodput = useful / max(cfg.cpu_total * horizon, 1)
    wasted_frac = (executed - useful) / max(executed, 1)

    done = [j for j in jobs if j.state == JobState.DONE]
    metrics = Metrics(
        utilization=util,
        jain_fairness=jain,
        mean_wait=float(np.mean(waits)) if waits else 0.0,
        p95_wait=float(np.percentile(waits, 95)) if waits else 0.0,
        mean_slowdown=float(np.mean(slowdowns)) if slowdowns else 0.0,
        throughput=len(done) / max(horizon, 1),
        killed_jobs=sum(1 for j in jobs if j.state == JobState.KILLED),
        preemptions=sum(j.n_preemptions for j in jobs),
        checkpoints=sum(j.n_checkpoints for j in jobs),
        spilled_checkpoints=sum(j.n_spills for j in jobs),
        cr_overhead_units=sum(j.overhead for j in jobs),
        goodput=goodput,
        wasted_work_frac=wasted_frac,
        violation_ticks=float(violations.mean()),
        reclaim_latency=reclaim,
    )
    return metrics


def event_summary(events: Iterable) -> Dict[str, float]:
    """Reconciliation view of an `repro.obs` event log: the subset of
    `Metrics` that is derivable from lifecycle events alone.

    The point of this function is the cross-check, not novelty: for an
    instrumented run, ``event_summary(result.events)`` must agree with the
    table-derived numbers (``preemptions`` == sum of ``n_preemptions``,
    ``checkpoints`` == sum of ``n_checkpoints``, per-job wait == DEFER
    count, ...) — the property tests assert it, so a drift between the
    event capture and the engine's own bookkeeping is a test failure, not
    a silent skew in the dashboards.
    """
    from repro.obs.events import EventType

    by_type = {e: 0 for e in EventType}
    defers: Dict[int, int] = {}
    starts: Dict[int, int] = {}
    restores = 0
    for ev in events:          # events arrive in canonical (tick,...) order
        by_type[EventType(ev.etype)] += 1
        if ev.etype == EventType.DEFER and ev.jid not in starts:
            # pre-first-start waiting only: post-eviction requeue ticks are
            # churn, not wait (matches first_start - submit_time)
            defers[ev.jid] = defers.get(ev.jid, 0) + 1
        elif ev.etype == EventType.START:
            starts.setdefault(ev.jid, ev.tick)
        elif ev.etype == EventType.RESTORE:
            restores += 1
    waits = [defers.get(jid, 0) for jid in starts]
    return {
        **{f"n_{e.name.lower()}": n for e, n in by_type.items()},
        "preemptions": by_type[EventType.EVICT],
        "checkpoints": by_type[EventType.SAVE],
        "spilled_checkpoints": by_type[EventType.SPILL],
        "restores": restores,
        "jobs_started": len(starts),
        "jobs_done": by_type[EventType.FINISH],
        "mean_wait": float(np.mean(waits)) if waits else 0.0,
        "p95_wait": float(np.percentile(waits, 95)) if waits else 0.0,
    }
