"""Unified scheduling engine: one tick kernel, pluggable policies, two backends.

The tick protocol is defined ONCE here and shared by every consumer:

  1. arrivals   — jobs with ``submit_time <= t`` become PENDING,
  2. progress   — every running job accrues one work unit; completed jobs
                  free their CPUs,
  3. scheduling — one policy pass over the pending-queue snapshot,
  4. metrics    — per-tick accounting (busy CPUs, per-user usage).

``tick_python`` runs it over `core.types.ClusterState` with any Python
policy (`core.omfs.scheduler_pass`, `core.baselines.*`, or user callables);
``tick_jax`` runs the identical semantics over the fixed-size `JobTable`
(`core.omfs_jax`) with any vectorized pass.  `core.simulator`,
`core.omfs_jax.simulate_jax`, and `cluster.executor.ClusterExecutor` are
thin adapters over these two kernels — there is no other tick loop in the
repo (DESIGN.md §Engine).

``simulate(users, jobs, cfg, horizon, policy=..., backend=...)`` is the
single entry point: every registered policy runs on every backend, and
`EngineResult.signature()` is directly comparable across backends, which is
what the per-policy Python-vs-JAX property tests assert.
"""
from __future__ import annotations

import contextlib
import functools
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import omfs_jax, policies_jax
from repro.core.baselines import ALL_BASELINES
from repro.core.omfs import Decision, cheap_victim_pass, scheduler_pass
from repro.core.types import ClusterState, Job, JobState, SchedulerConfig, User

#: reusable no-op context (profiling-off paths in `simulate_stream`)
_NULLCTX = contextlib.nullcontext()

PythonPolicy = Callable[[ClusterState], List[Decision]]
# JAX policy contract: pass_fn(cfg, entitled[U], t, JobTable) -> JobTable
JaxPass = Callable[[SchedulerConfig, jax.Array, jax.Array, "omfs_jax.JobTable"],
                   "omfs_jax.JobTable"]
JaxPassFactory = Callable[[Optional[int]], JaxPass]


# ---------------------------------------------------------------------------
# Policy registry: every policy names its Python pass and its JAX-pass factory
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PolicySpec:
    name: str
    python_pass: PythonPolicy
    jax_factory: JaxPassFactory


POLICIES: Dict[str, PolicySpec] = {}


def register_policy(name: str, python_pass: PythonPolicy,
                    jax_factory: JaxPassFactory) -> PolicySpec:
    spec = PolicySpec(name, python_pass, jax_factory)
    POLICIES[name] = spec
    return spec


register_policy("omfs", scheduler_pass,
                lambda pass_depth=None: omfs_jax.make_omfs_pass(pass_depth))
# beyond-paper OMFS variant: size-aware victim selection — evict the
# cheapest-to-checkpoint victims first (DESIGN.md §Tier placement)
register_policy(
    "omfs_cheap_victim", cheap_victim_pass,
    lambda pass_depth=None: omfs_jax.make_omfs_pass(pass_depth,
                                                    cheap_victims=True))
for _name, _factory in policies_jax.JAX_BASELINES.items():
    register_policy(_name, ALL_BASELINES[_name], _factory)


def _resolve_python(policy: Union[str, PythonPolicy]) -> PythonPolicy:
    if callable(policy):
        return policy
    if policy not in POLICIES:
        raise ValueError(
            f"unknown policy {policy!r}; known: {sorted(POLICIES)}")
    return POLICIES[policy].python_pass


# ---------------------------------------------------------------------------
# The tick kernel — Python backend
# ---------------------------------------------------------------------------


def tick_python(
    state: ClusterState,
    policy: PythonPolicy,
    *,
    work_fn: Optional[Callable[[Job], None]] = None,
    on_complete: Optional[Callable[[Job], None]] = None,
) -> Tuple[List[Decision], List[Tuple[Job, JobState, JobState]]]:
    """One tick at ``state.time``: arrivals -> progress -> policy pass.

    ``work_fn(job)`` is called for each running job before its progress
    accrues (the executor runs real optimizer steps here); ``on_complete``
    fires when a job finishes.  Returns the pass's decisions plus the state
    transitions it caused, ``[(job, was, now), ...]``, so adapters can react
    (checkpoint on eviction, restore on restart) without re-deriving them.
    """
    t = state.time
    # 1. arrivals
    for j in state.jobs.values():
        if j.state == JobState.UNSUBMITTED and j.submit_time <= t:
            j.state = JobState.PENDING
    # 2. progress + completions (jobs that ran during the previous tick)
    for j in state.running_jobs():
        if work_fn is not None:
            work_fn(j)
        j.progress += 1
        if j.progress >= j.work + j.overhead:
            j.state = JobState.DONE
            j.finish_time = t
            if on_complete is not None:
                on_complete(j)
    # 3. scheduling pass, with transition capture
    pre = {jid: j.state for jid, j in state.jobs.items()}
    decisions = policy(state)
    transitions = [
        (j, pre[jid], j.state)
        for jid, j in state.jobs.items() if j.state != pre[jid]
    ]
    return decisions, transitions


# ---------------------------------------------------------------------------
# The tick kernel — JAX backend (same four steps over the JobTable)
# ---------------------------------------------------------------------------


def tick_jax(cfg: SchedulerConfig, ent: jax.Array, tbl: "omfs_jax.JobTable",
             t: jax.Array, policy_pass: JaxPass,
             knobs: Optional["omfs_jax.Knobs"] = None
             ) -> "omfs_jax.JobTable":
    # 1. arrivals
    arrived = (tbl.state == omfs_jax.UNSUB) & (tbl.submit <= t)
    tbl = tbl._replace(state=jnp.where(arrived, omfs_jax.PENDING, tbl.state))
    # 2. progress + completions
    running = tbl.state == omfs_jax.RUNNING
    progress = tbl.progress + running.astype(jnp.int32)
    done = running & (progress >= tbl.work + tbl.overhead)
    tbl = tbl._replace(
        progress=progress,
        state=jnp.where(done, omfs_jax.DONE, tbl.state),
        finish=jnp.where(done, t, tbl.finish),
    )
    # 3. scheduling pass over the submitted queue snapshot; ``knobs`` (the
    # batched sweep's traced quantum/depth overrides) is only forwarded when
    # set, so 4-arg custom passes keep working and the sequential trace is
    # byte-identical to the pre-batching program
    if knobs is None:
        return policy_pass(cfg, ent, t, tbl)
    return policy_pass(cfg, ent, t, tbl, knobs)


def _tick_step(cfg: SchedulerConfig, ent: jax.Array,
               tbl: "omfs_jax.JobTable", t: jax.Array, pass_fn: JaxPass,
               knobs: Optional["omfs_jax.Knobs"] = None):
    """One scan step shared by ALL jitted runners (per-policy, matrix, and
    batched): the tick plus the per-tick busy reduction (protocol step 4) —
    defined once so `simulate`, `simulate_matrix`, and `simulate_batch`
    cannot drift apart."""
    tbl = tick_jax(cfg, ent, tbl, t, pass_fn, knobs)
    busy = jnp.sum(jnp.where(tbl.state == omfs_jax.RUNNING, tbl.cpus, 0))
    return tbl, busy


@functools.lru_cache(maxsize=128)
def _jitted_runner(cfg: SchedulerConfig, pass_fn: JaxPass, horizon: int):
    """One jitted scan per (cfg, pass, horizon): repeated `simulate` calls
    reuse the compilation (pass factories are memoized for the same reason —
    a fresh closure per call would defeat every warmup).

    The input table is DONATED: XLA reuses its buffers for the output, so a
    large-J sweep holds one table copy, not two.  Callers hand over a table
    they built for the call (`run_jax`) or an explicit copy."""

    @functools.partial(jax.jit, donate_argnums=(0,))
    def run(tbl, ent):
        def step(tbl, t):
            return _tick_step(cfg, ent, tbl, t, pass_fn)

        return jax.lax.scan(step, tbl, jnp.arange(horizon, dtype=jnp.int32))

    return run


def run_jax(users: List[User], jobs: List[Job], cfg: SchedulerConfig,
            horizon: int, pass_fn: JaxPass
            ) -> Tuple["omfs_jax.JobTable", jax.Array]:
    """Scan the jitted tick kernel over ``horizon`` ticks.

    Returns (final JobTable, busy[t] series); step 4 of the protocol is the
    per-tick busy reduction carried out of the scan."""
    tbl, ent = omfs_jax.table_from_jobs(jobs, users, cfg.cpu_total, cfg)
    if tbl.cpus.shape[0] == 0:
        # passes index order[0]/cumsum[-1]; match the python backend instead
        return tbl, jnp.zeros((horizon,), jnp.int32)
    return _jitted_runner(cfg, pass_fn, horizon)(tbl, ent)


# ---------------------------------------------------------------------------
# Instrumented runners: the SAME tick program plus in-scan event capture.
# Kept as separate lru_cached builders so the uninstrumented hot path above
# stays byte-identical with instrumentation off (repro.analysis enforces the
# confinement); the capture wraps _tick_step, it never reaches inside it.
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=32)
def _jitted_runner_events(cfg: SchedulerConfig, pass_fn: JaxPass,
                          horizon: int, ring_size: int):
    """`_jitted_runner` + per-tick event capture (`obs.jax_capture`): each
    scan step also emits (counts[E], ring[R,3], dropped) built from the
    tick-boundary diff.  ``ring_size`` is static per compile — the capture
    adds fixed-shape outputs only, so the runner compiles exactly once per
    (cfg, pass, horizon, ring) like its uninstrumented twin."""
    from repro.obs import jax_capture

    @functools.partial(jax.jit, donate_argnums=(0,))
    def run(tbl, ent):
        def step(tbl, t):
            pre = tbl
            tbl, busy = _tick_step(cfg, ent, tbl, t, pass_fn)
            cap = jax_capture.capture_tick(pre, tbl, t, ring_size)
            return tbl, (busy,) + cap

        return jax.lax.scan(step, tbl, jnp.arange(horizon, dtype=jnp.int32))

    return run


# ---------------------------------------------------------------------------
# Results (TickLog/SimResult live here; core.simulator re-exports them)
# ---------------------------------------------------------------------------


@dataclass
class TickLog:
    time: int
    busy: int
    pending: int
    running: int
    per_user_cpus: Dict[str, int]
    decisions: List[Decision]


@dataclass
class SimResult:
    state: ClusterState
    log: List[TickLog]

    # -- headline metrics (see core.metrics for derived scores) ------------
    def utilization(self) -> float:
        cfg = self.state.config
        if not self.log:
            return 0.0
        return float(np.mean([t.busy for t in self.log]) / cfg.cpu_total)

    def job_table(self) -> List[Job]:
        return sorted(self.state.jobs.values(), key=lambda j: j.id)

    def schedule_signature(self):
        """Hashable summary used by the Python-vs-JAX equivalence tests."""
        return tuple(
            (j.id, int(j.state), j.first_start, j.finish_time, j.progress,
             j.n_preemptions, j.n_checkpoints)
            for j in self.job_table()
        )


@dataclass
class EngineResult:
    """Backend-agnostic simulation outcome from `simulate`."""

    policy: str
    backend: str
    config: SchedulerConfig
    sim: Optional[SimResult] = None                    # python backend
    table: Optional["omfs_jax.JobTable"] = None        # jax backend
    busy: Optional[np.ndarray] = None                  # busy[t], both backends
    stream_stats: Optional[Dict[str, int]] = None      # simulate_stream only
    # -- observability (record_events=True); see repro.obs -----------------
    events: Optional[list] = None                      # List[obs.Event]
    event_counts: Optional[np.ndarray] = None          # [T, N_EVENT_TYPES]
    events_dropped: Optional[np.ndarray] = None        # [T] ring overflow

    def busy_series(self) -> np.ndarray:
        return np.asarray(self.busy)

    def events_dropped_total(self) -> int:
        if self.events_dropped is None:
            return 0
        return int(np.asarray(self.events_dropped).sum())

    def utilization(self) -> float:
        b = self.busy_series()
        return float(b.mean() / self.config.cpu_total) if b.size else 0.0

    def signature(self):
        """Id-free schedule signature, identical across backends when the
        policy's two implementations are step-equivalent."""
        if self.sim is not None:
            return tuple(s[1:] for s in self.sim.schedule_signature())
        return tuple(s[1:] for s in omfs_jax.signature_from_table(self.table))

    def summary(self) -> Dict[str, float]:
        """One comparison-table row: utilization / wait / preemption counts
        plus the paper's thrashing-cost terms — goodput (cpu-ticks that
        advanced *useful* work, per machine capacity) and the fraction of
        executed cpu-ticks wasted on C/R overhead or killed jobs."""
        if self.sim is not None:
            jobs = self.sim.job_table()
            started = [j for j in jobs if j.first_start >= 0]
            waits = [j.first_start - j.submit_time for j in started]
            preempt = sum(j.n_preemptions for j in jobs)
            ckpt = sum(j.n_checkpoints for j in jobs)
            spills = sum(j.n_spills for j in jobs)
            killed = sum(1 for j in jobs if j.state == JobState.KILLED)
            done = sum(1 for j in jobs if j.state == JobState.DONE)
            was_killed = np.asarray(
                [j.state == JobState.KILLED for j in jobs])
            progress = np.asarray([j.progress for j in jobs])
            work = np.asarray([j.work for j in jobs])
            cpus = np.asarray([j.cpus for j in jobs])
        else:
            t = jax.device_get(self.table)
            started = t.first_start >= 0
            waits = (t.first_start - t.submit)[started]
            preempt = int(t.n_preempt.sum())
            ckpt = int(t.n_ckpt.sum())
            spills = int(t.n_spill.sum())
            killed = int((t.state == omfs_jax.KILLED).sum())
            done = int((t.state == omfs_jax.DONE).sum())
            was_killed = np.asarray(t.state) == omfs_jax.KILLED
            progress = np.asarray(t.progress)
            work = np.asarray(t.work)
            cpus = np.asarray(t.cpus)
        # useful = progress toward `work` (overhead units come on top and
        # count as waste); killed jobs' entire progress is lost work
        useful = np.where(was_killed, 0, np.minimum(progress, work)) * cpus
        executed = progress * cpus
        wasted = executed.sum() - useful.sum()
        horizon = max(self.busy_series().size, 1)
        return {
            "policy": self.policy,
            "backend": self.backend,
            "utilization": self.utilization(),
            "goodput": float(useful.sum())
            / float(self.config.cpu_total * horizon),
            "wasted_frac": float(wasted) / float(max(executed.sum(), 1)),
            "mean_wait": float(np.mean(waits)) if len(waits) else 0.0,
            "preemptions": preempt,
            "checkpoints": ckpt,
            "spills": spills,        # checkpoints placed beyond the fast tier
            "killed": killed,
            "done": done,
        }


# ---------------------------------------------------------------------------
# The single entry point
# ---------------------------------------------------------------------------


def simulate(
    users: List[User],
    jobs: List[Job],
    config: SchedulerConfig,
    horizon: int,
    policy: Union[str, PythonPolicy] = "omfs",
    backend: str = "python",
    *,
    pass_depth: Optional[int] = None,
    record_events: bool = False,
    event_ring: Optional[int] = None,
) -> EngineResult:
    """Run ``policy`` on ``backend`` over the same tick protocol.

    ``policy`` is a registry name (see POLICIES) — or, on the python backend
    only, any ``ClusterState -> List[Decision]`` callable.  ``pass_depth``
    bounds the per-tick queue sweep on the jax backend (SLURM's
    sched_max_job_start); None sweeps the whole queue.

    ``record_events=True`` additionally captures the typed per-job lifecycle
    event log (`repro.obs`): on the python backend via an `obs.bus.EventBus`
    tick diff, on the jax backend inside the jitted scan with a bounded
    per-tick ring (`event_ring` overrides the per-tick capacity; the default
    `obs.events.lossless_ring_size` can never drop — any overflow of a
    smaller ring lands in ``EngineResult.events_dropped``, never silently).
    """
    name = policy if isinstance(policy, str) else getattr(
        policy, "__name__", "custom")

    if backend == "python":
        pol = _resolve_python(policy)
        state = ClusterState(config=config, users={u.name: u for u in users})
        for j in sorted(jobs, key=lambda x: x.id):
            j = j.clone()
            j.state = JobState.UNSUBMITTED
            state.jobs[j.id] = j
        bus = None
        if record_events:
            from repro.obs.bus import EventBus
            bus = EventBus()
        log: List[TickLog] = []
        for t in range(horizon):
            state.time = t
            if bus is not None:
                bus.snapshot(state.jobs)
            decisions, _ = tick_python(state, pol)
            if bus is not None:
                bus.record_tick(state.jobs, t)
            # 4. metrics
            per_user = {u: 0 for u in state.users}
            for j in state.running_jobs():
                per_user[j.user] += j.cpus
            log.append(TickLog(
                time=t, busy=state.cpu_busy(),
                pending=len(state.pending_jobs()),
                running=len(state.running_jobs()),
                per_user_cpus=per_user, decisions=decisions,
            ))
        sim = SimResult(state=state, log=log)
        res = EngineResult(
            policy=name, backend=backend, config=config, sim=sim,
            busy=np.asarray([tl.busy for tl in log]))
        if bus is not None:
            res.events = bus.events
            res.event_counts = bus.counts_matrix(horizon)
            res.events_dropped = bus.dropped_series(horizon)
        return res

    if backend == "jax":
        if not isinstance(policy, str):
            raise ValueError(
                "jax backend needs a registered policy name, got a callable; "
                f"known: {sorted(POLICIES)}")
        if policy not in POLICIES:
            raise ValueError(
                f"unknown policy {policy!r}; known: {sorted(POLICIES)}")
        pass_fn = POLICIES[policy].jax_factory(pass_depth)
        if not record_events:
            tbl, busy = run_jax(users, jobs, config, horizon, pass_fn)
            return EngineResult(
                policy=name, backend=backend, config=config, table=tbl,
                busy=np.asarray(busy))
        from repro.obs import jax_capture
        from repro.obs.events import lossless_ring_size
        tbl, ent = omfs_jax.table_from_jobs(jobs, users, config.cpu_total,
                                            config)
        n_rows = tbl.cpus.shape[0]
        if n_rows == 0:
            return EngineResult(
                policy=name, backend=backend, config=config, table=tbl,
                busy=np.zeros((horizon,), np.int32), events=[],
                event_counts=np.zeros((horizon, jax_capture.N_EVENT_TYPES),
                                      np.int64),
                events_dropped=np.zeros((horizon,), np.int64))
        ring = lossless_ring_size(n_rows) if event_ring is None else event_ring
        run = _jitted_runner_events(config, pass_fn, horizon, ring)
        tbl, (busy, counts, ring_buf, dropped) = run(tbl, ent)
        return EngineResult(
            policy=name, backend=backend, config=config, table=tbl,
            busy=np.asarray(busy),
            events=jax_capture.decode_events(counts, ring_buf, dropped),
            event_counts=np.asarray(counts, dtype=np.int64),
            events_dropped=np.asarray(dropped, dtype=np.int64))

    raise ValueError(f"unknown backend {backend!r}; use 'python' or 'jax'")


# ---------------------------------------------------------------------------
# Multi-policy matrix runner: ONE compiled scan shared by every policy
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=32)
def _jitted_matrix_runner(cfg: SchedulerConfig, pass_fns: tuple, horizon: int):
    """One jitted scan whose tick ``lax.switch``es over the policy passes.

    Compiling the union program once and selecting the policy by a dynamic
    index is measurably cheaper than compiling one scan per policy (the
    tick protocol, table plumbing, and XLA fixed costs are shared) — this
    is what keeps `bench_scheduler --smoke`'s policy matrix off the CI
    critical path.

    The input table is DONATED (see `_jitted_runner`); `simulate_matrix`
    passes each policy a fresh copy of the stacked table."""

    @functools.partial(jax.jit, donate_argnums=(0,))
    def run(tbl, ent, pidx):
        def step(tbl, t):
            branches = [
                lambda tb, p=p: _tick_step(cfg, ent, tb, t, p)
                for p in pass_fns
            ]
            return jax.lax.switch(pidx, branches, tbl)

        return jax.lax.scan(step, tbl, jnp.arange(horizon, dtype=jnp.int32))

    return run


def simulate_matrix(
    users: List[User],
    jobs: List[Job],
    config: SchedulerConfig,
    horizon: int,
    policies: Optional[List[str]] = None,
    *,
    pass_depth: Optional[int] = None,
) -> List[EngineResult]:
    """Run many registered policies on the JAX backend through one shared
    compiled scan (see `_jitted_matrix_runner`); per-policy results are
    bit-identical to ``simulate(..., backend="jax")`` — the policy pass is
    selected by ``lax.switch`` index, everything else is the same program.
    """
    names = list(policies) if policies is not None else sorted(POLICIES)
    unknown = [n for n in names if n not in POLICIES]
    if unknown:
        raise ValueError(f"unknown policies {unknown}; known: {sorted(POLICIES)}")
    pass_fns = tuple(POLICIES[n].jax_factory(pass_depth) for n in names)
    tbl, ent = omfs_jax.table_from_jobs(jobs, users, config.cpu_total, config)
    if tbl.cpus.shape[0] == 0:
        busy = jnp.zeros((horizon,), jnp.int32)
        return [EngineResult(policy=n, backend="jax", config=config,
                             table=tbl, busy=np.asarray(busy)) for n in names]
    run = _jitted_matrix_runner(config, pass_fns, horizon)
    out = []
    for k, name in enumerate(names):
        # the runner donates its input table; each policy gets its own copy
        final, busy = run(_copy_table(tbl), ent, k)
        out.append(EngineResult(policy=name, backend="jax", config=config,
                                table=final, busy=np.asarray(busy)))
    return out


def _copy_table(tbl: "omfs_jax.JobTable") -> "omfs_jax.JobTable":
    """Fresh buffers for every column — what callers hand to the donating
    jitted runners when they need to keep (or reuse) the original."""
    return jax.tree_util.tree_map(lambda a: a.copy(), tbl)


# ---------------------------------------------------------------------------
# Batched sweep engine: ONE compiled program for a scenario×policy×seed grid
# ---------------------------------------------------------------------------


@dataclass
class BatchCell:
    """One cell of a `simulate_batch` sweep: a workload (scenario × seed),
    a registered policy, and optional traced knob overrides.

    ``quantum``/``pass_depth`` override ``cfg.quantum`` / the full-queue
    sweep *without* recompiling: they ride the batch axis as int32 scalars
    (`omfs_jax.Knobs`), so a quantum×pass_depth×policy grid is one XLA
    program (see DESIGN.md §Batched execution)."""

    users: List[User]
    jobs: List[Job]
    policy: str = "omfs"
    quantum: Optional[int] = None
    pass_depth: Optional[int] = None


@functools.lru_cache(maxsize=16)
def _jitted_batch_runner(cfg: SchedulerConfig, pass_fns: tuple, horizon: int,
                         n_dev: int = 1):
    """`jax.vmap` of the matrix runner's tick scan over a leading batch
    axis: one compiled program sweeps every (table, ent, pidx, knobs) cell.

    With ``n_dev > 1`` the vmapped program runs under `shard_map`, the
    batch axis split evenly across devices (cells are independent — no
    collectives, no replication checks needed).  The batched table is
    donated like the sequential runners' tables."""

    def cell(tbl, ent, pidx, knobs):
        def step(tbl, t):
            branches = [
                lambda tb, p=p: _tick_step(cfg, ent, tb, t, p, knobs)
                for p in pass_fns
            ]
            return jax.lax.switch(pidx, branches, tbl)

        return jax.lax.scan(step, tbl, jnp.arange(horizon, dtype=jnp.int32))

    vcell = jax.vmap(cell)
    if n_dev > 1:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh, PartitionSpec

        mesh = Mesh(np.asarray(jax.devices()[:n_dev]), ("b",))
        spec = PartitionSpec("b")
        vcell = shard_map(vcell, mesh=mesh,
                          in_specs=(spec, spec, spec, spec),
                          out_specs=(spec, spec), check_rep=False)
    return jax.jit(vcell, donate_argnums=(0,))


@functools.lru_cache(maxsize=16)
def _jitted_batch_runner_events(cfg: SchedulerConfig, pass_fns: tuple,
                                horizon: int, ring_size: int, n_dev: int = 1):
    """`_jitted_batch_runner` + per-cell in-scan event capture: every cell
    of the vmapped sweep carries its own (counts, ring, dropped) series out
    of the scan, batch-stacked on the leading axis."""
    from repro.obs import jax_capture

    def cell(tbl, ent, pidx, knobs):
        def step(tbl, t):
            pre = tbl

            def branch(p):
                def run_branch(tb):
                    tb, busy = _tick_step(cfg, ent, tb, t, p, knobs)
                    return tb, (busy,) + jax_capture.capture_tick(
                        pre, tb, t, ring_size)
                return run_branch

            return jax.lax.switch(pidx, [branch(p) for p in pass_fns], tbl)

        return jax.lax.scan(step, tbl, jnp.arange(horizon, dtype=jnp.int32))

    vcell = jax.vmap(cell)
    if n_dev > 1:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh, PartitionSpec

        mesh = Mesh(np.asarray(jax.devices()[:n_dev]), ("b",))
        spec = PartitionSpec("b")
        vcell = shard_map(vcell, mesh=mesh,
                          in_specs=(spec, spec, spec, spec),
                          out_specs=(spec, (spec, spec, spec, spec)),
                          check_rep=False)
    return jax.jit(vcell, donate_argnums=(0,))


def simulate_batch(
    cells: List[BatchCell],
    config: SchedulerConfig,
    horizon: int,
    *,
    devices: Optional[int] = None,
    record_events: bool = False,
    event_ring: Optional[int] = None,
) -> List[EngineResult]:
    """Run ``B`` independent simulations as ONE compiled batched scan.

    Stacks every cell's `JobTable` / entitlement vector onto a leading
    batch axis (`omfs_jax.stack_tables` — short tables get inert pad rows),
    selects each cell's policy by `lax.switch` index and its quantum /
    pass-depth by traced `Knobs`, and `jax.vmap`s the shared tick scan.
    Per-cell results are bit-identical to sequential
    ``simulate(..., backend="jax")`` with the matching config
    (tests/test_simulate_batch.py asserts this for every registered
    policy).

    ``devices`` caps how many local devices the batch axis is sharded
    across (default: all of them; 1 on the CPU host).  With more than one
    device the batch is padded to a multiple of the device count with
    replicas of the last cell (dropped from the results).

    Empty corners match the sequential paths exactly: ``cells == []``
    returns ``[]``, and a batch whose tables are ALL empty skips the jitted
    path just like `simulate_matrix`'s early return (a mixed batch keeps
    empty cells on the jitted path via all-pad tables — same result either
    way, which is the regression test's point).
    """
    cells = list(cells)
    if not cells:
        return []
    names = sorted({c.policy for c in cells})
    unknown = [n for n in names if n not in POLICIES]
    if unknown:
        raise ValueError(f"unknown policies {unknown}; known: {sorted(POLICIES)}")
    # Per-cell depth rides the knobs (traced masking), but the fori_loop
    # trip count is static: when EVERY cell caps pass_depth, truncate the
    # compiled loop at the batch-wide max.  Iterations past a cell's own
    # depth are masked no-ops either way, so results are unchanged — the
    # truncation only drops dead work (a depth-4 cell in a J=40 table
    # otherwise pays all 40 positions under vmap).
    depths = [c.pass_depth for c in cells]
    bound = None if any(d is None for d in depths) else max(depths)
    pass_fns = tuple(POLICIES[n].jax_factory(bound) for n in names)
    built = [omfs_jax.table_from_jobs(c.jobs, c.users, config.cpu_total,
                                      config) for c in cells]
    sizes = [t.cpus.shape[0] for t, _ in built]
    if max(sizes) == 0:
        # all-empty batch: same early return simulate/simulate_matrix take
        out = [EngineResult(policy=c.policy, backend="jax", config=config,
                            table=t, busy=np.zeros((horizon,), np.int32))
               for c, (t, _) in zip(cells, built)]
        if record_events:
            from repro.obs.events import N_EVENT_TYPES
            for r in out:
                r.events = []
                r.event_counts = np.zeros((horizon, N_EVENT_TYPES), np.int64)
                r.events_dropped = np.zeros((horizon,), np.int64)
        return out

    tbl, ent = omfs_jax.stack_tables([t for t, _ in built],
                                     [e for _, e in built])
    pidx = jnp.asarray([names.index(c.policy) for c in cells], jnp.int32)
    knobs = omfs_jax.Knobs(
        quantum=jnp.asarray(
            [config.quantum if c.quantum is None else c.quantum
             for c in cells], jnp.int32),
        depth=jnp.asarray(
            [int(omfs_jax.BIG) if c.pass_depth is None else c.pass_depth
             for c in cells], jnp.int32),
    )

    n_dev = len(jax.devices()) if devices is None else int(devices)
    n_dev = max(1, min(n_dev, len(cells)))
    pad = (-len(cells)) % n_dev
    if pad:
        rep = lambda a: jnp.concatenate(
            [a, jnp.repeat(a[-1:], pad, axis=0)], axis=0)
        tbl = jax.tree_util.tree_map(rep, tbl)
        ent, pidx = rep(ent), rep(pidx)
        knobs = jax.tree_util.tree_map(rep, knobs)

    if record_events:
        from repro.obs import jax_capture
        from repro.obs.events import lossless_ring_size
        ring = (lossless_ring_size(tbl.cpus.shape[1])
                if event_ring is None else event_ring)
        run = _jitted_batch_runner_events(config, pass_fns, horizon, ring,
                                          n_dev)
        final, (busy, counts, ring_buf, dropped) = run(tbl, ent, pidx, knobs)
        counts = np.asarray(counts)
        ring_buf = np.asarray(ring_buf)
        dropped = np.asarray(dropped)
    else:
        run = _jitted_batch_runner(config, pass_fns, horizon, n_dev)
        final, busy = run(tbl, ent, pidx, knobs)
    busy = np.asarray(busy)
    out = []
    for i, (c, J) in enumerate(zip(cells, sizes)):
        # slice the cell back out of the batch axis and drop its pad rows
        # (rows never permute in the table, so [:J] is exactly the cell)
        cell_tbl = jax.tree_util.tree_map(lambda a: a[i, :J], final)
        res = EngineResult(policy=c.policy, backend="jax",
                           config=config, table=cell_tbl,
                           busy=busy[i])
        if record_events:
            res.events = jax_capture.decode_events(counts[i], ring_buf[i],
                                                   dropped[i])
            res.event_counts = counts[i].astype(np.int64)
            res.events_dropped = dropped[i].astype(np.int64)
        out.append(res)
    return out


# ---------------------------------------------------------------------------
# Chunked-epoch streaming engine: unbounded arrivals at bounded memory
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=32)
def _jitted_segment_runner(cfg: SchedulerConfig, pass_fn: JaxPass,
                           seg_len: int):
    """One jitted fixed-length segment of the tick scan, with the segment's
    start tick ``t0`` TRACED (an int32 scalar, not a Python constant): every
    segment of a stream reuses the one compilation — `_cache_size() == 1`
    after N segments is asserted by the jaxpr/retrace audit.  Donates the
    table like the other runners (between segments exactly one [capacity]-
    shaped table is alive)."""

    @functools.partial(jax.jit, donate_argnums=(0,))
    def run(tbl, ent, t0):
        def step(tbl, i):
            return _tick_step(cfg, ent, tbl, t0 + i, pass_fn)

        return jax.lax.scan(step, tbl, jnp.arange(seg_len, dtype=jnp.int32))

    return run


@functools.lru_cache(maxsize=32)
def _jitted_segment_runner_events(cfg: SchedulerConfig, pass_fn: JaxPass,
                                  seg_len: int, ring_size: int):
    """`_jitted_segment_runner` + in-scan event capture.  The ring records
    true job ids, so recycled slots decode correctly; the start tick stays
    traced — one compile per (cfg, pass, seg_len, ring) across the whole
    stream, same as the uninstrumented runner."""
    from repro.obs import jax_capture

    @functools.partial(jax.jit, donate_argnums=(0,))
    def run(tbl, ent, t0):
        def step(tbl, i):
            pre = tbl
            tbl, busy = _tick_step(cfg, ent, tbl, t0 + i, pass_fn)
            cap = jax_capture.capture_tick(pre, tbl, t0 + i, ring_size)
            return tbl, (busy,) + cap

        return jax.lax.scan(step, tbl, jnp.arange(seg_len, dtype=jnp.int32))

    return run


def simulate_stream(
    users: List[User],
    jobs,
    config: SchedulerConfig,
    horizon: int,
    policy: str = "omfs",
    *,
    capacity: int,
    segment_len: int,
    pass_depth: Optional[int] = None,
    record_events: bool = False,
    event_ring: Optional[int] = None,
    profile=None,
) -> EngineResult:
    """Run an arrival *stream* through a fixed-``capacity`` JobTable in
    jitted ``segment_len``-tick chunks — unbounded workloads at bounded
    memory (ROADMAP "million-job streaming simulation").

    ``jobs`` is any iterable of `core.types.Job` in ascending
    ``(submit_time, id)`` order (`core.workload.arrival_stream` sorts a
    list; `core.workload.endless_arrivals` generates forever).  The loop:

      1. host boundary: pull every job due before the segment's end from
         the iterator, fetch the table, compact finished (DONE/KILLED)
         rows out into a host-side archive, and scatter the arrivals into
         the freed slots (`omfs_jax.insert_rows` — one jitted program for
         the whole stream).  Arrivals land as UNSUBMITTED rows and fire at
         their true submit tick inside the scan, so inserting a segment
         early is semantics-free.
      2. run the jitted segment (`_jitted_segment_runner` — traced start
         tick, one compile across segments).

    When every due arrival always finds a slot (live jobs never exceed
    ``capacity``), the merged result is bit-identical to the monolithic
    ``simulate(..., backend="jax")`` run over the same jobs: row identity
    (queue/victim tie-breaks) rides the table's ``jid`` column, not row
    position.  When slots run out, surplus arrivals are DEFERRED to a
    later boundary (they arrive late, like a submit-rate-limited
    front-end); ``stream_stats["deferrals"]`` counts those events.

    Jobs whose ``submit_time >= horizon`` are left in the iterator and do
    not appear in the result table (the monolithic run keeps them as
    UNSUBMITTED rows — every metric still matches).

    ``record_events`` captures the lifecycle event log in-scan exactly like
    `simulate` (the ring records true job ids, so recycled slots decode
    correctly and finished jobs' events survive compaction — they were
    captured at their tick, before the row was archived).  ``profile`` is an
    optional `repro.obs.profile.ProfileTimers`; when given, the stream is
    timed into three sections — ``compile`` (segment-runner builds),
    ``dispatch`` (jitted segment execution), ``compaction`` (the host-side
    boundary) — surfaced by the scale bench.
    """
    if capacity <= 0:
        raise ValueError(f"capacity must be positive, got {capacity}")
    if segment_len <= 0:
        raise ValueError(f"segment_len must be positive, got {segment_len}")
    if not isinstance(policy, str) or policy not in POLICIES:
        raise ValueError(
            f"unknown policy {policy!r}; known: {sorted(POLICIES)}")
    pass_fn = POLICIES[policy].jax_factory(pass_depth)

    ring: Optional[int] = None
    if record_events:
        from repro.obs.events import lossless_ring_size
        ring = (lossless_ring_size(capacity) if event_ring is None
                else event_ring)

    ent = omfs_jax.entitlements(users, config.cpu_total)
    empty, _ = omfs_jax.table_from_jobs([], users, config.cpu_total, config)
    tbl = omfs_jax.pad_table(empty, capacity)

    feed = iter(jobs)
    lookahead: Optional[Job] = None
    due: List[Job] = []
    archived: List["omfs_jax.JobTable"] = []   # host-side finished rows
    busy_parts: List[np.ndarray] = []
    stats = {"segments": 0, "inserted": 0, "deferrals": 0, "peak_live": 0,
             "capacity": capacity}

    def boundary(tbl):
        """Compact finished rows out, insert due arrivals; host-side."""
        host = jax.device_get(tbl)
        pad = np.asarray(omfs_jax.is_pad(host))
        finished = np.isin(np.asarray(host.state),
                           (int(omfs_jax.DONE), int(omfs_jax.KILLED))) & ~pad
        if finished.any():
            idx = np.flatnonzero(finished)
            archived.append(jax.tree_util.tree_map(lambda a: a[idx], host))
        free = np.flatnonzero(finished | pad)
        stats["peak_live"] = max(stats["peak_live"], capacity - free.size)
        k = min(len(due), free.size)
        if k < len(due):
            stats["deferrals"] += len(due) - k
        if k == 0 and not finished.any():
            return tbl
        take, due[:] = due[:k], due[k:]
        block, _ = omfs_jax.table_from_jobs(take, users, config.cpu_total,
                                            config)
        rows = omfs_jax.pad_table(block, capacity)
        # arrivals fill the first k free slots; pad rows clear the rest of
        # the freed slots; occupied slots get a masked write-back.  `slots`
        # is a permutation of arange(capacity) by construction.
        slots = np.concatenate(
            [free, np.setdiff1d(np.arange(capacity), free)])
        valid = np.arange(capacity) < free.size
        stats["inserted"] += k
        return omfs_jax.insert_rows(tbl, jnp.asarray(slots, jnp.int32),
                                    rows, jnp.asarray(valid))

    ev_counts: List[np.ndarray] = []
    ev_rings: List[np.ndarray] = []
    ev_dropped: List[np.ndarray] = []
    seg_starts: List[int] = []

    t0 = 0
    while t0 < horizon:
        seg = min(segment_len, horizon - t0)
        while True:
            if lookahead is None:
                lookahead = next(feed, None)
            if lookahead is None or lookahead.submit_time >= t0 + seg:
                break
            due.append(lookahead)
            lookahead = None
        if profile is not None:
            with profile.section("compaction"):
                tbl = boundary(tbl)
        else:
            tbl = boundary(tbl)
        if record_events:
            builder, key = _jitted_segment_runner_events, (
                config, pass_fn, seg, ring)
        else:
            builder, key = _jitted_segment_runner, (config, pass_fn, seg)
        # a builder cache miss means this call traces + XLA-compiles the
        # segment program; later segments of the stream only dispatch it
        misses = builder.cache_info().misses
        runner = builder(*key)
        fresh = builder.cache_info().misses > misses
        with (profile.section("compile" if fresh else "dispatch")
              if profile is not None else _NULLCTX):
            if record_events:
                tbl, (busy, cnt, rbuf, drp) = runner(tbl, ent, jnp.int32(t0))
                busy = jax.block_until_ready(busy)
                ev_counts.append(np.asarray(cnt))
                ev_rings.append(np.asarray(rbuf))
                ev_dropped.append(np.asarray(drp))
                seg_starts.append(t0)
            else:
                tbl, busy = runner(tbl, ent, jnp.int32(t0))
                if profile is not None:
                    busy = jax.block_until_ready(busy)
        busy_parts.append(np.asarray(busy))
        stats["segments"] += 1
        t0 += seg

    # final extraction: archive + still-live rows, merged in job-id order
    # (= the monolithic table's row order).  Arrivals still deferred here
    # never entered the table; they stay out of the result (counted below).
    stats["dropped"] = len(due)
    host = jax.device_get(tbl)
    live = np.flatnonzero(~np.asarray(omfs_jax.is_pad(host)))
    parts = archived + [jax.tree_util.tree_map(lambda a: a[live], host)]
    merged_np = {
        f: np.concatenate([np.asarray(getattr(p, f)) for p in parts])
        for f in omfs_jax.JobTable._fields}
    order = np.argsort(merged_np["jid"], kind="stable")
    merged = omfs_jax.JobTable(**{
        f: jnp.asarray(v[order], jnp.int32) for f, v in merged_np.items()})
    busy = (np.concatenate(busy_parts) if busy_parts
            else np.zeros((0,), np.int32))
    res = EngineResult(policy=policy, backend="jax", config=config,
                       table=merged, busy=busy, stream_stats=stats)
    if record_events:
        from repro.obs import jax_capture
        from repro.obs.events import N_EVENT_TYPES
        events = []
        for cnt, rbuf, drp, s0 in zip(ev_counts, ev_rings, ev_dropped,
                                      seg_starts):
            events.extend(jax_capture.decode_events(cnt, rbuf, drp, t0=s0))
        res.events = events
        res.event_counts = (
            np.concatenate(ev_counts).astype(np.int64) if ev_counts
            else np.zeros((0, N_EVENT_TYPES), np.int64))
        res.events_dropped = (
            np.concatenate(ev_dropped).astype(np.int64) if ev_dropped
            else np.zeros((0,), np.int64))
        stats["events_dropped"] = int(res.events_dropped.sum())
    return res
