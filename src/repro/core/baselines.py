"""Baseline scheduling policies the paper argues against (§I, §III.B).

All share the simulator's per-tick pass signature so every benchmark runs
each policy on the *same* workload:

* ``static_partition`` — hard division: each user owns a fixed block of
  CPUs; jobs run only inside their owner's block.
* ``capping``          — usage capping: a user's running total may never
  exceed their entitlement, but CPUs are pooled (no preemption needed).
* ``fcfs``             — SLURM sched/builtin: strict queue order, head
  blocks the queue.
* ``backfill``         — conservative backfill (sched/backfill): jobs may
  jump the queue iff they do not delay the head job's earliest start,
  computed from *estimated* remaining runtimes (the paper's §III.B point:
  estimates are unreliable; we expose an estimate-error knob).
* ``backfill_cr``      — Niu et al. [30]: backfill + checkpoint-preemption
  of backfilled jobs when the head job becomes runnable.

C/R pricing — including tiered eviction placement (``cfg.cr_tiers``:
greedy cheapest-feasible tier choice with durable spill, the restore
priced at the placed tier) — rides the shared `omfs._evict` / `omfs._start`
helpers, so every baseline pays the same size- and tier-aware costs as
OMFS with no policy-specific code here (DESIGN.md §Tier placement).
"""
from __future__ import annotations

import math
from typing import Callable, Dict, List

from repro.core.omfs import Decision, _evict, _start
from repro.core.queues import sorted_pending, sorted_victims, submitted_key
from repro.core.types import ClusterState, Job, JobClass, JobState


def _admit(state: ClusterState, job: Job, reason: str) -> Decision:
    _start(state, job)
    return Decision(job_id=job.id, admitted=True, reason=reason)


def _deny(job: Job, reason: str) -> Decision:
    return Decision(job_id=job.id, admitted=False, reason=reason)


# ---------------------------------------------------------------------------


def static_partition(state: ClusterState) -> List[Decision]:
    """Hard divisions: user blocks sized by entitlement; no pooling at all."""
    decisions = []
    for job in sorted_pending(state):
        cap = state.entitled(job.user)
        used = state.user_usage(job.user)["total"]
        if used + job.cpus <= cap:
            decisions.append(_admit(state, job, "fits user partition"))
        else:
            decisions.append(_deny(job, "partition full"))
    return decisions


def capping(state: ClusterState) -> List[Decision]:
    """Pooled CPUs + per-user cap at the entitlement (no over-subscription)."""
    decisions = []
    for job in sorted_pending(state):
        cap = state.entitled(job.user)
        used = state.user_usage(job.user)["total"]
        if used + job.cpus <= cap and state.cpu_idle >= job.cpus:
            decisions.append(_admit(state, job, "within cap"))
        else:
            decisions.append(_deny(job, "cap or idle exceeded"))
    return decisions


def fcfs(state: ClusterState) -> List[Decision]:
    """Strict first-come-first-served: the queue head blocks everyone."""
    decisions = []
    for job in sorted_pending(state):
        if state.cpu_idle >= job.cpus:
            decisions.append(_admit(state, job, "fcfs head fits"))
        else:
            decisions.append(_deny(job, "fcfs head blocked"))
            break  # noone may overtake the head
    return decisions


def _estimated_remaining(job: Job, error: float = 0.0) -> int:
    """User-supplied runtime estimate: true remaining inflated by ``error``
    (papers show real estimates are inflated by 2-5x; see [19],[26],[30])."""
    return max(1, math.ceil((job.work + job.overhead - job.progress) * (1.0 + error)))


def make_backfill(estimate_error: float = 0.0, with_cr: bool = False) -> Callable:
    """Conservative backfill; optionally with C/R preemption (Niu et al.)."""

    def policy(state: ClusterState) -> List[Decision]:
        decisions: List[Decision] = []
        pending = sorted_pending(state)
        if not pending:
            return decisions
        head, rest = pending[0], pending[1:]

        if state.cpu_idle >= head.cpus:
            decisions.append(_admit(state, head, "head fits"))
            head_start = None
        elif with_cr:
            # Niu et al.: preempt checkpointable *backfilled* jobs to start
            # the head job now instead of waiting for the reservation.
            victims = [v for v in sorted_victims(state) if v.backfilled]
            freed = 0
            planned = []
            for v in victims:
                if state.cpu_idle + freed >= head.cpus:
                    break
                planned.append(v)
                freed += v.cpus
            if state.cpu_idle + freed >= head.cpus:
                dec = Decision(job_id=head.id, admitted=True, reason="head via C/R preemption")
                for v in planned:
                    _evict(state, v, dec)
                _start(state, head)
                decisions.append(dec)
                head_start = None
            else:
                head_start = _reservation_time(state, head, estimate_error)
                decisions.append(_deny(head, "head waits (reservation)"))
        else:
            # compute the head job's reservation from runtime estimates
            head_start = _reservation_time(state, head, estimate_error)
            decisions.append(_deny(head, "head waits (reservation)"))

        for job in rest:
            if job.state != JobState.PENDING:
                continue
            if state.cpu_idle < job.cpus:
                decisions.append(_deny(job, "no idle"))
                continue
            if head_start is not None:
                # conservative: would this backfill delay the reservation?
                est_end = state.time + _estimated_remaining(job, estimate_error)
                if est_end > head_start and not _fits_alongside_head(state, job, head):
                    decisions.append(_deny(job, "would delay head reservation"))
                    continue
            job.backfilled = True
            decisions.append(_admit(state, job, "backfilled"))
        return decisions

    policy.__name__ = "backfill_cr" if with_cr else "backfill"
    return policy


def _reservation_time(state: ClusterState, head: Job, error: float) -> int:
    """Earliest tick the head job can start, from estimated completions."""
    running = sorted(
        state.running_jobs(),
        key=lambda j: _estimated_remaining(j, error),
    )
    idle = state.cpu_idle
    for j in running:
        idle += j.cpus
        if idle >= head.cpus:
            return state.time + _estimated_remaining(j, error)
    return state.time + sum(_estimated_remaining(j, error) for j in running) + 1


def _fits_alongside_head(state: ClusterState, job: Job, head: Job) -> bool:
    """Backfill is safe regardless of duration if, after placing the job,
    enough CPUs remain for the head."""
    return state.cpu_idle - job.cpus >= head.cpus


backfill = make_backfill(estimate_error=0.0)
backfill_cr = make_backfill(estimate_error=0.0, with_cr=True)

ALL_BASELINES: Dict[str, Callable] = {
    "static_partition": static_partition,
    "capping": capping,
    "fcfs": fcfs,
    "backfill": backfill,
    "backfill_cr": backfill_cr,
}
