"""Priority queues for Jobs_Submitted and Jobs_Running (lines 5-6).

The paper leaves the prioritization policy open ("FIFO or priority-by-user").
Both queues are *orderings over the job table*, expressed as key functions,
so the Python reference and the JAX vectorized scheduler sort by the same
keys and stay step-equivalent.

Conventions:
* ``submitted_key``: smaller = dequeued (tried) first.
* ``running_key``: smaller = evicted first ("least prioritized", line 33),
  with quantum demotion: jobs running uninterruptedly for >= quantum are
  demoted (preferred victims).  Jobs still inside their quantum are NOT
  evictable (paper §II anti-thrashing) — expressed by ``evictable``.
"""
from __future__ import annotations

from typing import Callable, List, Tuple

from repro.core.types import ClusterState, Job


def submitted_key(job: Job) -> Tuple:
    """FIFO within priority: higher j.priority first, then earlier submit."""
    return (-job.priority, job.submit_time, job.id)


def sorted_pending(state: ClusterState) -> List[Job]:
    return sorted(state.pending_jobs(), key=submitted_key)


def evictable(state: ClusterState, job: Job) -> bool:
    """A running job may be evicted only after its quantum elapsed."""
    if not job.job_class.is_preemptable:
        return False
    return (state.time - job.run_start) >= state.config.quantum


def running_victim_key(job: Job) -> Tuple:
    """Victim order among evictable jobs: lowest priority first, then the
    job that has been running longest past its quantum (most demoted),
    then id for determinism."""
    return (job.priority, job.run_start, job.id)


def sorted_victims(state: ClusterState) -> List[Job]:
    return sorted(
        (j for j in state.running_jobs() if evictable(state, j)),
        key=running_victim_key,
    )
