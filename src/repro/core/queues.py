"""Priority queues for Jobs_Submitted and Jobs_Running (lines 5-6).

The paper leaves the prioritization policy open ("FIFO or priority-by-user").
Both queues are *orderings over the job table*, expressed as key functions,
so the Python reference and the JAX vectorized scheduler sort by the same
keys and stay step-equivalent.

Conventions:
* ``submitted_key``: smaller = dequeued (tried) first.
* ``running_key``: smaller = evicted first ("least prioritized", line 33),
  with quantum demotion: jobs running uninterruptedly for >= quantum are
  demoted (preferred victims).  Jobs still inside their quantum are NOT
  evictable (paper §II anti-thrashing) — expressed by ``evictable``.
"""
from __future__ import annotations

from typing import Callable, List, Optional, Tuple

from repro.core.types import ClusterState, Job


def submitted_key(job: Job) -> Tuple:
    """FIFO within priority: higher j.priority first, then earlier submit."""
    return (-job.priority, job.submit_time, job.id)


def sorted_pending(state: ClusterState) -> List[Job]:
    return sorted(state.pending_jobs(), key=submitted_key)


def evictable(state: ClusterState, job: Job) -> bool:
    """A running job may be evicted only after its quantum elapsed."""
    if not job.job_class.is_preemptable:
        return False
    return (state.time - job.run_start) >= state.config.quantum


def running_victim_key(job: Job) -> Tuple:
    """Victim order among evictable jobs: lowest priority first, then the
    job that has been running longest past its quantum (most demoted),
    then id for determinism."""
    return (job.priority, job.run_start, job.id)


def cheap_victim_key(state: ClusterState) -> Callable[[Job], Tuple]:
    """Size-aware victim order (beyond paper, `omfs_cheap_victim`):
    cheapest-to-checkpoint first — ``(save_cost, priority, run_start, id)``.

    The ordering cost is the *fast-tier* save cost (tier 0 of
    ``cfg.cr_tiers``, or ``cfg.cr_cost``), the same number the JAX backend
    precomputes as column 0 of ``JobTable.cost_save_lat`` /
    ``cost_rsave_lat``; the tier actually charged is still chosen at
    eviction time (capacity may force a spill).  Delta-aware: a warm job
    (one that already holds a snapshot) is priced at its recurrent cost —
    what evicting it *actually* costs — so warm jobs sort cheaper."""
    cfg = state.config

    def key(job: Job) -> Tuple:
        return (cfg.eviction_save_cost(job.state_mib, 0,
                                       recurrent=job.n_checkpoints > 0),
                job.priority, job.run_start, job.id)

    return key


def sorted_victims(state: ClusterState,
                   key: Optional[Callable[[Job], Tuple]] = None) -> List[Job]:
    return sorted(
        (j for j in state.running_jobs() if evictable(state, j)),
        key=key or running_victim_key,
    )
