"""OMFS vectorized in JAX: the paper's contribution as a composable module.

The whole scheduler state is a table of fixed-size arrays (`JobTable`); one
simulation tick — arrivals, progress/completions, and a full Algorithm-1
scheduling pass — is a single jitted function built from ``jax.lax`` control
flow (``fori_loop`` over the submitted queue, ``lexsort``+``cumsum`` victim
selection replacing the paper's while-loop, lines 32-36).  A fleet
simulation is ``lax.scan`` over ticks.

This is what makes 1000+-node / 100k-job what-if simulation cheap (see
benchmarks/bench_sched_scale.py) — and it is property-tested to produce
*identical schedules* to the Python reference (`core.omfs`) on randomized
workloads (tests/test_omfs_equivalence.py).

Sequential admission is inherent to Algorithm 1 (each admission changes the
state the next decision sees), so the pass is a ``fori_loop`` over queue
positions, each O(J) vectorized — O(J^2) per tick worst case; the
``pass_depth`` knob (same as SLURM's sched_max_job_start) bounds it at scale.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import ClusterState, Job, JobClass, JobState, SchedulerConfig, User

# JobState encoding (matches types.JobState)
UNSUB, PENDING, RUNNING, DONE, KILLED = 0, 1, 2, 3, 4
BIG = jnp.int32(2**30)


class JobTable(NamedTuple):
    """Static job attributes + mutable runtime state, all [J]-shaped."""

    user: jax.Array        # int32 user index
    cpus: jax.Array        # int32
    work: jax.Array        # int32 work units
    priority: jax.Array    # int32
    jclass: jax.Array      # int32 JobClass
    submit: jax.Array      # int32 tick
    # runtime
    state: jax.Array       # int32 JobState
    progress: jax.Array
    run_start: jax.Array
    first_start: jax.Array
    finish: jax.Array
    n_preempt: jax.Array
    n_ckpt: jax.Array
    overhead: jax.Array


def table_from_jobs(jobs, users) -> Tuple[JobTable, jnp.ndarray]:
    """Build (JobTable, entitled_cpus[U]) from core.types objects."""
    uidx = {u.name: i for i, u in enumerate(users)}
    j = sorted(jobs, key=lambda x: x.id)
    n = len(j)
    arr = lambda f, d=jnp.int32: jnp.asarray([f(x) for x in j], d)
    table = JobTable(
        user=arr(lambda x: uidx[x.user]),
        cpus=arr(lambda x: x.cpus),
        work=arr(lambda x: x.work),
        priority=arr(lambda x: x.priority),
        jclass=arr(lambda x: int(x.job_class)),
        submit=arr(lambda x: x.submit_time),
        state=jnp.full((n,), UNSUB, jnp.int32),
        progress=jnp.zeros((n,), jnp.int32),
        run_start=jnp.full((n,), -1, jnp.int32),
        first_start=jnp.full((n,), -1, jnp.int32),
        finish=jnp.full((n,), -1, jnp.int32),
        n_preempt=jnp.zeros((n,), jnp.int32),
        n_ckpt=jnp.zeros((n,), jnp.int32),
        overhead=jnp.zeros((n,), jnp.int32),
    )
    return table


def entitlements(users, cpu_total: int) -> jnp.ndarray:
    return jnp.asarray([u.entitled_cpus(cpu_total) for u in users], jnp.int32)


# ---------------------------------------------------------------------------
# One Algorithm-1 admission decision + its state update, vectorized
# ---------------------------------------------------------------------------


def _try_admit(cfg: SchedulerConfig, ent: jax.Array, t: jax.Array,
               tbl: JobTable, idx: jax.Array, eligible: jax.Array) -> JobTable:
    """Process job ``idx`` (runner, lines 18-38); no-op unless eligible and
    still pending."""
    running = tbl.state == RUNNING
    preempt_able = tbl.jclass != int(JobClass.NON_PREEMPTIBLE)

    ju = tbl.user[idx]
    jc = tbl.cpus[idx]
    same_user = tbl.user == ju
    non_p_usage = jnp.sum(jnp.where(running & same_user & ~preempt_able, tbl.cpus, 0))
    total_usage = jnp.sum(jnp.where(running & same_user, tbl.cpus, 0))
    busy = jnp.sum(jnp.where(running, tbl.cpus, 0))
    idle = cfg.cpu_total - busy
    entitled = ent[ju]

    job_non_p = tbl.jclass[idx] == int(JobClass.NON_PREEMPTIBLE)
    # line 23 (note >=): non-preemptible beyond (or exactly at) entitlement
    reject_23 = job_non_p & (non_p_usage + jc >= entitled)
    # line 26 (note >): enough idle -> run anyways
    admit_26 = idle > jc
    # line 28: request exceeds unused entitlement
    reject_28 = jc > entitled - total_usage

    # lines 31-36: victim selection among quantum-expired running jobs
    evictable = running & preempt_able & ((t - tbl.run_start) >= cfg.quantum)
    if cfg.avoid_self_eviction:                # beyond-paper flag
        evictable = evictable & ~same_user
    if cfg.victim_filter_over_entitlement:     # beyond-paper flag
        usage_per_user = jax.ops.segment_sum(
            jnp.where(running, tbl.cpus, 0), tbl.user, num_segments=ent.shape[0])
        over = usage_per_user[tbl.user] > ent[tbl.user]
        evictable = evictable & over

    # victim order: (priority asc, run_start asc, id asc)  [queues.py]
    order = jnp.lexsort((jnp.arange(tbl.cpus.shape[0]), tbl.run_start, tbl.priority))
    evict_sorted = evictable[order]
    cpus_sorted = jnp.where(evict_sorted, tbl.cpus[order], 0)
    freed_cum = jnp.cumsum(cpus_sorted)
    # minimal prefix with idle + freed >= jc  (the paper's while loop)
    need = jnp.maximum(jc - idle, 0)
    prefix_needed = freed_cum - cpus_sorted < need   # victim still required
    planned_sorted = evict_sorted & prefix_needed
    enough = idle + freed_cum[-1] >= jc

    admit_evict = (~reject_23) & (~admit_26) & (~reject_28) & enough
    admit = eligible & (tbl.state[idx] == PENDING) & (~reject_23) & (
        admit_26 | admit_evict)
    do_evict = admit & (~admit_26)

    # scatter planned victims back to table order
    planned = jnp.zeros_like(evictable).at[order].set(planned_sorted) & do_evict

    is_ckpt = tbl.jclass == int(JobClass.CHECKPOINTABLE)
    kill = planned & ~is_ckpt
    ckpt = planned & is_ckpt

    new_state = jnp.where(
        ckpt, PENDING,
        jnp.where(kill, (KILLED if cfg.drop_killed else PENDING), tbl.state))
    new_progress = jnp.where(kill & (not cfg.drop_killed), 0, tbl.progress)
    new_overhead = tbl.overhead + jnp.where(ckpt, cfg.cr_overhead, 0)
    new_run_start = jnp.where(planned, -1, tbl.run_start)
    new_finish = jnp.where(kill & cfg.drop_killed, t, tbl.finish)
    new_n_preempt = tbl.n_preempt + planned.astype(jnp.int32)
    new_n_ckpt = tbl.n_ckpt + ckpt.astype(jnp.int32)

    # admit the job itself (lines 37-38)
    new_state = new_state.at[idx].set(jnp.where(admit, RUNNING, new_state[idx]))
    new_run_start = new_run_start.at[idx].set(jnp.where(admit, t, new_run_start[idx]))
    new_first = tbl.first_start.at[idx].set(
        jnp.where(admit & (tbl.first_start[idx] < 0), t, tbl.first_start[idx]))

    return tbl._replace(
        state=new_state, progress=new_progress, overhead=new_overhead,
        run_start=new_run_start, finish=new_finish,
        n_preempt=new_n_preempt, n_ckpt=new_n_ckpt, first_start=new_first,
    )


# ---------------------------------------------------------------------------
# One tick: arrivals -> progress -> scheduling pass
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("cfg", "pass_depth"))
def omfs_tick(cfg: SchedulerConfig, ent: jax.Array, tbl: JobTable, t: jax.Array,
              pass_depth: Optional[int] = None) -> JobTable:
    n = tbl.cpus.shape[0]
    # 1. arrivals
    arrived = (tbl.state == UNSUB) & (tbl.submit <= t)
    tbl = tbl._replace(state=jnp.where(arrived, PENDING, tbl.state))
    # 2. progress + completions
    running = tbl.state == RUNNING
    progress = tbl.progress + running.astype(jnp.int32)
    done = running & (progress >= tbl.work + tbl.overhead)
    tbl = tbl._replace(
        progress=progress,
        state=jnp.where(done, DONE, tbl.state),
        finish=jnp.where(done, t, tbl.finish),
    )
    # 3. scheduling pass over the submitted queue snapshot
    eligible_mask = tbl.state == PENDING
    # queue order: (-priority, submit, id); ineligible jobs pushed to the end
    qkey = jnp.where(eligible_mask, -tbl.priority, BIG)
    order = jnp.lexsort((jnp.arange(n), tbl.submit, qkey))
    depth = n if pass_depth is None else min(pass_depth, n)

    def body(i, tbl):
        idx = order[i]
        return _try_admit(cfg, ent, t, tbl, idx, eligible_mask[idx])

    tbl = jax.lax.fori_loop(0, depth, body, tbl)
    return tbl


def simulate_jax(
    users, jobs, cfg: SchedulerConfig, horizon: int,
    pass_depth: Optional[int] = None,
) -> Tuple[JobTable, jax.Array]:
    """Run the full fleet simulation; returns (final table, busy[t] series)."""
    tbl = table_from_jobs(jobs, users)
    ent = entitlements(users, cfg.cpu_total)

    @jax.jit
    def run(tbl):
        def step(tbl, t):
            tbl = omfs_tick(cfg, ent, tbl, t, pass_depth)
            busy = jnp.sum(jnp.where(tbl.state == RUNNING, tbl.cpus, 0))
            return tbl, busy

        return jax.lax.scan(step, tbl, jnp.arange(horizon, dtype=jnp.int32))

    return run(tbl)


def signature_from_table(tbl: JobTable):
    """Same shape as SimResult.schedule_signature() for equivalence tests."""
    t = jax.device_get(tbl)
    return tuple(
        (int(i), int(t.state[i]), int(t.first_start[i]), int(t.finish[i]),
         int(t.progress[i]), int(t.n_preempt[i]), int(t.n_ckpt[i]))
        for i in range(t.state.shape[0])
    )
