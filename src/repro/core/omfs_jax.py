"""OMFS vectorized in JAX: the paper's contribution as a composable module.

The whole scheduler state is a table of fixed-size arrays (`JobTable`); the
tick protocol (arrivals -> progress/completions -> scheduling pass) is defined
once in `core.engine` and shared by every policy and backend.  This module
owns the table representation, the JobTable *primitives* every vectorized
policy builds on (queue ordering, admission, victim selection/eviction), and
the two OMFS passes:

* ``make_omfs_pass(incremental=False)`` — the original reference pass: each
  admission recomputes O(J) masked usage sums and a fresh ``lexsort`` for
  victim selection, faithful but O(J log J) per queue position.
* ``make_omfs_pass(incremental=True)`` — the optimized pass (the default):
  per-user usage ``[U]`` and the busy scalar ride the ``fori_loop`` carry and
  are updated in O(1) per admission; the idle-admit fast path touches no
  victim machinery at all, and the ``lexsort``+``cumsum`` victim selection
  runs only on the eviction branch of a ``lax.cond``.

Both produce bit-identical schedules (tests/test_policies_equivalence.py and
benchmarks/bench_sched_scale.py assert signature equality) — this is what
makes 1000+-node / 100k-job what-if simulation cheap.

Sequential admission is inherent to Algorithm 1 (each admission changes the
state the next decision sees), so the pass is a ``fori_loop`` over queue
positions; the ``pass_depth`` knob (same as SLURM's sched_max_job_start)
bounds it at scale.

C/R costs are size-aware (`core.crcost.CRCostModel`) and live in a
``[J, T]`` **cost lattice**: the table carries per-job ``state_mib`` plus
three precomputed lattices — ``cost_save_lat`` (first save per tier),
``cost_rsave_lat`` (recurrent/delta save per tier) and ``cost_restore_lat``
(restore per tier) — one column per tier of ``cfg.cr_tiers`` (T=1 when
untiered).  Sizes are static per job (until `update_state_mib`), so the
model evaluates once at build time with Python-int arithmetic — the exact
numbers the Python backend charges at runtime, which is what makes
cross-backend bit-equality hold by construction.  The shared primitives
charge from the lattice: `apply_evictions` adds the placed tier's save
cost (first or recurrent, by ``n_ckpt``) to each checkpointed victim,
`admit_job` adds the restore cost of the tier the snapshot was placed on.
Both are O(1) gathers/scatters, so the non-eviction fast path does no
extra O(J) work.  The legacy two-column accessors (``cost_save``,
``cost_save2``, ``cost_restore``, ``cost_restore2``) remain as read-only
views over the lattice for compatibility.

Tiered eviction placement (`SchedulerConfig.cr_tiers`,
`core.crcost.TieredCRCostModel`): the runtime ``ckpt_tier`` column records
where each pending job's latest snapshot lives.  `apply_evictions` places
each victim greedily (cheapest feasible tier over the T lattice columns,
spilling down the hierarchy when capacity-bounded tiers are full) with a
short ``lax.scan`` in victim order — confined to the eviction branch, so
the admit fast path stays O(1) — and `admit_job` charges the restore cost
of the *placed* tier, then frees the slot.  Sizes may change at runtime
via `update_state_mib` (O(1) scatters recomputing the lattice rows with
the same arithmetic, no re-trace of the jitted scan).
"""
from __future__ import annotations

from functools import lru_cache, partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.crcost import MAX_STATE_MIB
from repro.core.types import JobClass, SchedulerConfig

# JobState encoding (matches types.JobState)
UNSUB, PENDING, RUNNING, DONE, KILLED = 0, 1, 2, 3, 4
BIG = jnp.int32(2**30)
#: infeasible-tier sentinel for the placement argmin: larger than any real
#: lattice entry (costs saturate at cap_ticks << int32 max)
MASK = jnp.int32(jnp.iinfo(jnp.int32).max)
NONP = int(JobClass.NON_PREEMPTIBLE)
CKPT = int(JobClass.CHECKPOINTABLE)


class JobTable(NamedTuple):
    """Static job attributes + mutable runtime state, all [J]-shaped."""

    jid: jax.Array         # int32 job id — the tie-break identity.  For a
    #   monolithic table rows are sorted by id, so this is order-isomorphic
    #   to the row index (schedules unchanged); for the streaming engine a
    #   recycled slot keeps the job's true id, so queue/victim tie-breaking
    #   stays bit-identical to the monolithic run (DESIGN.md §Batched
    #   execution).  Pad rows carry BIG.
    user: jax.Array        # int32 user index
    cpus: jax.Array        # int32
    work: jax.Array        # int32 work units
    priority: jax.Array    # int32
    jclass: jax.Array      # int32 JobClass
    submit: jax.Array      # int32 tick
    state_mib: jax.Array   # int32 checkpoint image size (MiB)
    # The [J, T] C/R cost lattice, precomputed from (cfg.cr_cost /
    # cr_tiers, cfg.cr_overhead, state_mib): sizes are static per job
    # (until `update_state_mib`), so the model evaluates once at table
    # build and the passes pay only an O(1) gather per charge.  Column k
    # prices tier k of ``cfg.cr_tiers`` (T=1 untiered); tier 0 is the
    # fastest tier, the last column the durable spill target.
    cost_save_lat: jax.Array     # int32 [J, T] FIRST-save cost per tier
    cost_rsave_lat: jax.Array    # int32 [J, T] RECURRENT (delta) save cost
    cost_restore_lat: jax.Array  # int32 [J, T] restore cost per tier
    # runtime
    state: jax.Array       # int32 JobState
    progress: jax.Array
    run_start: jax.Array
    first_start: jax.Array
    finish: jax.Array
    n_preempt: jax.Array
    n_ckpt: jax.Array
    overhead: jax.Array
    backfilled: jax.Array  # int32 0/1: ever admitted by queue-jumping
    ckpt_tier: jax.Array   # int32 tier holding the latest snapshot (-1: none)
    n_spill: jax.Array     # int32 checkpoints placed beyond the fast tier

    # Legacy two-column accessors, kept as read-only VIEWS over the lattice
    # during the [J, T] migration (DESIGN.md §Cost lattice).  ``...``
    # indexing keeps them correct for batched [B, J, T] tables too.  With
    # T=1 fast==durable (the old untiered aliasing); with T=2 these are
    # bit-exactly the old columns.  They are deliberately NOT fields: the
    # column-dataflow contract (`repro.analysis`) tracks lattice columns.
    @property
    def cost_save(self) -> jax.Array:
        """Fast-tier (tier 0) first-save cost — view of cost_save_lat."""
        return self.cost_save_lat[..., 0]

    @property
    def cost_save2(self) -> jax.Array:
        """Durable-tier (last) first-save cost — view of cost_save_lat."""
        return self.cost_save_lat[..., -1]

    @property
    def cost_restore(self) -> jax.Array:
        """Fast-tier restore cost — view of cost_restore_lat."""
        return self.cost_restore_lat[..., 0]

    @property
    def cost_restore2(self) -> jax.Array:
        """Durable-tier restore cost — view of cost_restore_lat."""
        return self.cost_restore_lat[..., -1]


def table_from_jobs(jobs, users, cpu_total: int,
                    config: Optional[SchedulerConfig] = None,
                    ) -> Tuple[JobTable, jax.Array]:
    """Build ``(JobTable, entitled_cpus[U])`` from core.types objects.

    Rows are ordered by job id, matching the Python backend's job table, so
    per-row signatures are directly comparable across backends.  ``config``
    supplies the C/R cost model: the per-job save/restore cost columns are
    evaluated here with Python integers — the exact arithmetic the Python
    backend charges at runtime — so cross-backend bit-equality holds by
    construction.  ``config=None`` builds a free-C/R table (legacy callers).
    """
    uidx = {u.name: i for i, u in enumerate(users)}
    j = sorted(jobs, key=lambda x: x.id)
    n = len(j)
    cfg = config if config is not None else SchedulerConfig()
    n_tiers = cfg.n_cost_tiers
    arr = lambda f, d=jnp.int32: jnp.asarray([f(x) for x in j], d)
    # the [J, T] lattices: evaluated per (job, tier) with Python ints —
    # the exact arithmetic omfs._evict / _start charge at runtime
    lat = lambda f: jnp.asarray(
        [[f(x, k) for k in range(n_tiers)] for x in j],
        jnp.int32).reshape(n, n_tiers)
    table = JobTable(
        jid=arr(lambda x: x.id),
        user=arr(lambda x: uidx[x.user]),
        cpus=arr(lambda x: x.cpus),
        work=arr(lambda x: x.work),
        priority=arr(lambda x: x.priority),
        jclass=arr(lambda x: int(x.job_class)),
        submit=arr(lambda x: x.submit_time),
        state_mib=arr(lambda x: x.state_mib),
        cost_save_lat=lat(
            lambda x, k: cfg.eviction_save_cost(x.state_mib, k)),
        cost_rsave_lat=lat(
            lambda x, k: cfg.eviction_save_cost(x.state_mib, k,
                                                recurrent=True)),
        cost_restore_lat=lat(
            lambda x, k: cfg.restart_restore_cost(x.state_mib, k)),
        state=jnp.full((n,), UNSUB, jnp.int32),
        progress=jnp.zeros((n,), jnp.int32),
        run_start=jnp.full((n,), -1, jnp.int32),
        first_start=jnp.full((n,), -1, jnp.int32),
        finish=jnp.full((n,), -1, jnp.int32),
        n_preempt=jnp.zeros((n,), jnp.int32),
        n_ckpt=jnp.zeros((n,), jnp.int32),
        overhead=jnp.zeros((n,), jnp.int32),
        backfilled=arr(lambda x: int(x.backfilled)),
        ckpt_tier=jnp.full((n,), -1, jnp.int32),
        n_spill=jnp.zeros((n,), jnp.int32),
    )
    return table, entitlements(users, cpu_total)


def entitlements(users, cpu_total: int) -> jnp.ndarray:
    return jnp.asarray([u.entitled_cpus(cpu_total) for u in users], jnp.int32)


class Knobs(NamedTuple):
    """Per-cell *traced* scheduling knobs for the batched sweep engine.

    A sequential `simulate` bakes ``cfg.quantum`` and ``pass_depth`` into
    the trace as Python constants — sweeping them means one XLA program
    per grid point.  `engine.simulate_batch` instead threads them through
    the pass as int32 scalars (one per batch cell under ``vmap``), so ONE
    compiled program covers the whole quantum×pass_depth grid.  Passes
    read them only when ``knobs is not None``; the default path traces
    exactly as before (bit-identity with the per-cell programs is asserted
    by tests/test_simulate_batch.py).

    ``depth`` bounds the per-tick queue sweep by *masking* loop iterations
    past it (the fori_loop still runs the full static trip count), which is
    result-identical to truncating the loop: a masked iteration admits
    nothing and the eviction branch is never taken.
    """

    quantum: jax.Array     # int32 — minimal uninterrupted run before evictable
    depth: jax.Array       # int32 — queue positions processed per tick


def default_knobs(cfg: SchedulerConfig,
                  pass_depth: Optional[int] = None) -> Knobs:
    return Knobs(quantum=jnp.int32(cfg.quantum),
                 depth=jnp.int32(BIG if pass_depth is None else pass_depth))


# ---------------------------------------------------------------------------
# JobTable primitives shared by every vectorized policy (OMFS + baselines)
# ---------------------------------------------------------------------------


def queue_order(tbl: JobTable) -> Tuple[jax.Array, jax.Array]:
    """Snapshot the submitted queue: (order[J], eligible[J]).

    Order is (-priority, submit, id) — the same key as queues.submitted_key —
    with ineligible rows pushed to the end.  The id tie-break is the ``jid``
    column (== row order for monolithic tables; the true job id for
    streaming tables whose slots are recycled)."""
    eligible = tbl.state == PENDING
    qkey = jnp.where(eligible, -tbl.priority, BIG)
    order = jnp.lexsort((tbl.jid, tbl.submit, qkey))
    return order, eligible


def running_usage(tbl: JobTable, num_users: int):
    """Aggregates at pass start: (usage[U], non_preemptible_usage[U], busy)."""
    running = tbl.state == RUNNING
    run_cpus = jnp.where(running, tbl.cpus, 0)
    usage = jax.ops.segment_sum(run_cpus, tbl.user, num_segments=num_users)
    nonp = jax.ops.segment_sum(
        jnp.where(running & (tbl.jclass == NONP), tbl.cpus, 0),
        tbl.user, num_segments=num_users)
    return usage, nonp, jnp.sum(run_cpus)


def admit_job(tbl: JobTable, idx: jax.Array, t: jax.Array,
              admit: jax.Array) -> JobTable:
    """Start job ``idx`` (lines 37-38) iff ``admit``; O(1) scatter updates.

    A job with a checkpoint (``n_ckpt > 0``) restarts by restoring its
    latest snapshot, so admission charges the restore cost of the tier the
    snapshot was *placed* on at eviction (``ckpt_tier``; lattice column 0
    when untiered) — the twin of ``omfs._start``.  The restore consumes
    the snapshot: ``ckpt_tier`` clears, freeing the placed tier's capacity
    for the next victim."""
    tier = jnp.maximum(tbl.ckpt_tier[idx], 0)
    restore = jnp.where(
        admit & (tbl.n_ckpt[idx] > 0),
        tbl.cost_restore_lat[idx, tier],
        0)
    return tbl._replace(
        state=tbl.state.at[idx].set(
            jnp.where(admit, RUNNING, tbl.state[idx])),
        run_start=tbl.run_start.at[idx].set(
            jnp.where(admit, t, tbl.run_start[idx])),
        first_start=tbl.first_start.at[idx].set(
            jnp.where(admit & (tbl.first_start[idx] < 0), t,
                      tbl.first_start[idx])),
        overhead=tbl.overhead.at[idx].add(restore),
        ckpt_tier=tbl.ckpt_tier.at[idx].set(
            jnp.where(admit, -1, tbl.ckpt_tier[idx])),
    )


def effective_save_lat(tbl: JobTable) -> jax.Array:
    """The ``[J, T]`` save costs evicting each job *now* would charge:
    recurrent (delta) rows for warm jobs (``n_ckpt > 0`` — they already
    hold a snapshot), first-save rows otherwise.  Evaluated before the
    pass bumps ``n_ckpt``, mirroring ``omfs._evict``'s pre-increment
    ``recurrent`` flag."""
    return jnp.where((tbl.n_ckpt > 0)[..., None],
                     tbl.cost_rsave_lat, tbl.cost_save_lat)


def tier_occupancy(tbl: JobTable, n_tiers: int) -> jax.Array:
    """Per-tier MiB held by evicted-and-pending snapshots, ``[T]`` — the
    twin of ``omfs._tier_occupancy`` (a restore consumes the slot:
    `admit_job` cleared ``ckpt_tier``)."""
    held = (tbl.state == PENDING) & (tbl.ckpt_tier >= 0)
    return jax.ops.segment_sum(
        jnp.where(held, tbl.state_mib, 0),
        jnp.clip(tbl.ckpt_tier, 0, n_tiers - 1), num_segments=n_tiers)


def victim_order(tbl: JobTable, cheap: bool = False) -> jax.Array:
    """Victim permutation.  Standard: ``(priority, run_start, id)`` —
    queues.running_victim_key.  ``cheap`` (the `omfs_cheap_victim` policy):
    ``(save_cost, priority, run_start, id)`` — cheapest-to-checkpoint
    first, priced at the fast tier with the delta-aware effective cost
    (warm jobs only rewrite their delta — queues.cheap_victim_key)."""
    if cheap:
        key = effective_save_lat(tbl)[..., 0]
        return jnp.lexsort((tbl.jid, tbl.run_start, tbl.priority, key))
    return jnp.lexsort((tbl.jid, tbl.run_start, tbl.priority))


def select_victims(tbl: JobTable, evictable: jax.Array, idle: jax.Array,
                   cpus_needed: jax.Array,
                   order: Optional[jax.Array] = None,
                   ) -> Tuple[jax.Array, jax.Array]:
    """The paper's while-loop (lines 32-36) as lexsort+cumsum: the minimal
    prefix of evictable jobs — in ``order`` (default: the standard victim
    key) — whose release makes ``cpus_needed`` fit.

    Returns (planned[J] victim mask, enough: idle + all evictable suffices)."""
    if order is None:
        order = victim_order(tbl)
    evict_sorted = evictable[order]
    cpus_sorted = jnp.where(evict_sorted, tbl.cpus[order], 0)
    freed_cum = jnp.cumsum(cpus_sorted)
    need = jnp.maximum(cpus_needed - idle, 0)
    prefix_needed = freed_cum - cpus_sorted < need   # victim still required
    planned_sorted = evict_sorted & prefix_needed
    enough = idle + freed_cum[-1] >= cpus_needed
    planned = jnp.zeros_like(evictable).at[order].set(planned_sorted)
    return planned, enough


def place_checkpoints(cfg: SchedulerConfig, tbl: JobTable, ckpt: jax.Array,
                      order: Optional[jax.Array] = None,
                      ) -> Tuple[jax.Array, jax.Array]:
    """Tier placement for the ``ckpt`` victims: greedy cheapest-feasible
    over the T lattice columns in victim ``order``, spilling down the
    hierarchy when capacity-bounded tiers are full.  Returns
    ``(tier[J], save_cost[J])`` (tier 0 / cost 0 on non-victims).

    Per victim the chosen tier is the first-occurrence ``argmin`` of its
    *effective* (delta-aware) save row over feasible tiers — bit-identical
    to `TieredCRCostModel.choose_tier`'s ascending scan with ties toward
    the faster tier, the last tier always feasible (UNBOUNDED invariant).
    Occupancy counts evicted-and-pending snapshots per tier (a restore
    consumed the slot — `admit_job` cleared the tier), plus the victims
    placed earlier in this very batch: the ``lax.scan`` walks the batch in
    victim order so a victim that doesn't fit spills while a later,
    smaller one may still claim the remaining space — exactly the
    sequential greedy the Python reference performs per `_evict` call."""
    tiers = cfg.cr_tiers
    assert tiers is not None
    n_tiers = tiers.n_tiers
    caps = jnp.asarray(tiers.capacity_mib, jnp.int32)
    if order is None:
        order = victim_order(tbl)
    ckpt_sorted = ckpt[order]
    lat_sorted = effective_save_lat(tbl)[order]          # [J, T]
    if all(c < 0 for c in tiers.capacity_mib):
        # every tier unbounded: no occupancy to carry, pure row-argmin
        tier_sorted = jnp.argmin(lat_sorted, axis=1).astype(jnp.int32)
    else:
        occ0 = tier_occupancy(tbl, n_tiers)
        mib_sorted = jnp.where(ckpt_sorted, tbl.state_mib[order], 0)

        def place(occ, x):
            want, mib, costs = x
            feasible = (caps < 0) | (occ + mib <= caps)
            tier = jnp.argmin(
                jnp.where(feasible, costs, MASK)).astype(jnp.int32)
            taken = jnp.where(want & (jnp.arange(n_tiers) == tier), mib, 0)
            return occ + taken, tier

        _, tier_sorted = jax.lax.scan(
            place, occ0, (ckpt_sorted, mib_sorted, lat_sorted))
    tier_sorted = jnp.where(ckpt_sorted, tier_sorted, 0)
    tier = jnp.zeros_like(tbl.ckpt_tier).at[order].set(tier_sorted)
    save = jnp.take_along_axis(
        effective_save_lat(tbl), tier[:, None], axis=1)[:, 0]
    save = jnp.where(ckpt, save, 0)
    return tier, save


def _tiered(cfg: SchedulerConfig) -> bool:
    return cfg.cr_tiers is not None and cfg.cr_tiers.n_tiers > 1


def plan_evictions(cfg: SchedulerConfig, tbl: JobTable, evictable: jax.Array,
                   idle: jax.Array, cpus_needed: jax.Array,
                   cheap: bool = False, order: Optional[jax.Array] = None):
    """The whole per-eviction decision, dispatched on ``cfg.kernel_backend``.

    Returns ``(planned, enough, order, placement)``: the minimal victim
    prefix, the feasibility bit, the victim order to reuse downstream
    (lax path only), and the precomputed ``(take_fast, save_cost)`` tier
    placement (pallas path only, ``None`` otherwise — `apply_evictions`
    computes it from ``order`` when absent).

    * ``"lax"`` — `victim_order` lexsort + `select_victims` cumsum cutoff;
      placement deferred to `place_checkpoints` inside `apply_evictions`.
    * ``"pallas"`` / ``"pallas_interpret"`` — the fused
      `kernels.sched_select` kernel: masked bitonic sort + prefix-sum
      cutoff + greedy T-tier placement over the effective save lattice in
      one ``pallas_call`` (interpret mode off-TPU, or always for
      ``"pallas_interpret"``).  Placement here is computed on the
      pre-feasibility-mask ``planned``; callers mask ``planned`` with an
      all-or-nothing scalar, and every table write in `apply_evictions` is
      gated on the masked victim set, so the results are bit-identical
      either way.

    The dispatch is a static Python branch on the (hashable, jit-static)
    config, so each backend traces its own program — toggling the flag
    selects a different lru-cached runner, never a retrace."""
    backend = cfg.kernel_backend
    if backend == "lax":
        if order is None:
            order = victim_order(tbl, cheap)
        planned, enough = select_victims(tbl, evictable, idle, cpus_needed,
                                         order)
        return planned, enough, order, None
    if backend not in ("pallas", "pallas_interpret"):
        raise ValueError(f"unknown SchedulerConfig.kernel_backend "
                         f"{backend!r}: expected 'lax', 'pallas' or "
                         f"'pallas_interpret'")
    from repro.kernels.sched_select.ops import plan_evictions_fused
    interpret = (backend == "pallas_interpret"
                 or jax.default_backend() != "tpu")
    tiered = _tiered(cfg)
    eff_lat = effective_save_lat(tbl)
    if tiered:
        caps = tuple(cfg.cr_tiers.capacity_mib)
        bounded = any(c >= 0 for c in caps)
        occ = tier_occupancy(tbl, cfg.cr_tiers.n_tiers)
        is_ckpt = tbl.jclass == CKPT
    else:
        caps = (-1,)
        bounded = False
        occ = jnp.zeros((1,), jnp.int32)
        is_ckpt = jnp.zeros_like(evictable)
    planned, enough, tier = plan_evictions_fused(
        tbl.priority, tbl.run_start, tbl.jid, eff_lat[..., 0],
        evictable, tbl.cpus, tbl.state_mib, is_ckpt, eff_lat,
        idle, cpus_needed, occ, jnp.asarray(caps, jnp.int32),
        cheap=cheap, tiered=tiered, bounded=bounded, interpret=interpret)
    placement = None
    if tiered:
        save = jnp.take_along_axis(eff_lat, tier[:, None], axis=1)[:, 0]
        placement = (tier, save)
    return planned, enough, None, placement


def apply_evictions(cfg: SchedulerConfig, t: jax.Array, tbl: JobTable,
                    planned: jax.Array,
                    order: Optional[jax.Array] = None,
                    placement: Optional[Tuple[jax.Array, jax.Array]] = None,
                    ) -> JobTable:
    """Lines 33-36 for every planned victim: checkpoint (or drop) and free.

    With ``cfg.cr_tiers`` set, each checkpointed victim is *placed* on a
    tier first (``placement`` precomputed by `plan_evictions`' fused
    kernel, else `place_checkpoints` in victim ``order``) and charged that
    tier's save cost; the placement is recorded in ``ckpt_tier`` so the
    later restore (`admit_job`) reads from the same tier."""
    is_ckpt = tbl.jclass == CKPT
    kill = planned & ~is_ckpt
    ckpt = planned & is_ckpt
    if _tiered(cfg):
        tier_of, save_cost = (place_checkpoints(cfg, tbl, ckpt, order)
                              if placement is None else placement)
        spilled = ckpt & (tier_of > 0)
    else:
        save_cost = effective_save_lat(tbl)[..., 0]
        tier_of = jnp.zeros_like(tbl.ckpt_tier)
        spilled = jnp.zeros_like(ckpt)
    return tbl._replace(
        state=jnp.where(
            ckpt, PENDING,
            jnp.where(kill, (KILLED if cfg.drop_killed else PENDING),
                      tbl.state)),
        progress=jnp.where(kill & (not cfg.drop_killed), 0, tbl.progress),
        overhead=tbl.overhead + jnp.where(ckpt, save_cost, 0),
        run_start=jnp.where(planned, -1, tbl.run_start),
        finish=jnp.where(kill & cfg.drop_killed, t, tbl.finish),
        n_preempt=tbl.n_preempt + planned.astype(jnp.int32),
        n_ckpt=tbl.n_ckpt + ckpt.astype(jnp.int32),
        ckpt_tier=jnp.where(ckpt, tier_of, tbl.ckpt_tier),
        n_spill=tbl.n_spill + spilled.astype(jnp.int32),
    )


# ---------------------------------------------------------------------------
# Reference pass: one Algorithm-1 admission, everything recomputed (O(J))
# ---------------------------------------------------------------------------


def _hoistable(cfg: SchedulerConfig, knobs: Optional[Knobs]) -> bool:
    """Whether one `victim_order` per tick serves every admission (the lax
    path's per-tick hoist).  Mid-pass admissions/evictions only move rows
    *out* of the evictable set when ``quantum >= 1`` (an admitted job has
    ``t - run_start == 0 < quantum``; an evicted one stops running), and
    untouched rows keep their keys — so the stale order restricted to the
    still-evictable rows is exactly the fresh order, which is all
    `select_victims` / `place_checkpoints` consume.  ``quantum == 0``
    (reachable: tests fuzz it) makes a just-admitted job immediately
    evictable under a *new* key, and a traced ``knobs.quantum`` cannot be
    inspected — both keep the faithful in-branch recompute."""
    return knobs is None and cfg.quantum >= 1


def _try_admit(cfg: SchedulerConfig, ent: jax.Array, t: jax.Array,
               tbl: JobTable, idx: jax.Array, eligible: jax.Array,
               cheap_victims: bool = False,
               knobs: Optional[Knobs] = None,
               order: Optional[jax.Array] = None) -> JobTable:
    """Process job ``idx`` (runner, lines 18-38); no-op unless eligible and
    still pending.  Kept as the un-optimized reference the incremental pass
    is benchmarked and property-tested against."""
    quantum = cfg.quantum if knobs is None else knobs.quantum
    running = tbl.state == RUNNING
    preempt_able = tbl.jclass != NONP

    ju = tbl.user[idx]
    jc = tbl.cpus[idx]
    same_user = tbl.user == ju
    non_p_usage = jnp.sum(jnp.where(running & same_user & ~preempt_able, tbl.cpus, 0))
    total_usage = jnp.sum(jnp.where(running & same_user, tbl.cpus, 0))
    busy = jnp.sum(jnp.where(running, tbl.cpus, 0))
    idle = cfg.cpu_total - busy
    entitled = ent[ju]

    job_non_p = tbl.jclass[idx] == NONP
    # line 23 (note >=): non-preemptible beyond (or exactly at) entitlement
    reject_23 = job_non_p & (non_p_usage + jc >= entitled)
    # line 26 (note >): enough idle -> run anyways
    admit_26 = idle > jc
    # line 28: request exceeds unused entitlement
    reject_28 = jc > entitled - total_usage

    # lines 31-36: victim selection among quantum-expired running jobs
    evictable = running & preempt_able & ((t - tbl.run_start) >= quantum)
    if cfg.avoid_self_eviction:                # beyond-paper flag
        evictable = evictable & ~same_user
    if cfg.victim_filter_over_entitlement:     # beyond-paper flag
        usage_per_user = jax.ops.segment_sum(
            jnp.where(running, tbl.cpus, 0), tbl.user, num_segments=ent.shape[0])
        over = usage_per_user[tbl.user] > ent[tbl.user]
        evictable = evictable & over

    planned, enough, order, placement = plan_evictions(
        cfg, tbl, evictable, idle, jc, cheap_victims, order)

    admit_evict = (~reject_23) & (~admit_26) & (~reject_28) & enough
    admit = eligible & (tbl.state[idx] == PENDING) & (~reject_23) & (
        admit_26 | admit_evict)
    do_evict = admit & (~admit_26)
    planned = planned & do_evict

    tbl = apply_evictions(cfg, t, tbl, planned, order, placement)
    return admit_job(tbl, idx, t, admit)


# ---------------------------------------------------------------------------
# The OMFS scheduling pass (policy contract: pass_fn(cfg, ent, t, tbl) -> tbl)
# ---------------------------------------------------------------------------


@lru_cache(maxsize=None)
def make_omfs_pass(pass_depth: Optional[int] = None, incremental: bool = True,
                   cheap_victims: bool = False):
    """Build the Algorithm-1 scheduling pass for `core.engine`.
    Memoized so repeated `engine.simulate` calls reuse the jitted scan.

    ``incremental=True`` threads (usage[U], non_preemptible_usage[U], busy)
    through the fori_loop carry — O(1) per admission decision on the
    idle-admit fast path and on every rejection — and defers the victim
    lexsort+cumsum to a ``lax.cond`` branch taken only when eviction is
    actually needed.  ``incremental=False`` is the original reference pass.

    ``cheap_victims=True`` is the `omfs_cheap_victim` registry policy:
    victims order by ``(save_cost, priority, run_start, id)``.

    Every pass accepts an optional trailing ``knobs`` argument
    (`Knobs`): traced per-cell quantum / pass-depth overrides used by
    `engine.simulate_batch`.  ``knobs=None`` (every sequential caller)
    traces exactly the pre-batching program.
    """

    def pass_fn(cfg: SchedulerConfig, ent: jax.Array, t: jax.Array,
                tbl: JobTable, knobs: Optional[Knobs] = None) -> JobTable:
        n = tbl.cpus.shape[0]
        order, eligible = queue_order(tbl)
        depth = n if pass_depth is None else min(pass_depth, n)
        quantum = cfg.quantum if knobs is None else knobs.quantum

        # satellite hoist: one victim_order per tick (see _hoistable) —
        # the lax path reuses it across every admission of the pass; the
        # pallas kernel re-sorts internally (the fusion is the point), so
        # the hoisted lexsort would only be dead weight there.
        hoist = cfg.kernel_backend == "lax" and _hoistable(cfg, knobs)
        vorder0 = victim_order(tbl, cheap_victims) if hoist else None

        if not incremental:
            def body_ref(i, tbl):
                idx = order[i]
                elig = eligible[idx]
                if knobs is not None:
                    elig = elig & (i < knobs.depth)
                return _try_admit(cfg, ent, t, tbl, idx, elig,
                                  cheap_victims, knobs, vorder0)
            return jax.lax.fori_loop(0, depth, body_ref, tbl)

        usage0, nonp0, busy0 = running_usage(tbl, ent.shape[0])

        def body(i, carry):
            tbl, usage, nonp_usage, busy = carry
            idx = order[i]
            ju = tbl.user[idx]
            jc = tbl.cpus[idx]
            pending_now = eligible[idx] & (tbl.state[idx] == PENDING)
            if knobs is not None:
                pending_now = pending_now & (i < knobs.depth)
            job_non_p = tbl.jclass[idx] == NONP
            idle = cfg.cpu_total - busy
            # lines 23 / 26 / 28 from the carried aggregates — O(1)
            reject_23 = job_non_p & (nonp_usage[ju] + jc >= ent[ju])
            admit_26 = idle > jc
            reject_28 = jc > ent[ju] - usage[ju]
            ok = pending_now & ~reject_23
            fast_admit = ok & admit_26
            need_evict = ok & ~admit_26 & ~reject_28

            def evict_case(carry):
                tbl, usage, nonp_usage, busy = carry
                running = tbl.state == RUNNING
                preempt_able = tbl.jclass != NONP
                evictable = running & preempt_able & (
                    (t - tbl.run_start) >= quantum)
                if cfg.avoid_self_eviction:            # beyond-paper flag
                    evictable = evictable & (tbl.user != ju)
                if cfg.victim_filter_over_entitlement:  # beyond-paper flag
                    evictable = evictable & (usage[tbl.user] > ent[tbl.user])
                planned, enough, vorder, placement = plan_evictions(
                    cfg, tbl, evictable, idle, jc, cheap_victims, vorder0)
                admit = enough
                planned = planned & admit
                freed = jnp.where(planned, tbl.cpus, 0)
                tbl = apply_evictions(cfg, t, tbl, planned, vorder, placement)
                usage = usage - jax.ops.segment_sum(
                    freed, tbl.user, num_segments=ent.shape[0])
                busy = busy - jnp.sum(freed)
                tbl = admit_job(tbl, idx, t, admit)
                grant = jnp.where(admit, jc, 0)
                usage = usage.at[ju].add(grant)
                nonp_usage = nonp_usage.at[ju].add(
                    jnp.where(job_non_p, grant, 0))
                busy = busy + grant
                return tbl, usage, nonp_usage, busy

            tbl, usage, nonp_usage, busy = jax.lax.cond(
                need_evict, evict_case, lambda c: c,
                (tbl, usage, nonp_usage, busy))

            # idle-admit fast path: no victim machinery, O(1) updates
            tbl = admit_job(tbl, idx, t, fast_admit)
            grant = jnp.where(fast_admit, jc, 0)
            usage = usage.at[ju].add(grant)
            nonp_usage = nonp_usage.at[ju].add(jnp.where(job_non_p, grant, 0))
            busy = busy + grant
            return tbl, usage, nonp_usage, busy

        tbl, _, _, _ = jax.lax.fori_loop(
            0, depth, body, (tbl, usage0, nonp0, busy0))
        return tbl

    return pass_fn


# ---------------------------------------------------------------------------
# Thin adapters over core.engine (kept for API compatibility)
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("cfg", "pass_depth"))
def omfs_tick(cfg: SchedulerConfig, ent: jax.Array, tbl: JobTable, t: jax.Array,
              pass_depth: Optional[int] = None) -> JobTable:
    """One engine tick with the (incremental) OMFS pass."""
    from repro.core import engine
    return engine.tick_jax(cfg, ent, tbl, t, make_omfs_pass(pass_depth))


def simulate_jax(
    users, jobs, cfg: SchedulerConfig, horizon: int,
    pass_depth: Optional[int] = None, incremental: bool = True,
    cheap_victims: bool = False,
) -> Tuple[JobTable, jax.Array]:
    """Run the full fleet simulation; returns (final table, busy[t] series)."""
    from repro.core import engine
    return engine.run_jax(users, jobs, cfg, horizon,
                          make_omfs_pass(pass_depth, incremental,
                                         cheap_victims))


def update_state_mib(tbl: JobTable, idx, state_mib,
                     config: SchedulerConfig) -> JobTable:
    """Grow/shrink job ``idx``'s checkpoint image at runtime — O(1) scatters.

    Real training state changes size (optimizer warmup grows it, quantized
    fast-tier saves shrink it); this hook rewrites ``state_mib`` and
    re-evaluates the per-tier cost columns with the SAME integer arithmetic
    `table_from_jobs` used at build time (`CRCostModel` evaluates on traced
    int32 just as on Python ints).  Shapes and dtypes are unchanged, so a
    jitted tick/scan compiled for the table keeps its cache — no re-trace.
    The Python backend needs no twin: it prices ``Job.state_mib`` at charge
    time, so assigning ``job.state_bytes`` is already enough.

    ``idx`` and ``state_mib`` may be Python ints or traced int32 scalars;
    ``config`` must be the same (static) config the pass runs under.
    """
    mib = jnp.clip(jnp.asarray(state_mib, jnp.int32), 0, MAX_STATE_MIB)
    flat = config.cr_overhead
    models = [config.tier_model(k) for k in range(config.n_cost_tiers)]
    row = lambda vals: jnp.stack(
        [jnp.asarray(v, jnp.int32) for v in vals])
    save_row = row([flat + m.save_cost(mib) for m in models])
    rsave_row = row([flat + m.recurrent_save_cost(mib) for m in models])
    restore_row = row([m.restore_cost(mib) for m in models])
    return tbl._replace(
        state_mib=tbl.state_mib.at[idx].set(mib),
        cost_save_lat=tbl.cost_save_lat.at[idx].set(save_row),
        cost_rsave_lat=tbl.cost_rsave_lat.at[idx].set(rsave_row),
        cost_restore_lat=tbl.cost_restore_lat.at[idx].set(restore_row),
    )


# ---------------------------------------------------------------------------
# Batch stacking + streaming-segment compaction (engine.simulate_batch /
# engine.simulate_stream build on these; DESIGN.md §Batched execution)
# ---------------------------------------------------------------------------

#: pad-row values per column; unlisted columns pad with 0.  A pad row is
#: inert by construction: ``submit=BIG`` never arrives (state stays UNSUB,
#: never PENDING/RUNNING), ``cpus=0`` so even a bug admitting one would
#: not move any aggregate, and ``jid=BIG`` keeps it last in every
#: tie-break.
_PAD_VALUES = {"jid": int(BIG), "submit": int(BIG), "run_start": -1,
               "first_start": -1, "finish": -1, "ckpt_tier": -1}


def pad_table(tbl: JobTable, rows: int) -> JobTable:
    """Grow ``tbl`` to ``rows`` with inert pad rows (identity if equal)."""
    n = tbl.cpus.shape[0]
    if rows == n:
        return tbl
    assert rows > n, f"cannot shrink table {n} -> {rows}"
    k = rows - n
    return JobTable(**{
        f: jnp.concatenate(
            [getattr(tbl, f),
             jnp.full((k,) + getattr(tbl, f).shape[1:],
                      _PAD_VALUES.get(f, 0), jnp.int32)])
        for f in JobTable._fields})


def is_pad(tbl: JobTable) -> jax.Array:
    """Mask of inert pad rows (see ``_PAD_VALUES``)."""
    return (tbl.jid == BIG) & (tbl.submit == BIG)


def stack_tables(tables, ents) -> Tuple[JobTable, jax.Array]:
    """Stack per-cell ``(JobTable[Ji], ent[Ui])`` pairs onto a leading
    batch axis: pad every table to max(Ji) rows (inert rows, see
    `pad_table`) and every entitlement vector to max(Ui) users (0 CPUs —
    a user that owns no rows and can admit nothing), then stack.

    The result feeds ``jax.vmap`` over axis 0; per-cell schedules are
    unaffected by the padding because pad rows are never eligible, never
    running, and sort last in every queue/victim key."""
    rows = max(t.cpus.shape[0] for t in tables)
    n_users = max(e.shape[0] for e in ents)
    padded = [pad_table(t, rows) for t in tables]
    ents = [jnp.concatenate(
        [e, jnp.zeros((n_users - e.shape[0],), jnp.int32)])
        if e.shape[0] < n_users else e for e in ents]
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *padded)
    return stacked, jnp.stack(ents)


@partial(jax.jit, donate_argnums=(0,))
def insert_rows(tbl: JobTable, slots: jax.Array, rows: JobTable,
                valid: jax.Array) -> JobTable:
    """Segment-compaction scatter for the streaming engine: overwrite
    ``tbl[slots[i]]`` with ``rows[i]`` where ``valid[i]``, keep the
    current row otherwise.

    ``slots`` MUST be a permutation of ``arange(J)`` (the caller sends
    every free slot first — new arrivals, then pad rows clearing the
    compacted-out finished jobs — and the occupied slots as write-back
    targets), so scatter indices never collide and the update is
    order-independent.  Donates the table: between segments exactly one
    [J]-shaped table exists.  One compile per table shape — segment
    boundaries never re-trace (`python -m repro.analysis`, rule: retrace).
    """
    def put(col, new):
        v = valid.reshape(valid.shape + (1,) * (col.ndim - 1))
        return col.at[slots].set(jnp.where(v, new, col[slots]))

    return JobTable(*[put(getattr(tbl, f), getattr(rows, f))
                      for f in JobTable._fields])


def signature_from_table(tbl: JobTable):
    """Same shape as SimResult.schedule_signature() for equivalence tests."""
    t = jax.device_get(tbl)
    return tuple(
        (int(i), int(t.state[i]), int(t.first_start[i]), int(t.finish[i]),
         int(t.progress[i]), int(t.n_preempt[i]), int(t.n_ckpt[i]))
        for i in range(t.state.shape[0])
    )


def tables_equal(a: JobTable, b: JobTable) -> bool:
    """Fast whole-table schedule equality (the fields of the signature)."""
    import numpy as np
    fields = ("state", "first_start", "finish", "progress", "n_preempt",
              "n_ckpt")
    a, b = jax.device_get(a), jax.device_get(b)
    return all(np.array_equal(getattr(a, f), getattr(b, f)) for f in fields)
