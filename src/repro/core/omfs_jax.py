"""OMFS vectorized in JAX: the paper's contribution as a composable module.

The whole scheduler state is a table of fixed-size arrays (`JobTable`); the
tick protocol (arrivals -> progress/completions -> scheduling pass) is defined
once in `core.engine` and shared by every policy and backend.  This module
owns the table representation, the JobTable *primitives* every vectorized
policy builds on (queue ordering, admission, victim selection/eviction), and
the two OMFS passes:

* ``make_omfs_pass(incremental=False)`` — the original reference pass: each
  admission recomputes O(J) masked usage sums and a fresh ``lexsort`` for
  victim selection, faithful but O(J log J) per queue position.
* ``make_omfs_pass(incremental=True)`` — the optimized pass (the default):
  per-user usage ``[U]`` and the busy scalar ride the ``fori_loop`` carry and
  are updated in O(1) per admission; the idle-admit fast path touches no
  victim machinery at all, and the ``lexsort``+``cumsum`` victim selection
  runs only on the eviction branch of a ``lax.cond``.

Both produce bit-identical schedules (tests/test_policies_equivalence.py and
benchmarks/bench_sched_scale.py assert signature equality) — this is what
makes 1000+-node / 100k-job what-if simulation cheap.

Sequential admission is inherent to Algorithm 1 (each admission changes the
state the next decision sees), so the pass is a ``fori_loop`` over queue
positions; the ``pass_depth`` knob (same as SLURM's sched_max_job_start)
bounds it at scale.

C/R costs are size-aware (`core.crcost.CRCostModel`): the table carries
per-job ``state_mib`` plus precomputed ``cost_save``/``cost_restore``
columns (sizes are static, so the model evaluates once at build time), and
the shared primitives charge them — `apply_evictions` adds the save cost to
each checkpointed victim, `admit_job` adds the restore cost when a job with
an existing checkpoint restarts.  Both are O(1) scatters, so the
non-eviction fast path does no extra O(J) work.
"""
from __future__ import annotations

from functools import lru_cache, partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.crcost import CRCostModel
from repro.core.types import JobClass, SchedulerConfig

# JobState encoding (matches types.JobState)
UNSUB, PENDING, RUNNING, DONE, KILLED = 0, 1, 2, 3, 4
BIG = jnp.int32(2**30)
NONP = int(JobClass.NON_PREEMPTIBLE)
CKPT = int(JobClass.CHECKPOINTABLE)


class JobTable(NamedTuple):
    """Static job attributes + mutable runtime state, all [J]-shaped."""

    user: jax.Array        # int32 user index
    cpus: jax.Array        # int32
    work: jax.Array        # int32 work units
    priority: jax.Array    # int32
    jclass: jax.Array      # int32 JobClass
    submit: jax.Array      # int32 tick
    state_mib: jax.Array   # int32 checkpoint image size (MiB)
    # C/R costs precomputed from (cfg.cr_cost, cfg.cr_overhead, state_mib):
    # sizes are static per job, so the model evaluates once at table build
    # and the passes pay only an O(1) gather per charge
    cost_save: jax.Array       # int32 work units charged per checkpoint
    cost_restore: jax.Array    # int32 work units charged per restore
    # runtime
    state: jax.Array       # int32 JobState
    progress: jax.Array
    run_start: jax.Array
    first_start: jax.Array
    finish: jax.Array
    n_preempt: jax.Array
    n_ckpt: jax.Array
    overhead: jax.Array
    backfilled: jax.Array  # int32 0/1: ever admitted by queue-jumping


def table_from_jobs(jobs, users, cpu_total: int,
                    config: Optional[SchedulerConfig] = None,
                    ) -> Tuple[JobTable, jax.Array]:
    """Build ``(JobTable, entitled_cpus[U])`` from core.types objects.

    Rows are ordered by job id, matching the Python backend's job table, so
    per-row signatures are directly comparable across backends.  ``config``
    supplies the C/R cost model: the per-job save/restore cost columns are
    evaluated here with Python integers — the exact arithmetic the Python
    backend charges at runtime — so cross-backend bit-equality holds by
    construction.  ``config=None`` builds a free-C/R table (legacy callers).
    """
    uidx = {u.name: i for i, u in enumerate(users)}
    j = sorted(jobs, key=lambda x: x.id)
    n = len(j)
    model = config.cr_cost if config is not None else CRCostModel()
    flat = config.cr_overhead if config is not None else 0
    arr = lambda f, d=jnp.int32: jnp.asarray([f(x) for x in j], d)
    table = JobTable(
        user=arr(lambda x: uidx[x.user]),
        cpus=arr(lambda x: x.cpus),
        work=arr(lambda x: x.work),
        priority=arr(lambda x: x.priority),
        jclass=arr(lambda x: int(x.job_class)),
        submit=arr(lambda x: x.submit_time),
        state_mib=arr(lambda x: x.state_mib),
        cost_save=arr(lambda x: flat + model.save_cost(x.state_mib)),
        cost_restore=arr(lambda x: model.restore_cost(x.state_mib)),
        state=jnp.full((n,), UNSUB, jnp.int32),
        progress=jnp.zeros((n,), jnp.int32),
        run_start=jnp.full((n,), -1, jnp.int32),
        first_start=jnp.full((n,), -1, jnp.int32),
        finish=jnp.full((n,), -1, jnp.int32),
        n_preempt=jnp.zeros((n,), jnp.int32),
        n_ckpt=jnp.zeros((n,), jnp.int32),
        overhead=jnp.zeros((n,), jnp.int32),
        backfilled=arr(lambda x: int(x.backfilled)),
    )
    return table, entitlements(users, cpu_total)


def entitlements(users, cpu_total: int) -> jnp.ndarray:
    return jnp.asarray([u.entitled_cpus(cpu_total) for u in users], jnp.int32)


# ---------------------------------------------------------------------------
# JobTable primitives shared by every vectorized policy (OMFS + baselines)
# ---------------------------------------------------------------------------


def queue_order(tbl: JobTable) -> Tuple[jax.Array, jax.Array]:
    """Snapshot the submitted queue: (order[J], eligible[J]).

    Order is (-priority, submit, id) — the same key as queues.submitted_key —
    with ineligible rows pushed to the end."""
    n = tbl.cpus.shape[0]
    eligible = tbl.state == PENDING
    qkey = jnp.where(eligible, -tbl.priority, BIG)
    order = jnp.lexsort((jnp.arange(n), tbl.submit, qkey))
    return order, eligible


def running_usage(tbl: JobTable, num_users: int):
    """Aggregates at pass start: (usage[U], non_preemptible_usage[U], busy)."""
    running = tbl.state == RUNNING
    run_cpus = jnp.where(running, tbl.cpus, 0)
    usage = jax.ops.segment_sum(run_cpus, tbl.user, num_segments=num_users)
    nonp = jax.ops.segment_sum(
        jnp.where(running & (tbl.jclass == NONP), tbl.cpus, 0),
        tbl.user, num_segments=num_users)
    return usage, nonp, jnp.sum(run_cpus)


def admit_job(tbl: JobTable, idx: jax.Array, t: jax.Array,
              admit: jax.Array) -> JobTable:
    """Start job ``idx`` (lines 37-38) iff ``admit``; O(1) scatter updates.

    A job with a checkpoint (``n_ckpt > 0``) restarts by restoring its
    latest snapshot, so admission charges its precomputed restore cost —
    the twin of ``omfs._start``."""
    restore = jnp.where(admit & (tbl.n_ckpt[idx] > 0),
                        tbl.cost_restore[idx], 0)
    return tbl._replace(
        state=tbl.state.at[idx].set(
            jnp.where(admit, RUNNING, tbl.state[idx])),
        run_start=tbl.run_start.at[idx].set(
            jnp.where(admit, t, tbl.run_start[idx])),
        first_start=tbl.first_start.at[idx].set(
            jnp.where(admit & (tbl.first_start[idx] < 0), t,
                      tbl.first_start[idx])),
        overhead=tbl.overhead.at[idx].add(restore),
    )


def select_victims(tbl: JobTable, evictable: jax.Array, idle: jax.Array,
                   cpus_needed: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """The paper's while-loop (lines 32-36) as lexsort+cumsum: the minimal
    prefix of evictable jobs — ordered (priority asc, run_start asc, id asc),
    queues.running_victim_key — whose release makes ``cpus_needed`` fit.

    Returns (planned[J] victim mask, enough: idle + all evictable suffices)."""
    n = tbl.cpus.shape[0]
    order = jnp.lexsort((jnp.arange(n), tbl.run_start, tbl.priority))
    evict_sorted = evictable[order]
    cpus_sorted = jnp.where(evict_sorted, tbl.cpus[order], 0)
    freed_cum = jnp.cumsum(cpus_sorted)
    need = jnp.maximum(cpus_needed - idle, 0)
    prefix_needed = freed_cum - cpus_sorted < need   # victim still required
    planned_sorted = evict_sorted & prefix_needed
    enough = idle + freed_cum[-1] >= cpus_needed
    planned = jnp.zeros_like(evictable).at[order].set(planned_sorted)
    return planned, enough


def apply_evictions(cfg: SchedulerConfig, t: jax.Array, tbl: JobTable,
                    planned: jax.Array) -> JobTable:
    """Lines 33-36 for every planned victim: checkpoint (or drop) and free."""
    is_ckpt = tbl.jclass == CKPT
    kill = planned & ~is_ckpt
    ckpt = planned & is_ckpt
    return tbl._replace(
        state=jnp.where(
            ckpt, PENDING,
            jnp.where(kill, (KILLED if cfg.drop_killed else PENDING),
                      tbl.state)),
        progress=jnp.where(kill & (not cfg.drop_killed), 0, tbl.progress),
        overhead=tbl.overhead + jnp.where(ckpt, tbl.cost_save, 0),
        run_start=jnp.where(planned, -1, tbl.run_start),
        finish=jnp.where(kill & cfg.drop_killed, t, tbl.finish),
        n_preempt=tbl.n_preempt + planned.astype(jnp.int32),
        n_ckpt=tbl.n_ckpt + ckpt.astype(jnp.int32),
    )


# ---------------------------------------------------------------------------
# Reference pass: one Algorithm-1 admission, everything recomputed (O(J))
# ---------------------------------------------------------------------------


def _try_admit(cfg: SchedulerConfig, ent: jax.Array, t: jax.Array,
               tbl: JobTable, idx: jax.Array, eligible: jax.Array) -> JobTable:
    """Process job ``idx`` (runner, lines 18-38); no-op unless eligible and
    still pending.  Kept as the un-optimized reference the incremental pass
    is benchmarked and property-tested against."""
    running = tbl.state == RUNNING
    preempt_able = tbl.jclass != NONP

    ju = tbl.user[idx]
    jc = tbl.cpus[idx]
    same_user = tbl.user == ju
    non_p_usage = jnp.sum(jnp.where(running & same_user & ~preempt_able, tbl.cpus, 0))
    total_usage = jnp.sum(jnp.where(running & same_user, tbl.cpus, 0))
    busy = jnp.sum(jnp.where(running, tbl.cpus, 0))
    idle = cfg.cpu_total - busy
    entitled = ent[ju]

    job_non_p = tbl.jclass[idx] == NONP
    # line 23 (note >=): non-preemptible beyond (or exactly at) entitlement
    reject_23 = job_non_p & (non_p_usage + jc >= entitled)
    # line 26 (note >): enough idle -> run anyways
    admit_26 = idle > jc
    # line 28: request exceeds unused entitlement
    reject_28 = jc > entitled - total_usage

    # lines 31-36: victim selection among quantum-expired running jobs
    evictable = running & preempt_able & ((t - tbl.run_start) >= cfg.quantum)
    if cfg.avoid_self_eviction:                # beyond-paper flag
        evictable = evictable & ~same_user
    if cfg.victim_filter_over_entitlement:     # beyond-paper flag
        usage_per_user = jax.ops.segment_sum(
            jnp.where(running, tbl.cpus, 0), tbl.user, num_segments=ent.shape[0])
        over = usage_per_user[tbl.user] > ent[tbl.user]
        evictable = evictable & over

    planned, enough = select_victims(tbl, evictable, idle, jc)

    admit_evict = (~reject_23) & (~admit_26) & (~reject_28) & enough
    admit = eligible & (tbl.state[idx] == PENDING) & (~reject_23) & (
        admit_26 | admit_evict)
    do_evict = admit & (~admit_26)
    planned = planned & do_evict

    tbl = apply_evictions(cfg, t, tbl, planned)
    return admit_job(tbl, idx, t, admit)


# ---------------------------------------------------------------------------
# The OMFS scheduling pass (policy contract: pass_fn(cfg, ent, t, tbl) -> tbl)
# ---------------------------------------------------------------------------


@lru_cache(maxsize=None)
def make_omfs_pass(pass_depth: Optional[int] = None, incremental: bool = True):
    """Build the Algorithm-1 scheduling pass for `core.engine`.
    Memoized so repeated `engine.simulate` calls reuse the jitted scan.

    ``incremental=True`` threads (usage[U], non_preemptible_usage[U], busy)
    through the fori_loop carry — O(1) per admission decision on the
    idle-admit fast path and on every rejection — and defers the victim
    lexsort+cumsum to a ``lax.cond`` branch taken only when eviction is
    actually needed.  ``incremental=False`` is the original reference pass.
    """

    def pass_fn(cfg: SchedulerConfig, ent: jax.Array, t: jax.Array,
                tbl: JobTable) -> JobTable:
        n = tbl.cpus.shape[0]
        order, eligible = queue_order(tbl)
        depth = n if pass_depth is None else min(pass_depth, n)

        if not incremental:
            def body_ref(i, tbl):
                idx = order[i]
                return _try_admit(cfg, ent, t, tbl, idx, eligible[idx])
            return jax.lax.fori_loop(0, depth, body_ref, tbl)

        usage0, nonp0, busy0 = running_usage(tbl, ent.shape[0])

        def body(i, carry):
            tbl, usage, nonp_usage, busy = carry
            idx = order[i]
            ju = tbl.user[idx]
            jc = tbl.cpus[idx]
            pending_now = eligible[idx] & (tbl.state[idx] == PENDING)
            job_non_p = tbl.jclass[idx] == NONP
            idle = cfg.cpu_total - busy
            # lines 23 / 26 / 28 from the carried aggregates — O(1)
            reject_23 = job_non_p & (nonp_usage[ju] + jc >= ent[ju])
            admit_26 = idle > jc
            reject_28 = jc > ent[ju] - usage[ju]
            ok = pending_now & ~reject_23
            fast_admit = ok & admit_26
            need_evict = ok & ~admit_26 & ~reject_28

            def evict_case(carry):
                tbl, usage, nonp_usage, busy = carry
                running = tbl.state == RUNNING
                preempt_able = tbl.jclass != NONP
                evictable = running & preempt_able & (
                    (t - tbl.run_start) >= cfg.quantum)
                if cfg.avoid_self_eviction:            # beyond-paper flag
                    evictable = evictable & (tbl.user != ju)
                if cfg.victim_filter_over_entitlement:  # beyond-paper flag
                    evictable = evictable & (usage[tbl.user] > ent[tbl.user])
                planned, enough = select_victims(tbl, evictable, idle, jc)
                admit = enough
                planned = planned & admit
                freed = jnp.where(planned, tbl.cpus, 0)
                tbl = apply_evictions(cfg, t, tbl, planned)
                usage = usage - jax.ops.segment_sum(
                    freed, tbl.user, num_segments=ent.shape[0])
                busy = busy - jnp.sum(freed)
                tbl = admit_job(tbl, idx, t, admit)
                grant = jnp.where(admit, jc, 0)
                usage = usage.at[ju].add(grant)
                nonp_usage = nonp_usage.at[ju].add(
                    jnp.where(job_non_p, grant, 0))
                busy = busy + grant
                return tbl, usage, nonp_usage, busy

            tbl, usage, nonp_usage, busy = jax.lax.cond(
                need_evict, evict_case, lambda c: c,
                (tbl, usage, nonp_usage, busy))

            # idle-admit fast path: no victim machinery, O(1) updates
            tbl = admit_job(tbl, idx, t, fast_admit)
            grant = jnp.where(fast_admit, jc, 0)
            usage = usage.at[ju].add(grant)
            nonp_usage = nonp_usage.at[ju].add(jnp.where(job_non_p, grant, 0))
            busy = busy + grant
            return tbl, usage, nonp_usage, busy

        tbl, _, _, _ = jax.lax.fori_loop(
            0, depth, body, (tbl, usage0, nonp0, busy0))
        return tbl

    return pass_fn


# ---------------------------------------------------------------------------
# Thin adapters over core.engine (kept for API compatibility)
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("cfg", "pass_depth"))
def omfs_tick(cfg: SchedulerConfig, ent: jax.Array, tbl: JobTable, t: jax.Array,
              pass_depth: Optional[int] = None) -> JobTable:
    """One engine tick with the (incremental) OMFS pass."""
    from repro.core import engine
    return engine.tick_jax(cfg, ent, tbl, t, make_omfs_pass(pass_depth))


def simulate_jax(
    users, jobs, cfg: SchedulerConfig, horizon: int,
    pass_depth: Optional[int] = None, incremental: bool = True,
) -> Tuple[JobTable, jax.Array]:
    """Run the full fleet simulation; returns (final table, busy[t] series)."""
    from repro.core import engine
    return engine.run_jax(users, jobs, cfg, horizon,
                          make_omfs_pass(pass_depth, incremental))


def signature_from_table(tbl: JobTable):
    """Same shape as SimResult.schedule_signature() for equivalence tests."""
    t = jax.device_get(tbl)
    return tuple(
        (int(i), int(t.state[i]), int(t.first_start[i]), int(t.finish[i]),
         int(t.progress[i]), int(t.n_preempt[i]), int(t.n_ckpt[i]))
        for i in range(t.state.shape[0])
    )


def tables_equal(a: JobTable, b: JobTable) -> bool:
    """Fast whole-table schedule equality (the fields of the signature)."""
    import numpy as np
    fields = ("state", "first_start", "finish", "progress", "n_preempt",
              "n_ckpt")
    a, b = jax.device_get(a), jax.device_get(b)
    return all(np.array_equal(getattr(a, f), getattr(b, f)) for f in fields)
