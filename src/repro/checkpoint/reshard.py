"""Elastic restore: load a checkpoint onto a different mesh / slice shape.

Checkpoints store *global* arrays keyed by tree path (multi-host would store
chunk boxes; reassembly is the same code path).  Restore builds the target
template with ``eval_shape``, then ``device_put``s each global array with the
target NamedSharding — JAX slices out exactly the shards each device owns.

This is what lets the OMFS executor restart a preempted job on a smaller or
larger slice (elastic scaling), and a failed job on whatever capacity is
left (fault tolerance): the training loop is oblivious, it just receives a
TrainState with the new sharding.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.checkpoint import serialize


def restore_resharded(
    leaves: Dict[str, np.ndarray],
    template,
    shardings=None,
):
    """Fill ``template`` (ShapeDtypeStructs or arrays) from global leaves,
    placing each with the matching sharding (pytree like template, or None
    for default placement)."""
    shard_by_key = {}
    if shardings is not None:
        shard_by_key = {
            jax.tree_util.keystr(path): s
            for path, s in jax.tree_util.tree_flatten_with_path(shardings)[0]
        }

    def put(key, arr, tleaf):
        dtype = getattr(tleaf, "dtype", arr.dtype)
        arr = arr.astype(dtype) if arr.dtype != dtype else arr
        sh = shard_by_key.get(key)
        if sh is not None:
            return jax.device_put(arr, sh)
        return jax.device_put(arr)

    return serialize.fill_template(template, leaves, put=put)


def save_global(state) -> Dict[str, np.ndarray]:
    """Snapshot a (possibly sharded) pytree to host-global numpy arrays.

    With sharded inputs this performs the all-gather-to-host implicitly via
    ``jax.device_get`` on addressable shards (single-process: full arrays)."""
    return {k: np.asarray(jax.device_get(v)) for k, v in serialize.leaf_paths(state)}
