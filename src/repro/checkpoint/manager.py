"""CheckpointManager: the framework's DMTCP — one object per job.

Policy implemented (all knobs in ManagerConfig):
* every preemption / quantum boundary -> **fast-tier** snapshot (MemTier,
  the NVM analogue) — optionally delta-encoded against the previous one;
* every ``durable_every`` saves -> promote to **disk tier** (zstd), written
  **asynchronously** (training overlaps the I/O);
* ``keep_last`` durable checkpoints are retained, older ones GC'd;
* restore prefers the fastest tier, verifies integrity (crc in manifest),
  and can **reshard** onto a different mesh (elastic restart).
"""
from __future__ import annotations

import dataclasses
import time
from pathlib import Path
from typing import Any, Dict, Optional

import numpy as np

from repro.checkpoint import delta as delta_mod
from repro.checkpoint.async_writer import AsyncCheckpointer
from repro.checkpoint.reshard import restore_resharded, save_global
from repro.checkpoint.tiers import DiskTier, MemTier


@dataclasses.dataclass(frozen=True)
class ManagerConfig:
    root: Path
    mem_capacity_bytes: int = 4 << 30
    durable_every: int = 5         # promote every k-th save to disk
    keep_last: int = 2             # durable checkpoints retained
    use_delta: bool = True         # delta-encode fast-tier snapshots
    zstd_level: int = 3
    async_durable: bool = True


class CheckpointManager:
    def __init__(self, cfg: ManagerConfig):
        self.cfg = cfg
        self.mem = MemTier(cfg.mem_capacity_bytes)
        self.disk = DiskTier(Path(cfg.root), compress=cfg.zstd_level)
        self._async = AsyncCheckpointer(self.disk.save_leaves)
        self._save_count = 0
        self._last_leaves: Optional[Dict[str, np.ndarray]] = None
        self._delta_chain: Dict[str, Any] = {}   # name -> (blobs, meta, parent)
        self.timings: Dict[str, float] = {"fast_save_s": 0.0, "durable_save_s": 0.0}

    # -- save ----------------------------------------------------------------
    def save(self, step: int, state, *, durable: Optional[bool] = None) -> str:
        name = f"step_{step:08d}"
        t0 = time.perf_counter()
        leaves = save_global(state)
        if self.cfg.use_delta and self._last_leaves is not None:
            blobs, _sizes = delta_mod.encode_snapshot(
                leaves, self._last_leaves, level=self.cfg.zstd_level)
            meta = {k: (str(a.dtype), a.shape) for k, a in leaves.items()}
            parent = f"step_{self._last_step:08d}" if self._last_leaves is not None else None
            self._delta_chain[name] = (blobs, meta, parent)
        self.mem.save_leaves(name, leaves)
        self._last_leaves = leaves
        self._last_step = step
        self.timings["fast_save_s"] += time.perf_counter() - t0

        self._save_count += 1
        make_durable = durable if durable is not None else (
            self._save_count % self.cfg.durable_every == 0)
        if make_durable:
            t1 = time.perf_counter()
            if self.cfg.async_durable:
                self._async.save_leaves(name, leaves)
            else:
                self.disk.save_leaves(name, leaves)
            self._gc()
            self.timings["durable_save_s"] += time.perf_counter() - t1
        return name

    # -- restore -------------------------------------------------------------
    def restore(self, template, *, name: Optional[str] = None, shardings=None):
        """Latest (or named) snapshot -> pytree shaped like template."""
        self._async.wait()
        if name is None:
            names = sorted(set(self.mem.names()) | set(self.disk.names()))
            if not names:
                raise FileNotFoundError("no checkpoints")
            name = names[-1]
        if name in self.mem:
            leaves = self.mem.restore(name)
        else:
            leaves = self.disk.restore(name)
        return restore_resharded(leaves, template, shardings), name

    def latest_step(self) -> Optional[int]:
        names = sorted(set(self.mem.names()) | set(self.disk.names()))
        return int(names[-1].split("_")[1]) if names else None

    # -- misc -----------------------------------------------------------------
    def _gc(self) -> None:
        self._async.wait()
        names = self.disk.names()
        for old in names[: -self.cfg.keep_last]:
            self.disk.delete(old)

    def close(self):
        self._async.close()

