"""CheckpointManager: the framework's DMTCP — one object per job.

Policy implemented (all knobs in ManagerConfig):
* every preemption / quantum boundary -> **fast-tier** snapshot (MemTier,
  the NVM analogue) — optionally delta-encoded against the previous one;
* every ``durable_every`` saves -> promote to **disk tier** (zstd), written
  **asynchronously** (training overlaps the I/O);
* ``keep_last`` durable checkpoints are retained, older ones GC'd; the
  delta chain keeps the last ``delta_keep_last`` encoded snapshots and is
  *decodable*: a snapshot LRU-evicted from the fast tier can still be
  rebuilt by XOR-walking the chain from the nearest full snapshot;
* a snapshot too large for the fast tier writes through to the disk tier
  (the capacity bound is never silently blown);
* restore prefers the fastest tier, verifies integrity (crc in manifest),
  and can **reshard** onto a different mesh (elastic restart).

Most callers want `checkpoint.service.CheckpointService`, the facade that
adds unified stats and C/R cost-model calibration on top of this class.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from pathlib import Path
from typing import Dict, Optional, Tuple

import numpy as np

from repro.checkpoint import delta as delta_mod
from repro.checkpoint.async_writer import AsyncCheckpointer
from repro.checkpoint.reshard import restore_resharded, save_global
from repro.checkpoint.tiers import DiskTier, MemTier


@dataclasses.dataclass(frozen=True)
class ManagerConfig:
    root: Path
    mem_capacity_bytes: int = 4 << 30
    durable_every: int = 5         # promote every k-th save to disk
    keep_last: int = 2             # durable checkpoints retained
    use_delta: bool = True         # delta-encode fast-tier snapshots
    delta_keep_last: int = 8       # encoded snapshots kept in the chain
    zstd_level: int = 3
    async_durable: bool = True


class CheckpointManager:
    def __init__(self, cfg: ManagerConfig):
        self.cfg = cfg
        self.mem = MemTier(cfg.mem_capacity_bytes)
        self.disk = DiskTier(Path(cfg.root), compress=cfg.zstd_level)
        self._async = AsyncCheckpointer(self.disk.save_leaves)
        self._save_count = 0
        self._last_leaves: Optional[Dict[str, np.ndarray]] = None
        self._last_step: Optional[int] = None
        # name -> (blobs, meta, parent_name); bounded FIFO of delta-encoded
        # snapshots, decodable via _restore_from_chain
        self._delta_chain: "OrderedDict[str, Tuple]" = OrderedDict()
        self.timings: Dict[str, float] = {"fast_save_s": 0.0, "durable_save_s": 0.0}
        self.last_save_bytes = 0       # raw snapshot size of the last save
        self.last_restore_bytes = 0    # raw size of the last restored snapshot

    # -- save ----------------------------------------------------------------
    def save(self, step: int, state, *, durable: Optional[bool] = None) -> str:
        name = f"step_{step:08d}"
        t0 = time.perf_counter()
        leaves = save_global(state)
        self.last_save_bytes = sum(a.nbytes for a in leaves.values())
        if self.cfg.use_delta and self._last_leaves is not None:
            blobs, _sizes = delta_mod.encode_snapshot(
                leaves, self._last_leaves, level=self.cfg.zstd_level)
            meta = {k: (str(a.dtype), a.shape) for k, a in leaves.items()}
            parent = f"step_{self._last_step:08d}"
            self._delta_chain[name] = (blobs, meta, parent)
            while len(self._delta_chain) > self.cfg.delta_keep_last:
                self._delta_chain.popitem(last=False)
        oversized = False
        try:
            self.mem.save_leaves(name, leaves)
        except ValueError:
            oversized = True        # write through to the durable tier below
        self._last_leaves = leaves
        self._last_step = step
        self.timings["fast_save_s"] += time.perf_counter() - t0

        self._save_count += 1
        make_durable = durable if durable is not None else (
            self._save_count % self.cfg.durable_every == 0)
        if make_durable or oversized:
            t1 = time.perf_counter()
            if self.cfg.async_durable and not oversized:
                self._async.save_leaves(name, leaves)
            else:
                # oversized snapshots persist synchronously: the fast tier
                # holds no copy, so the write must land before we return
                self.disk.save_leaves(name, leaves)
            self._gc()
            self.timings["durable_save_s"] += time.perf_counter() - t1
        return name

    def drain(self) -> None:
        """Barrier on any in-flight async durable write.  Restore timing
        should exclude this (it is save-side I/O that happens to complete
        late), so timed callers drain first — see CheckpointService."""
        self._async.wait()

    @property
    def fast_capacity_mib(self) -> int:
        """MemTier capacity on the scheduler's whole-MiB grid (floor: the
        simulator must never place more than the real tier can hold) —
        feeds `TieredCRCostModel.from_stats` via the service facade."""
        return self.mem.capacity >> 20

    # -- restore -------------------------------------------------------------
    def names(self):
        """Every restorable snapshot: fast tier, durable tier, delta chain."""
        return sorted(set(self.mem.names()) | set(self.disk.names())
                      | set(self._delta_chain))

    def restore_leaves(self, name: str) -> Dict[str, np.ndarray]:
        """Raw leaves from the fastest tier holding ``name`` — falling back
        to decoding the delta chain from the nearest full snapshot."""
        if name in self.mem:
            leaves = self.mem.restore(name)
        elif name in self.disk:
            leaves = self.disk.restore(name)
        elif name in self._delta_chain:
            leaves = self._restore_from_chain(name)
        else:
            raise FileNotFoundError(f"snapshot {name} in no tier")
        self.last_restore_bytes = sum(a.nbytes for a in leaves.values())
        return leaves

    def _restore_from_chain(self, name: str) -> Dict[str, np.ndarray]:
        """Walk parent links back to a full snapshot, then XOR-decode
        forward.  Raises if the chain's base left every tier (evicted and
        never made durable)."""
        chain = []
        cur: Optional[str] = name
        while cur is not None and cur not in self.mem and cur not in self.disk:
            if cur not in self._delta_chain:
                raise FileNotFoundError(
                    f"snapshot {name}: chain base {cur} left every tier")
            entry = self._delta_chain[cur]
            chain.append(entry)
            cur = entry[2]
        if cur is None:
            raise FileNotFoundError(f"snapshot {name}: chain has no base")
        base = self.mem.restore(cur) if cur in self.mem else self.disk.restore(cur)
        for blobs, meta, _parent in reversed(chain):
            base = delta_mod.decode_snapshot(blobs, base, meta)
        return base

    def restore(self, template, *, name: Optional[str] = None, shardings=None):
        """Latest (or named) snapshot -> pytree shaped like template."""
        self._async.wait()
        if name is None:
            names = self.names()
            if not names:
                raise FileNotFoundError("no checkpoints")
            name = names[-1]
        leaves = self.restore_leaves(name)
        return restore_resharded(leaves, template, shardings), name

    def latest_step(self) -> Optional[int]:
        names = self.names()
        return int(names[-1].split("_")[1]) if names else None

    # -- misc -----------------------------------------------------------------
    def _gc(self) -> None:
        self._async.wait()
        names = self.disk.names()
        for old in names[: -self.cfg.keep_last]:
            self.disk.delete(old)

    def close(self):
        self._async.close()
