"""Delta checkpoints: XOR-vs-parent + zstd — recurrent C/R made cheap.

The paper's thrashing cost is dominated by writing the full job image on
every preemption.  Between two checkpoints of the *same* job, most bytes of
the optimizer state barely move: XOR of the raw bit patterns against the
parent snapshot is highly compressible (exponent/sign bytes mostly zero).
We store per leaf whichever is smaller: zstd(xor-delta) or zstd(raw), and
rebuild by XOR-ing back onto the parent chain.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import numpy as np

try:
    import zstandard as zstd
except ImportError:  # pragma: no cover
    zstd = None       # stdlib zlib below keeps deltas functional


@dataclass
class DeltaBlob:
    data: bytes
    is_delta: bool
    nbytes_raw: int


def _compress(buf: bytes, level: int) -> bytes:
    if zstd is None:
        # zstd unavailable: zlib is slower but the XOR-delta compressibility
        # argument (mostly-zero exponent/sign bytes) holds identically
        return zlib.compress(buf, min(max(level, 1), 9))
    return zstd.ZstdCompressor(level=level).compress(buf)


def _decompress(buf: bytes, nbytes: int) -> bytes:
    if zstd is None:
        return zlib.decompress(buf)
    return zstd.ZstdDecompressor().decompress(buf, max_output_size=nbytes)


def encode_leaf(
    new: np.ndarray, base: Optional[np.ndarray], *, level: int = 3
) -> DeltaBlob:
    raw = new.tobytes()
    raw_c = _compress(raw, level)
    if base is None or base.nbytes != new.nbytes:
        return DeltaBlob(raw_c, False, len(raw))
    x = np.bitwise_xor(
        np.frombuffer(raw, np.uint8),
        np.frombuffer(base.tobytes(), np.uint8),
    ).tobytes()
    x_c = _compress(x, level)
    if len(x_c) < len(raw_c):
        return DeltaBlob(x_c, True, len(raw))
    return DeltaBlob(raw_c, False, len(raw))


def decode_leaf(
    blob: DeltaBlob, base: Optional[np.ndarray], dtype, shape
) -> np.ndarray:
    raw = _decompress(blob.data, blob.nbytes_raw)
    if blob.is_delta:
        assert base is not None
        raw = np.bitwise_xor(
            np.frombuffer(raw, np.uint8),
            np.frombuffer(base.tobytes(), np.uint8),
        ).tobytes()
    return np.frombuffer(raw, dtype=np.dtype(dtype)).reshape(shape)


def encode_snapshot(
    new_leaves: Dict[str, np.ndarray],
    base_leaves: Optional[Dict[str, np.ndarray]],
    *,
    level: int = 3,
) -> Tuple[Dict[str, DeltaBlob], Dict[str, int]]:
    blobs, sizes = {}, {}
    for k, arr in new_leaves.items():
        base = base_leaves.get(k) if base_leaves else None
        blob = encode_leaf(arr, base, level=level)
        blobs[k] = blob
        sizes[k] = len(blob.data)
    return blobs, sizes


def decode_snapshot(
    blobs: Dict[str, DeltaBlob],
    base_leaves: Optional[Dict[str, np.ndarray]],
    meta: Dict[str, Tuple[str, tuple]],
) -> Dict[str, np.ndarray]:
    out = {}
    for k, blob in blobs.items():
        dtype, shape = meta[k]
        base = base_leaves.get(k) if base_leaves else None
        out[k] = decode_leaf(blob, base, dtype, shape)
    return out
