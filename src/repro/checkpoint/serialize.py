"""Checkpoint serialization: pytree -> per-leaf binary blobs + JSON manifest.

Leaves are keyed by their *tree path* (stable across processes and code
versions), so restore fills a template pytree produced by ``eval_shape`` —
the restoring job never needs to unpickle foreign structure.  The manifest
records shape/dtype/bytes/crc per leaf; a multi-host deployment would write
per-shard chunks with global-offset boxes (single-process here: one blob per
leaf; the chunk fields are already in the manifest schema).
"""
from __future__ import annotations

import json
import zlib
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

try:
    import zstandard as zstd
except ImportError:  # pragma: no cover
    zstd = None

MANIFEST = "manifest.json"


def leaf_paths(tree) -> List[Tuple[str, Any]]:
    """[(path_key, leaf), ...] with deterministic, readable path keys."""
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


def _to_numpy(leaf) -> np.ndarray:
    return np.asarray(jax.device_get(leaf))


def save_tree(
    tree,
    out_dir: Path,
    *,
    compress: Optional[int] = None,      # zstd level, None = raw
) -> Dict:
    """Serialize a pytree; returns the manifest dict."""
    return save_leaf_dict(dict(leaf_paths(tree)), out_dir, compress=compress)


def save_leaf_dict(
    leaves_by_key: Dict[str, Any],
    out_dir: Path,
    *,
    compress: Optional[int] = None,
) -> Dict:
    """Serialize an already-flattened {path_key: array} dict (tier promotion
    path — keys must stay exactly as the original tree produced them)."""
    out_dir = Path(out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    manifest: Dict[str, Any] = {"leaves": {}, "compress": compress}
    for i, (key, leaf) in enumerate(sorted(leaves_by_key.items())):
        arr = _to_numpy(leaf)
        raw = arr.tobytes()
        blob = raw
        if compress and zstd is not None:
            blob = zstd.ZstdCompressor(level=compress).compress(raw)
        fname = f"leaf_{i:05d}.bin"
        (out_dir / fname).write_bytes(blob)
        manifest["leaves"][key] = {
            "file": fname,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "nbytes_raw": len(raw),
            "nbytes_stored": len(blob),
            "crc32": zlib.crc32(raw),
            # chunk metadata (multi-host layout; single chunk here)
            "chunks": [{"offset": [0] * arr.ndim, "shape": list(arr.shape)}],
        }
    (out_dir / MANIFEST).write_text(json.dumps(manifest, indent=1))
    return manifest


def load_manifest(in_dir: Path) -> Dict:
    return json.loads((Path(in_dir) / MANIFEST).read_text())


def load_leaves(in_dir: Path, *, verify: bool = True) -> Dict[str, np.ndarray]:
    """path_key -> numpy array (host memory)."""
    in_dir = Path(in_dir)
    manifest = load_manifest(in_dir)
    out = {}
    for key, meta in manifest["leaves"].items():
        blob = (in_dir / meta["file"]).read_bytes()
        if manifest.get("compress") and zstd is not None:
            blob = zstd.ZstdDecompressor().decompress(blob, max_output_size=meta["nbytes_raw"])
        if verify and zlib.crc32(blob) != meta["crc32"]:
            raise IOError(f"checkpoint corruption in {key} ({meta['file']})")
        out[key] = np.frombuffer(blob, dtype=np.dtype(meta["dtype"])).reshape(meta["shape"])
    return out


def fill_template(template, leaves: Dict[str, np.ndarray], *,
                  put: Optional[Callable] = None):
    """Rebuild a pytree from ``leaves`` using ``template``'s structure.

    ``put`` maps (path_key, np_array, template_leaf) -> leaf (default:
    jnp.asarray with the template dtype) — reshard.py passes a device_put
    with the target sharding here.
    """
    import jax.numpy as jnp

    flat = jax.tree_util.tree_flatten_with_path(template)
    paths_leaves, treedef = flat
    rebuilt = []
    for path, tleaf in paths_leaves:
        key = jax.tree_util.keystr(path)
        if key not in leaves:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = leaves[key]
        expect = tuple(getattr(tleaf, "shape", arr.shape))
        if tuple(arr.shape) != expect:
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {expect}")
        if put is not None:
            rebuilt.append(put(key, arr, tleaf))
        else:
            rebuilt.append(jnp.asarray(arr, dtype=getattr(tleaf, "dtype", None)))
    return jax.tree_util.tree_unflatten(treedef, rebuilt)


def tree_bytes(tree) -> int:
    return sum(
        int(np.prod(l.shape)) * np.dtype(l.dtype).itemsize
        for l in jax.tree.leaves(tree)
    )
