"""Asynchronous checkpoint persistence: overlap training with I/O.

``snapshot`` (device -> host copy) is synchronous and cheap; the durable
write happens on a background thread.  The next save (or an explicit
``wait``) barriers on the previous write — the standard async-checkpoint
contract (at most one in-flight write, training never blocked on disk).
"""
from __future__ import annotations

import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Callable, Dict, Optional

import numpy as np

from repro.checkpoint.reshard import save_global


class AsyncCheckpointer:
    def __init__(self, write_fn: Callable[[str, Dict[str, np.ndarray]], None]):
        """write_fn(name, leaves) performs the durable write."""
        self._write_fn = write_fn
        self._pool = ThreadPoolExecutor(max_workers=1, thread_name_prefix="ckpt")
        self._inflight: Optional[Future] = None
        self._lock = threading.Lock()

    def save(self, name: str, state) -> Future:
        """Synchronously snapshot to host, asynchronously persist."""
        return self.save_leaves(name, save_global(state))

    def save_leaves(self, name: str, leaves: Dict[str, np.ndarray]) -> Future:
        """Persist an already-flattened snapshot (device->host done)."""
        with self._lock:
            if self._inflight is not None:
                self._inflight.result()      # one write in flight at a time
            self._inflight = self._pool.submit(self._write_fn, name, leaves)
            return self._inflight

    def wait(self) -> None:
        with self._lock:
            if self._inflight is not None:
                self._inflight.result()
                self._inflight = None

    def close(self) -> None:
        self.wait()
        self._pool.shutdown(wait=True)
