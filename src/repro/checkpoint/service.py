"""CheckpointService: ONE save/restore/stats facade over `checkpoint/`.

The subsystem has four moving parts — `CheckpointManager` (tier policy +
delta chain), `MemTier`/`DiskTier` (storage), `delta` (XOR+compress codec),
`AsyncCheckpointer` (overlapped durable writes).  Consumers should not care:
the executor, the benchmarks, and any future agent talk to this facade and
get

* ``save(step, state)`` / ``restore(template)`` — the DMTCP-style
  transparent C/R pair, timed and byte-counted;
* ``stats()`` — one `CRStats` aggregate over every tier (bytes moved, wall
  seconds, save/restore counts);
* ``calibrate(tick_seconds, tiers=...)`` — the bridge to the scheduler:
  measured bandwidths become a `core.crcost.CRCostModel` (``tiers=None``)
  or the `TieredCRCostModel` cost lattice (``tiers=("mem", "disk")``), so
  the simulated cost-per-eviction and the real executor's measured
  overhead are expressed in the same units (DESIGN.md §C/R cost model,
  §Cost lattice).  ``calibrate_tiered`` remains as a deprecated shim.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Dict, Optional, Sequence, Tuple

from repro.checkpoint.manager import CheckpointManager, ManagerConfig
from repro.core.crcost import (
    DEFAULT_CAP_TICKS,
    UNBOUNDED,
    CRCostModel,
    TieredCRCostModel,
)


@dataclasses.dataclass
class CRStats:
    """Aggregate C/R traffic, in the shape `CRCostModel.from_stats` reads."""

    saves: int = 0
    restores: int = 0
    bytes_saved: int = 0
    bytes_restored: int = 0
    save_seconds: float = 0.0
    restore_seconds: float = 0.0

    @property
    def save_bytes_per_s(self) -> float:
        return self.bytes_saved / self.save_seconds if self.save_seconds else 0.0

    @property
    def restore_bytes_per_s(self) -> float:
        return (self.bytes_restored / self.restore_seconds
                if self.restore_seconds else 0.0)


class CheckpointService:
    """The single entry point to the checkpoint subsystem (facade)."""

    def __init__(self, cfg: ManagerConfig):
        self.manager = CheckpointManager(cfg)
        self._stats = CRStats()
        self.last_save_seconds = 0.0
        self.last_restore_seconds = 0.0

    # -- the save/restore protocol -------------------------------------------
    def save(self, step: int, state, *, durable: Optional[bool] = None) -> str:
        t0 = time.perf_counter()
        name = self.manager.save(step, state, durable=durable)
        dt = time.perf_counter() - t0
        self.last_save_seconds = dt
        self._stats.saves += 1
        self._stats.bytes_saved += self.manager.last_save_bytes
        self._stats.save_seconds += dt
        return name

    def restore(self, template, *, name: Optional[str] = None, shardings=None):
        # drain the async durable writer OUTSIDE the timed window: a pending
        # background save completing late is save-side I/O, and charging it
        # as restore would invert the calibrated save/restore bandwidths
        self.manager.drain()
        t0 = time.perf_counter()
        state, name = self.manager.restore(
            template, name=name, shardings=shardings)
        dt = time.perf_counter() - t0
        self.last_restore_seconds = dt
        self._stats.restores += 1
        self._stats.bytes_restored += self.manager.last_restore_bytes
        self._stats.restore_seconds += dt
        return state, name

    def drain(self) -> None:
        self.manager.drain()

    def latest_step(self) -> Optional[int]:
        return self.manager.latest_step()

    def names(self):
        return self.manager.names()

    # -- stats + calibration --------------------------------------------------
    def stats(self) -> CRStats:
        """Service-level aggregate (whole save/restore calls, every tier)."""
        return dataclasses.replace(self._stats)

    def tier_stats(self) -> Dict[str, object]:
        """Per-tier breakdown, for the bandwidth benchmarks."""
        return {"mem": self.manager.mem.stats, "disk": self.manager.disk.stats}

    def calibrate(self, tick_seconds: float, *,
                  tiers: Optional[Sequence[str]] = None,
                  compress_ratio: float = 1.0,
                  save_base: int = 0, restore_base: int = 0,
                  delta_ratio: float = 1.0,
                  cap_ticks: int = DEFAULT_CAP_TICKS):
        """Measured traffic -> a scheduler cost model (the unified entry).

        ``tick_seconds`` is the wall length of one scheduler tick (the
        executor's unit); requires at least one measured save.
        ``delta_ratio`` is the measured recurrent-save coefficient
        (`crcost.measured_delta_num` quantizes the bench_cr_cost blend).

        ``tiers=None`` returns a flat `CRCostModel` from the service-level
        aggregate.  ``tiers`` as a sequence of tier names (from
        ``tier_stats()``, fastest first — e.g. ``("mem", "disk")``)
        returns the `TieredCRCostModel` lattice over those tiers: the
        "mem" tier is capacity-bounded at the manager's real
        ``mem_capacity_bytes`` on the whole-MiB grid, the last tier is
        forced UNBOUNDED (the durable spill target).  A tier with no
        measured save traffic inherits the fastest measured tier's model."""
        if tiers is None:
            return CRCostModel.from_stats(
                self.stats(), tick_seconds=tick_seconds,
                compress_ratio=compress_ratio, save_base=save_base,
                restore_base=restore_base, cap_ticks=cap_ticks,
                delta_ratio=delta_ratio)
        ts = self.tier_stats()
        caps = {"mem": self.manager.fast_capacity_mib, "disk": UNBOUNDED}
        return TieredCRCostModel.from_stats(
            [ts[name] for name in tiers], tick_seconds=tick_seconds,
            capacity_mib=[caps.get(name, UNBOUNDED) for name in tiers],
            compress_ratio=compress_ratio, cap_ticks=cap_ticks,
            delta_ratio=delta_ratio)

    def calibrate_tiered(self, tick_seconds: float, *,
                         compress_ratio: float = 1.0,
                         cap_ticks: int = DEFAULT_CAP_TICKS,
                         ) -> TieredCRCostModel:
        """Deprecated shim: use ``calibrate(tiers=("mem", "disk"))``."""
        warnings.warn(
            "CheckpointService.calibrate_tiered is deprecated; use "
            "calibrate(tiers=('mem', 'disk'))", DeprecationWarning,
            stacklevel=2)
        return self.calibrate(tick_seconds, tiers=("mem", "disk"),
                              compress_ratio=compress_ratio,
                              cap_ticks=cap_ticks)

    def close(self) -> None:
        self.manager.close()
