"""Multi-tier checkpoint storage — the paper's NVM/DCPMM adaptation.

The paper reduces C/R thrashing cost with persistent-memory file systems
(SplitFS/NOVA/Assise over Optane DCPMM) and, further, DAX direct access.
The TPU-fleet analogue:

* ``MemTier``  — host-DRAM object store: memory-speed save/restore,
  survives the *job* (the scheduler process holds it) but not the host —
  exactly the role DCPMM plays for recurrent preemption checkpoints.  The
  "DAX" property maps to zero-serialization: arrays are kept as live numpy
  buffers and restored by device_put, no encode/decode pass.
* ``DiskTier`` — durable storage with zstd compression (the distributed-FS
  tier); used for the every-N-steps durable checkpoint and for node-failure
  recovery.

``TieredStore`` implements write-through/promote/evict between them with a
capacity-bounded LRU on the fast tier (DCPMM is small — same constraint).
"""
from __future__ import annotations

import shutil
import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.checkpoint import serialize


@dataclass
class TierStats:
    saves: int = 0
    restores: int = 0
    evictions: int = 0
    bytes_written: int = 0
    bytes_read: int = 0
    save_seconds: float = 0.0
    restore_seconds: float = 0.0


class MemTier:
    """Capacity-bounded in-memory snapshot store (the "NVM" tier)."""

    def __init__(self, capacity_bytes: int = 8 << 30):
        self.capacity = capacity_bytes
        self._store: "OrderedDict[str, Dict[str, np.ndarray]]" = OrderedDict()
        self._sizes: Dict[str, int] = {}
        self.stats = TierStats()

    def save(self, name: str, tree) -> None:
        leaves = {k: np.asarray(jax.device_get(v))
                  for k, v in serialize.leaf_paths(tree)}
        self.save_leaves(name, leaves)

    def save_leaves(self, name: str, leaves: Dict[str, np.ndarray]) -> None:
        t0 = time.perf_counter()
        size = sum(a.nbytes for a in leaves.values())
        if size > self.capacity:
            # An admission could only succeed by evicting EVERY resident
            # snapshot and would still blow the capacity bound; reject with
            # the store untouched (callers write through to the durable
            # tier instead — manager.save / TieredStore.save).
            raise ValueError(
                f"snapshot {name!r} ({size} B) exceeds MemTier capacity "
                f"({self.capacity} B)")
        while self._store and (sum(self._sizes.values()) + size) > self.capacity:
            old, _ = self._store.popitem(last=False)           # LRU eviction
            self._sizes.pop(old)
            self.stats.evictions += 1
        self._store[name] = leaves
        self._sizes[name] = size
        self._store.move_to_end(name)
        self.stats.saves += 1
        self.stats.bytes_written += size
        self.stats.save_seconds += time.perf_counter() - t0

    def restore(self, name: str) -> Dict[str, np.ndarray]:
        t0 = time.perf_counter()
        leaves = self._store[name]
        self._store.move_to_end(name)
        self.stats.restores += 1
        self.stats.bytes_read += self._sizes[name]
        self.stats.restore_seconds += time.perf_counter() - t0
        return leaves

    def __contains__(self, name: str) -> bool:
        return name in self._store

    def delete(self, name: str) -> None:
        self._store.pop(name, None)
        self._sizes.pop(name, None)

    def names(self):
        return list(self._store)


class DiskTier:
    """Durable zstd-compressed checkpoints (the distributed-FS tier)."""

    def __init__(self, root: Path, compress: Optional[int] = 3):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.compress = compress
        self.stats = TierStats()
        # save_leaves runs on the AsyncCheckpointer writer thread while the
        # caller thread saves/restores concurrently; stats is shared.
        self._lock = threading.Lock()

    def _dir(self, name: str) -> Path:
        return self.root / name

    def save(self, name: str, tree) -> None:
        t0 = time.perf_counter()
        manifest = serialize.save_tree(tree, self._dir(name), compress=self.compress)
        with self._lock:
            self.stats.saves += 1
            self.stats.bytes_written += sum(
                m["nbytes_stored"] for m in manifest["leaves"].values())
            self.stats.save_seconds += time.perf_counter() - t0

    def save_leaves(self, name: str, leaves: Dict[str, np.ndarray]) -> None:
        """Persist an already-snapshotted MemTier entry (promotion) —
        path keys are preserved verbatim."""
        t0 = time.perf_counter()
        manifest = serialize.save_leaf_dict(
            leaves, self._dir(name), compress=self.compress)
        with self._lock:
            self.stats.saves += 1
            self.stats.bytes_written += sum(
                m["nbytes_stored"] for m in manifest["leaves"].values())
            self.stats.save_seconds += time.perf_counter() - t0

    def restore(self, name: str) -> Dict[str, np.ndarray]:
        t0 = time.perf_counter()
        leaves = serialize.load_leaves(self._dir(name))
        with self._lock:
            self.stats.restores += 1
            self.stats.bytes_read += sum(a.nbytes for a in leaves.values())
            self.stats.restore_seconds += time.perf_counter() - t0
        return leaves

    def __contains__(self, name: str) -> bool:
        return (self._dir(name) / serialize.MANIFEST).exists()

    def delete(self, name: str) -> None:
        shutil.rmtree(self._dir(name), ignore_errors=True)

    def names(self):
        return sorted(p.parent.name if p.name == serialize.MANIFEST else p.name
                      for p in self.root.glob(f"*/{serialize.MANIFEST}"))


class TieredStore:
    """Write to the fast tier; promote to durable on demand; restore from
    the fastest tier that has the snapshot."""

    def __init__(self, mem: MemTier, disk: DiskTier):
        self.mem = mem
        self.disk = disk

    def save(self, name: str, tree, durable: bool = False) -> None:
        leaves = {k: np.asarray(jax.device_get(v))
                  for k, v in serialize.leaf_paths(tree)}
        try:
            self.mem.save_leaves(name, leaves)
        except ValueError:
            durable = True    # oversized for the fast tier: write through
        if durable:
            self.disk.save_leaves(name, leaves)

    def promote(self, name: str) -> None:
        if name in self.mem and name not in self.disk:
            self.disk.save_leaves(name, self.mem.restore(name))

    def restore_leaves(self, name: str) -> Dict[str, np.ndarray]:
        if name in self.mem:
            return self.mem.restore(name)
        if name in self.disk:
            leaves = self.disk.restore(name)
            return leaves
        raise KeyError(f"snapshot {name} in no tier")

    def __contains__(self, name: str) -> bool:
        return name in self.mem or name in self.disk
