"""Serve launcher: prefill + decode loop for any assigned arch, or — with
``--sched-status`` — a fleet-status HTTP endpoint exposing scheduler
telemetry (Prometheus ``/metrics``, Perfetto ``/trace.json``, ``/healthz``)
for a simulated schedule (the ROADMAP's fleet-status service substrate).

  PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b --smoke \
      --batch 4 --prompt-len 16 --gen 24
  PYTHONPATH=src python -m repro.launch.serve --sched-status --port 9090 \
      --policy omfs --tenants 4 --chips 64 --horizon 300
"""
import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models.model import build_model


def sched_status_payloads(args):
    """Run the configured simulation once and materialize every endpoint's
    response body: ``{path: (content_type, bytes)}``.  Split out from the
    HTTP plumbing so tests can hit the payloads without a socket — and the
    server can serve heavy read traffic from memory without re-simulating
    per scrape."""
    from repro.core import engine
    from repro.core.metrics import event_summary
    from repro.core.types import SchedulerConfig
    from repro.core.workload import WorkloadSpec, make_jobs, make_users
    from repro.obs import registry_from_result, trace_from_result

    spec = WorkloadSpec(n_users=args.tenants, horizon=args.horizon,
                        cpu_total=args.chips, seed=args.seed,
                        arrival_rate=args.arrival_rate)
    users = make_users(spec)
    jobs = make_jobs(spec, users)
    cfg = SchedulerConfig(cpu_total=args.chips, quantum=args.quantum,
                          cr_overhead=2)
    res = engine.simulate(users, jobs, cfg, args.horizon, policy=args.policy,
                          backend=args.backend, record_events=True)
    reg = registry_from_result(res, users=users)
    trace = trace_from_result(res, users=users)
    health = {"status": "ok", "policy": args.policy, "backend": args.backend,
              "horizon": args.horizon, "events": len(res.events),
              "events_dropped": res.events_dropped_total(),
              "summary": event_summary(res.events)}
    return {
        "/metrics": ("text/plain; version=0.0.4",
                     reg.to_prometheus().encode()),
        "/trace.json": ("application/json", json.dumps(trace).encode()),
        "/healthz": ("application/json", json.dumps(health).encode()),
    }


def serve_sched_status(args):
    """Serve the scheduler-status payloads over stdlib HTTP."""
    from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

    payloads = sched_status_payloads(args)

    class Handler(BaseHTTPRequestHandler):
        def do_GET(self):
            hit = payloads.get(self.path.split("?", 1)[0])
            if hit is None:
                self.send_error(404, explain=f"known: {sorted(payloads)}")
                return
            ctype, body = hit
            self.send_response(200)
            self.send_header("Content-Type", ctype)
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, fmt, *a):   # quiet scrape spam
            pass

    server = ThreadingHTTPServer((args.host, args.port), Handler)
    host, port = server.server_address[:2]
    print(f"sched-status on http://{host}:{port}  "
          f"endpoints: {' '.join(sorted(payloads))}")
    try:
        if args.max_requests > 0:
            for _ in range(args.max_requests):
                server.handle_request()
        else:
            server.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        server.server_close()
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    # -- scheduler fleet-status mode (repro.obs telemetry over HTTP) -------
    ap.add_argument("--sched-status", action="store_true",
                    help="serve scheduler telemetry for a simulated fleet "
                         "instead of running a model")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=9090)
    ap.add_argument("--policy", default="omfs")
    ap.add_argument("--backend", default="jax", choices=["python", "jax"])
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--chips", type=int, default=64)
    ap.add_argument("--horizon", type=int, default=300)
    ap.add_argument("--quantum", type=int, default=10)
    ap.add_argument("--arrival-rate", type=float, default=0.08)
    ap.add_argument("--max-requests", type=int, default=0,
                    help="serve N requests then exit (0 = forever); "
                         "lets smoke tests and CI probes terminate")
    args = ap.parse_args(argv)

    if args.sched_status:
        return serve_sched_status(args)
    if args.arch is None:
        ap.error("--arch is required unless --sched-status is given")

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg, q_chunk=64, kv_chunk=64)
    params = model.init(jax.random.PRNGKey(args.seed))
    key = jax.random.PRNGKey(args.seed + 1)
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab)}
    if cfg.family == "vlm":
        batch["frontend"] = jnp.zeros(
            (args.batch, cfg.vision.n_patches, cfg.vision.vision_dim), jnp.bfloat16)
    if cfg.family == "audio":
        batch["frontend"] = jnp.zeros(
            (args.batch, cfg.audio.n_audio_ctx, cfg.d_model), jnp.bfloat16)

    cache = model.init_cache(args.batch, args.prompt_len + args.gen)
    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)

    t0 = time.perf_counter()
    cache, logits = prefill(params, batch, cache)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    out = [tok]
    t0 = time.perf_counter()
    for _ in range(args.gen - 1):
        cache, logits = decode(params, cache, tok)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    gen = jnp.concatenate(out, axis=1)
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} gen={args.gen}")
    print(f"prefill: {t_prefill*1e3:.1f} ms "
          f"({args.batch*args.prompt_len/t_prefill:.0f} tok/s)")
    print(f"decode : {t_decode*1e3:.1f} ms "
          f"({args.batch*(args.gen-1)/max(t_decode,1e-9):.0f} tok/s)")
    print("sample generation row 0:", gen[0].tolist())


if __name__ == "__main__":
    main()
