"""Serve launcher: prefill + decode loop for any assigned arch.

  PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b --smoke \
      --batch 4 --prompt-len 16 --gen 24
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models.model import build_model


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg, q_chunk=64, kv_chunk=64)
    params = model.init(jax.random.PRNGKey(args.seed))
    key = jax.random.PRNGKey(args.seed + 1)
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab)}
    if cfg.family == "vlm":
        batch["frontend"] = jnp.zeros(
            (args.batch, cfg.vision.n_patches, cfg.vision.vision_dim), jnp.bfloat16)
    if cfg.family == "audio":
        batch["frontend"] = jnp.zeros(
            (args.batch, cfg.audio.n_audio_ctx, cfg.d_model), jnp.bfloat16)

    cache = model.init_cache(args.batch, args.prompt_len + args.gen)
    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)

    t0 = time.perf_counter()
    cache, logits = prefill(params, batch, cache)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    out = [tok]
    t0 = time.perf_counter()
    for _ in range(args.gen - 1):
        cache, logits = decode(params, cache, tok)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    gen = jnp.concatenate(out, axis=1)
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} gen={args.gen}")
    print(f"prefill: {t_prefill*1e3:.1f} ms "
          f"({args.batch*args.prompt_len/t_prefill:.0f} tok/s)")
    print(f"decode : {t_decode*1e3:.1f} ms "
          f"({args.batch*(args.gen-1)/max(t_decode,1e-9):.0f} tok/s)")
    print("sample generation row 0:", gen[0].tolist())


if __name__ == "__main__":
    main()
