"""Cluster-simulation launcher: OMFS (or a baseline) on a synthetic fleet.

  PYTHONPATH=src python -m repro.launch.cluster_sim --policy omfs \
      --chips 1024 --tenants 6 --horizon 800 --jax
"""
import argparse

import numpy as np

from repro.core import omfs_jax
from repro.core.baselines import ALL_BASELINES
from repro.core.metrics import compute_metrics
from repro.core.simulator import simulate
from repro.core.types import SchedulerConfig
from repro.core.workload import WorkloadSpec, make_jobs, make_users


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="omfs",
                    choices=["omfs"] + list(ALL_BASELINES))
    ap.add_argument("--chips", type=int, default=1024)
    ap.add_argument("--tenants", type=int, default=6)
    ap.add_argument("--horizon", type=int, default=800)
    ap.add_argument("--quantum", type=int, default=20)
    ap.add_argument("--cr-overhead", type=int, default=2)
    ap.add_argument("--arrival-rate", type=float, default=0.08)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--jax", action="store_true",
                    help="vectorized lax simulator (omfs only)")
    args = ap.parse_args(argv)

    spec = WorkloadSpec(n_users=args.tenants, horizon=args.horizon,
                        cpu_total=args.chips, seed=args.seed,
                        arrival_rate=args.arrival_rate)
    users = make_users(spec)
    jobs = make_jobs(spec, users)
    cfg = SchedulerConfig(cpu_total=args.chips, quantum=args.quantum,
                          cr_overhead=args.cr_overhead)
    print(f"{len(jobs)} jobs, {args.tenants} tenants, {args.chips} chips, "
          f"policy={args.policy}")

    if args.jax:
        assert args.policy == "omfs", "JAX path implements OMFS"
        tbl, busy = omfs_jax.simulate_jax(users, jobs, cfg, args.horizon,
                                          pass_depth=64)
        busy = np.asarray(busy)
        t = np.asarray(tbl.state)
        print(f"utilization {busy.mean()/args.chips:.3f} | done "
              f"{(t==omfs_jax.DONE).sum()} | killed {(t==omfs_jax.KILLED).sum()} "
              f"| checkpoints {int(np.asarray(tbl.n_ckpt).sum())}")
        return

    policy = ALL_BASELINES.get(args.policy)
    if policy is None:
        res = simulate(users, jobs, cfg, args.horizon)
    else:
        res = simulate(users, jobs, cfg, args.horizon, policy=policy)
    m = compute_metrics(res)
    print(f"utilization {m.utilization:.3f} | jain {m.jain_fairness:.3f} | "
          f"wait {m.mean_wait:.1f} | preemptions {m.preemptions} | "
          f"checkpoints {m.checkpoints} | killed {m.killed_jobs}")


if __name__ == "__main__":
    main()
