"""Cluster-simulation launcher: any registered policy on a synthetic fleet,
on either engine backend.

  PYTHONPATH=src python -m repro.launch.cluster_sim --policy omfs \
      --chips 1024 --tenants 6 --horizon 800 --backend jax
"""
import argparse

from repro.core import engine
from repro.core.crcost import UNBOUNDED, CRCostModel, TieredCRCostModel
from repro.core.metrics import compute_metrics
from repro.core.types import SchedulerConfig
from repro.core.workload import WorkloadSpec, make_jobs, make_users


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="omfs", choices=sorted(engine.POLICIES))
    ap.add_argument("--backend", default="python", choices=["python", "jax"])
    ap.add_argument("--jax", action="store_true",
                    help="shorthand for --backend jax")
    ap.add_argument("--chips", type=int, default=1024)
    ap.add_argument("--tenants", type=int, default=6)
    ap.add_argument("--horizon", type=int, default=800)
    ap.add_argument("--quantum", type=int, default=20)
    ap.add_argument("--cr-overhead", type=int, default=2)
    ap.add_argument("--save-mib-per-tick", type=int, default=0,
                    help="size-aware C/R: tier write bandwidth (0 = free)")
    ap.add_argument("--restore-mib-per-tick", type=int, default=0,
                    help="size-aware C/R: tier read bandwidth (0 = free)")
    ap.add_argument("--fast-tier-cap-mib", type=int, default=None,
                    help="enable tiered eviction placement: fast-tier "
                         "capacity in MiB (-1 = unbounded); the "
                         "--*-mib-per-tick bandwidths price the fast tier")
    ap.add_argument("--spill-save-mib-per-tick", type=int, default=2048,
                    help="durable spill tier write bandwidth")
    ap.add_argument("--spill-restore-mib-per-tick", type=int, default=4096,
                    help="durable spill tier read bandwidth")
    ap.add_argument("--pass-depth", type=int, default=64,
                    help="per-tick queue sweep bound on the jax backend")
    ap.add_argument("--arrival-rate", type=float, default=0.08)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--events", action="store_true",
                    help="record the typed lifecycle event log (repro.obs) "
                         "and print its reconciliation summary")
    ap.add_argument("--trace-out", metavar="PATH", default=None,
                    help="write a Perfetto/Chrome trace of the schedule "
                         "(implies --events)")
    ap.add_argument("--metrics-out", metavar="PATH", default=None,
                    help="write the metrics-registry JSON snapshot "
                         "(implies --events)")
    args = ap.parse_args(argv)
    backend = "jax" if args.jax else args.backend
    record = args.events or args.trace_out or args.metrics_out

    spec = WorkloadSpec(n_users=args.tenants, horizon=args.horizon,
                        cpu_total=args.chips, seed=args.seed,
                        arrival_rate=args.arrival_rate)
    users = make_users(spec)
    jobs = make_jobs(spec, users)
    fast = CRCostModel(save_mib_per_tick=args.save_mib_per_tick,
                       restore_mib_per_tick=args.restore_mib_per_tick)
    tiers = None
    if args.fast_tier_cap_mib is not None:
        tiers = TieredCRCostModel(
            tiers=(fast, CRCostModel(
                save_mib_per_tick=args.spill_save_mib_per_tick,
                restore_mib_per_tick=args.spill_restore_mib_per_tick)),
            capacity_mib=(args.fast_tier_cap_mib, UNBOUNDED))
    cfg = SchedulerConfig(
        cpu_total=args.chips, quantum=args.quantum,
        cr_overhead=args.cr_overhead, cr_cost=fast, cr_tiers=tiers)
    print(f"{len(jobs)} jobs, {args.tenants} tenants, {args.chips} chips, "
          f"policy={args.policy}, backend={backend}")

    res = engine.simulate(
        users, jobs, cfg, args.horizon, policy=args.policy, backend=backend,
        pass_depth=args.pass_depth if backend == "jax" else None,
        record_events=bool(record))

    if record:
        from repro.core.metrics import event_summary
        from repro.obs import registry_from_result, trace_from_result
        ev = event_summary(res.events)
        print(f"events: {len(res.events)} recorded, "
              f"{res.events_dropped_total()} dropped | starts "
              f"{ev['jobs_started']} | restores {ev['restores']} | evicts "
              f"{ev['preemptions']} | saves {ev['checkpoints']} | spills "
              f"{ev['spilled_checkpoints']} | done {ev['jobs_done']}")
        if args.metrics_out:
            import json
            reg = registry_from_result(res, users=users)
            with open(args.metrics_out, "w") as fh:
                json.dump(reg.to_json(), fh, indent=2)
            print(f"metrics snapshot -> {args.metrics_out}")
        if args.trace_out:
            import json
            trace = trace_from_result(res, users=users)
            with open(args.trace_out, "w") as fh:
                json.dump(trace, fh)
            print(f"perfetto trace -> {args.trace_out} "
                  f"(open in ui.perfetto.dev or chrome://tracing)")

    if backend == "jax":
        s = res.summary()
        print(f"utilization {s['utilization']:.3f} | goodput "
              f"{s['goodput']:.3f} | wasted {s['wasted_frac']:.3f} | wait "
              f"{s['mean_wait']:.1f} | preemptions {s['preemptions']} | "
              f"checkpoints {s['checkpoints']} | killed {s['killed']} | "
              f"done {s['done']}")
        return

    m = compute_metrics(res.sim)
    print(f"utilization {m.utilization:.3f} | goodput {m.goodput:.3f} | "
          f"wasted {m.wasted_work_frac:.3f} | jain {m.jain_fairness:.3f} | "
          f"wait {m.mean_wait:.1f} | preemptions {m.preemptions} | "
          f"checkpoints {m.checkpoints} | killed {m.killed_jobs}")


if __name__ == "__main__":
    main()
