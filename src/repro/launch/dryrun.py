import os
os.environ["XLA_FLAGS"] = (
    os.environ.get("DRYRUN_EXTRA_XLA_FLAGS", "") +
    " --xla_force_host_platform_device_count=512"
)
"""Multi-pod dry-run driver (deliverable e).

For every (architecture x input-shape x mesh) cell: build the full-size
config, lower the appropriate step function with production shardings,
``.compile()`` it, and record memory analysis, cost analysis, and the
roofline terms.  ShapeDtypeStruct stand-ins only — nothing is allocated at
full size.

NOTE: the XLA_FLAGS line above MUST run before any other import — jax locks
the device count at first init.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both
  PYTHONPATH=src python -m repro.launch.dryrun --report   # print the table
"""
# NOTE: no `from __future__ import annotations` here — the XLA_FLAGS lines
# above must be the first statements in the file.
import argparse
import dataclasses
import json
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, SHAPES_BY_NAME, cell_is_applicable, get_config
from repro.distributed import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.models.model import build_model, model_flops_per_step
from repro.roofline import analysis as roofline
from repro.train.state import train_state_shapes
from repro.train.steps import TrainConfig, make_decode_step, make_prefill_step, make_train_step

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"

# per-shape attention chunk sizes + grad accumulation (activation-memory knobs)
SHAPE_TUNING = {
    "train_4k": dict(q_chunk=2048, kv_chunk=2048, grad_accum=4),
    "prefill_32k": dict(q_chunk=2048, kv_chunk=2048, grad_accum=1),
    "decode_32k": dict(q_chunk=1024, kv_chunk=1024, grad_accum=1),
    "long_500k": dict(q_chunk=1024, kv_chunk=1024, grad_accum=1),
}


def _layer_unit(cfg):
    """Smallest depth step preserving the arch's layer-group structure."""
    if cfg.family == "vlm":
        return cfg.vision.cross_attn_every
    if cfg.family == "ssm":
        return cfg.xlstm.slstm_every
    return 1


def build_cell(arch: str, shape_name: str, mesh, tuning_override=None,
               costing: bool = False, depth_override=None):
    """Returns (lowered, n_devices, model_flops, accum) for one cell.

    Two build modes:
    * production (``costing=False``): scans + remat + grad accumulation —
      the deployable artifact; its ``memory_analysis()`` is authoritative.
    * costing (``costing=True``): layer scans unrolled, single-trip
      attention chunking, accum=1 with a microbatch-sized global batch —
      XLA cost_analysis counts while-loop bodies ONCE, so only this build
      yields correct FLOPs/bytes/collective totals.  ``depth_override``
      reduces n_layers: run_cell lowers TWO shallow variants and
      extrapolates cost(L) = base + L * per_layer to the true depth
      (all per-layer costs are depth-independent), keeping the unrolled
      compile tractable for 62-layer archs.
    """
    cfg = get_config(arch)
    if depth_override is not None:
        cfg = cfg.replace(n_layers=depth_override)
    shape = SHAPES_BY_NAME[shape_name]
    tune = dict(SHAPE_TUNING[shape_name])
    if tuning_override:
        extra = dict(tuning_override)
        cfg_over = extra.pop("cfg", {})
        if cfg_over:
            cfg = cfg.replace(**cfg_over)
        tune.update(extra)
    accum = tune["grad_accum"] if shape.kind == "train" else 1
    if costing:
        seq = shape.seq_len
        model = build_model(cfg, q_chunk=seq, kv_chunk=seq, unroll=True)
        if accum > 1:
            shape = dataclasses.replace(shape, global_batch=shape.global_batch // accum)
    else:
        model = build_model(cfg, q_chunk=tune["q_chunk"], kv_chunk=tune["kv_chunk"])
    batch_shapes = model.input_specs(shape)
    batch_sh = shd.batch_shardings(cfg, batch_shapes, mesh)

    if shape.kind == "train":
        state_shapes = train_state_shapes(model)
        p_sh = shd.param_shardings(cfg, state_shapes.params, mesh)
        state_sh = state_shapes._replace(
            params=p_sh,
            opt=state_shapes.opt._replace(
                step=shd.replicated(mesh, state_shapes.opt.step),
                m=shd.param_shardings(cfg, state_shapes.opt.m, mesh),
                v=shd.param_shardings(cfg, state_shapes.opt.v, mesh),
            ),
            rng=shd.replicated(mesh, state_shapes.rng),
            data_cursor=shd.replicated(mesh, state_shapes.data_cursor),
        )
        from jax.sharding import NamedSharding, PartitionSpec as P
        repl = NamedSharding(mesh, P())
        step = make_train_step(
            model, TrainConfig(grad_accum=1 if costing else accum))
        with jax.set_mesh(mesh):
            lowered = jax.jit(
                step,
                in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, repl),
                donate_argnums=(0,),
            ).lower(state_shapes, batch_shapes)
    else:
        params_shapes = model.param_shapes()
        p_sh = shd.param_shardings(cfg, params_shapes, mesh)
        cache_shapes = model.cache_shapes(shape.global_batch, shape.seq_len)
        cache_sh = shd.cache_shardings(cfg, cache_shapes, mesh)
        if shape.kind == "prefill":
            step = make_prefill_step(model)
            with jax.set_mesh(mesh):
                lowered = jax.jit(
                    step,
                    in_shardings=(p_sh, batch_sh, cache_sh),
                    out_shardings=(cache_sh, None),
                    donate_argnums=(2,),
                ).lower(params_shapes, batch_shapes, cache_shapes)
        else:
            step = make_decode_step(model)
            tok_sh = shd.batch_shardings(cfg, {"tokens": batch_shapes["tokens"]}, mesh)["tokens"]
            with jax.set_mesh(mesh):
                lowered = jax.jit(
                    step,
                    in_shardings=(p_sh, cache_sh, tok_sh),
                    out_shardings=(cache_sh, None),
                    donate_argnums=(1,),
                ).lower(params_shapes, cache_shapes, batch_shapes["tokens"])

    mflops = model_flops_per_step(
        cfg, SHAPES_BY_NAME[shape_name], backward=(shape.kind == "train"))
    return lowered, mesh.size, mflops, accum


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
             tuning_override=None, tag: str = "", costing: bool = True) -> dict:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    cell_id = f"{arch}__{shape_name}__{mesh_name}" + (f"__{tag}" if tag else "")
    out_path = out_dir / f"{cell_id}.json"
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    ok, why = cell_is_applicable(cfg, shape)
    record = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "tag": tag,
        "status": "skipped", "reason": why,
    }
    if not ok:
        out_path.write_text(json.dumps(record, indent=2))
        print(f"SKIP {cell_id}: {why}")
        return record

    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        # 1. production compile: the deployable artifact; memory analysis
        lowered, n_dev, mflops, accum = build_cell(
            arch, shape_name, mesh, tuning_override)
        compiled = lowered.compile()
        t_prod = time.time() - t0
        mem = roofline.memory_stats(compiled)
        del lowered, compiled
        if not costing:
            # multi-pod cells: compile success + memory is the deliverable;
            # the roofline table is single-pod only (assignment SSRoofline)
            record.update({
                "status": "ok", "n_devices": n_dev, "grad_accum": accum,
                "compile_s": round(t_prod, 1), "memory": mem,
            })
            print(f"OK   {cell_id}: compile={t_prod:.0f}s "
                  f"mem/dev={mem['peak_estimate_bytes']/2**30:.2f}GiB (no costing)")
            out_path.write_text(json.dumps(record, indent=2))
            return record
        # 2. costing compiles: unrolled shallow variants at depths (a, b),
        #    extrapolated linearly to the true depth L (per-layer costs are
        #    depth-independent; base = embed/CE/optimizer-scalars).
        t1 = time.time()
        cfg_full = get_config(arch)
        unit = _layer_unit(cfg_full)
        l_full = cfg_full.n_layers
        a = min(2 * unit, l_full)
        b = min(4 * unit, l_full)
        if b <= a:  # very shallow arch: single exact costing compile
            lowered_c, _, _, _ = build_cell(
                arch, shape_name, mesh, tuning_override, costing=True)
            compiled_c = lowered_c.compile()
            rf = roofline.analyze(
                compiled_c, compiled_c.as_text(), n_devices=n_dev,
                model_flops=mflops, cost_scale=float(accum))
            extrapolated = False
        else:
            costs = {}
            for depth in (a, b):
                lowered_c, _, _, _ = build_cell(
                    arch, shape_name, mesh, tuning_override, costing=True,
                    depth_override=depth)
                compiled_c = lowered_c.compile()
                costs[depth] = roofline.raw_costs(compiled_c)
                del lowered_c, compiled_c
            rf = roofline.analyze_extrapolated(
                costs[a], costs[b], a, b, l_full,
                n_devices=n_dev, model_flops=mflops, cost_scale=float(accum))
            extrapolated = True
        t_cost = time.time() - t1
        record.update({
            "status": "ok",
            "n_devices": n_dev,
            "grad_accum": accum,
            "costing_extrapolated": extrapolated,
            "compile_s": round(t_prod, 1),
            "costing_compile_s": round(t_cost, 1),
            "memory": mem,
            "roofline": rf.row(),
            "coll_breakdown": rf.coll_breakdown,
        })
        print(f"OK   {cell_id}: compile={t_prod:.0f}s+{t_cost:.0f}s "
              f"mem/dev={mem['peak_estimate_bytes']/2**30:.2f}GiB "
              f"terms(c/m/coll)={rf.compute_s*1e3:.1f}/{rf.memory_s*1e3:.1f}/"
              f"{rf.collective_s*1e3:.1f}ms bottleneck={rf.bottleneck} "
              f"MF%={(rf.model_flops_ratio or 0)*100:.0f}")
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        record.update({"status": "failed", "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-4000:]})
        print(f"FAIL {cell_id}: {type(e).__name__}: {str(e)[:200]}")
    out_path.write_text(json.dumps(record, indent=2))
    return record


def report(out_dir: Path) -> None:
    rows = []
    for p in sorted(out_dir.glob("*.json")):
        rows.append(json.loads(p.read_text()))
    fmt = "{:<22s} {:<12s} {:<8s} {:<8s} {:>9s} {:>8s} {:>8s} {:>8s} {:<10s} {:>6s}"
    print(fmt.format("arch", "shape", "mesh", "status", "mem GiB",
                     "comp ms", "mem ms", "coll ms", "bottleneck", "MF%"))
    for r in rows:
        if r["status"] != "ok":
            print(fmt.format(r["arch"], r["shape"], r["mesh"], r["status"],
                             "-", "-", "-", "-", r.get("reason", r.get("error", ""))[:30], "-"))
            continue
        if "roofline" not in r:
            print(fmt.format(r["arch"], r["shape"], r["mesh"], r["status"],
                             f"{r['memory']['peak_estimate_bytes']/2**30:.2f}",
                             "-", "-", "-", "compile-only", "-"))
            continue
        rf = r["roofline"]
        print(fmt.format(
            r["arch"], r["shape"], r["mesh"], r["status"],
            f"{r['memory']['peak_estimate_bytes']/2**30:.2f}",
            f"{rf['compute_s']*1e3:.1f}", f"{rf['memory_s']*1e3:.1f}",
            f"{rf['collective_s']*1e3:.1f}", rf["bottleneck"],
            f"{(rf['model_flops_ratio'] or 0)*100:.0f}",
        ))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES_BY_NAME))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=["off", "on", "both"], default="off")
    ap.add_argument("--out", type=Path, default=RESULTS_DIR)
    ap.add_argument("--report", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--no-costing", action="store_true",
                    help="production compile only (multi-pod sweeps)")
    args = ap.parse_args(argv)

    args.out.mkdir(parents=True, exist_ok=True)
    if args.report:
        report(args.out)
        return

    meshes = {"off": [False], "on": [True], "both": [False, True]}[args.multi_pod]
    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for shape in SHAPES_BY_NAME:
                for mp in meshes:
                    cells.append((arch, shape, mp))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        cells = [(args.arch, args.shape, mp) for mp in meshes]

    for arch, shape, mp in cells:
        mesh_name = "2x16x16" if mp else "16x16"
        if args.skip_existing:
            p = args.out / f"{arch}__{shape}__{mesh_name}.json"
            if p.exists() and json.loads(p.read_text()).get("status") in ("ok", "skipped"):
                continue
        run_cell(arch, shape, mp, args.out,
                 costing=not (args.no_costing or mp))


if __name__ == "__main__":
    main()
