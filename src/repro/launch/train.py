"""Production train launcher: any assigned arch, any mesh, full C/R.

On the CPU container this runs reduced configs end-to-end; on a TPU fleet
the same script runs the full configs (the mesh/sharding/dry-run machinery
is identical — that is the point of the dry-run deliverable).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b --smoke \
      --steps 50 --ckpt-dir /tmp/ck
  PYTHONPATH=src python -m repro.launch.train --arch glm4-9b --smoke \
      --steps 20 --resume --ckpt-dir /tmp/ck     # transparent restart
"""
import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager, ManagerConfig
from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.data.pipeline import DataConfig, SyntheticLM, shard_batch
from repro.models.model import build_model
from repro.optim.compression import compress_tree, init_ef
from repro.train.state import init_train_state, train_state_shapes
from repro.train.steps import TrainConfig, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", type=Path, default=Path("/tmp/repro_ckpt"))
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg, q_chunk=min(64, args.seq), kv_chunk=min(64, args.seq))
    tcfg = TrainConfig(lr=args.lr, warmup_steps=10, total_steps=10_000,
                       grad_accum=args.grad_accum)
    step_fn = jax.jit(make_train_step(model, tcfg), donate_argnums=(0,))
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                  global_batch=args.batch, seed=args.seed))
    mgr = CheckpointManager(ManagerConfig(root=args.ckpt_dir / args.arch,
                                          durable_every=2))

    if args.resume and mgr.latest_step() is not None:
        state, name = mgr.restore(train_state_shapes(model, args.seed))
        print(f"resumed from {name} (step {int(state.step)})")
    else:
        state = init_train_state(model.init(jax.random.PRNGKey(args.seed)),
                                 args.seed)
        print("cold start")

    t0 = time.time()
    start_step = int(state.step)
    for i in range(args.steps):
        batch = shard_batch(data.batch_at(int(state.data_cursor)))
        # vlm/audio frontends are stubs: supply zero embeddings
        if cfg.family == "vlm":
            batch["frontend"] = jnp.zeros(
                (args.batch, cfg.vision.n_patches, cfg.vision.vision_dim),
                jnp.bfloat16)
        if cfg.family == "audio":
            batch["frontend"] = jnp.zeros(
                (args.batch, cfg.audio.n_audio_ctx, cfg.d_model), jnp.bfloat16)
        state, metrics = step_fn(state, batch)
        if i % 5 == 0 or i == args.steps - 1:
            print(f"step {int(metrics['step']):5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e}")
        if (i + 1) % args.ckpt_every == 0:
            name = mgr.save(int(state.step), state)
            print(f"checkpointed {name}")
    mgr.save(int(state.step), state, durable=True)
    dt = time.time() - t0
    tokens = (int(state.step) - start_step) * args.seq * args.batch
    print(f"done: {tokens} tokens in {dt:.1f}s ({tokens/dt:.0f} tok/s)")
    mgr.close()


if __name__ == "__main__":
    main()
