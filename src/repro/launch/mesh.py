"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run sets
XLA_FLAGS for 512 host devices before first jax init; tests and benches see
the real single device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = 256 chips (data, model).
    Multi-pod: (2, 16, 16) = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_local_mesh(model_parallel: int = 1):
    """Debug mesh over whatever devices exist (tests run with 1-8 CPUs)."""
    n = jax.device_count()
    assert n % model_parallel == 0
    return jax.make_mesh(
        (n // model_parallel, model_parallel), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )


# TPU v5e hardware constants used by the roofline analysis (per chip).
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # bytes/s
ICI_BW_PER_LINK = 50e9            # bytes/s per link (~100GB/s bidi / 2)
ICI_LINKS_2D = 4                  # 2D torus: 4 links per chip (x+,x-,y+,y-)
