"""GLM-4 9B: dense, RoPE, extreme GQA (kv=2). [hf:THUDM/glm-4-9b; hf]."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="glm4-9b", family="dense",
        n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2,
        d_ff=13696, vocab=151552, rope_theta=10000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="glm4-9b-smoke", family="dense",
        n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
        d_ff=64, vocab=128,
    )
