"""Configuration system: model configs, input shapes, and the arch registry.

Every assigned architecture is a ``ModelConfig`` produced by a module in
``repro.configs``.  Configs are plain frozen dataclasses — hashable, usable
as jit static args, and printable into EXPERIMENTS.md tables.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Sub-configs for architecture families
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    """Fine-grained mixture-of-experts FFN (DeepSeek-MoE / DBRX style)."""

    n_routed: int                 # routed experts
    top_k: int                    # experts per token
    d_expert: int                 # hidden dim of each routed expert
    n_shared: int = 0             # always-on shared experts (DeepSeek-MoE)
    d_shared: int = 0             # hidden dim of the shared expert(s)
    router_aux_coef: float = 0.01  # load-balance aux loss coefficient
    router_dtype: str = "float32"


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3)."""

    q_lora_rank: int              # low-rank bottleneck for Q
    kv_lora_rank: int             # compressed latent dim cached at decode
    qk_nope_head_dim: int         # non-rotary part of the QK head
    qk_rope_head_dim: int         # rotary part of the QK head (shared K)
    v_head_dim: int


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-style selective SSM branch (Hymba hybrid blocks)."""

    d_state: int = 16
    d_conv: int = 4               # causal depthwise conv width
    expand: int = 2               # d_inner = expand * d_model
    dt_rank: int = 0              # 0 -> ceil(d_model / 16)


@dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM block stack: alternating mLSTM (matrix memory) / sLSTM blocks."""

    slstm_every: int = 2          # every k-th block is an sLSTM block
    proj_factor_mlstm: float = 2.0
    proj_factor_slstm: float = 1.333
    conv_width: int = 4


@dataclass(frozen=True)
class VisionConfig:
    """Cross-attention VLM (Llama-3.2-Vision).  Frontend is a STUB: the
    input pipeline supplies pre-computed patch embeddings."""

    cross_attn_every: int = 5     # every 5th layer is a cross-attn layer
    n_patches: int = 6404         # 4 tiles x 1601 patches
    vision_dim: int = 1280        # ViT-H/14 output width (pre-projector)


@dataclass(frozen=True)
class AudioConfig:
    """Encoder-decoder audio model (Whisper).  Conv/mel frontend is a STUB:
    the input pipeline supplies pre-computed frame embeddings."""

    n_encoder_layers: int = 6
    n_audio_ctx: int = 1500       # encoder positions (30s @ 50Hz)


# ---------------------------------------------------------------------------
# The unified model config
# ---------------------------------------------------------------------------

FAMILIES = ("dense", "moe", "vlm", "hybrid", "ssm", "audio")


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # one of FAMILIES
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # sliding-window attention; 0 = full/global attention
    sliding_window: int = 0
    # always-visible learnable prefix (Hymba meta tokens); 0 = none
    n_meta_tokens: int = 0
    # decode hillclimb: shard the KV cache on the SEQUENCE dim over the
    # model axis and flash-decode with psum-combined softmax stats
    # (distributed.collectives.sharded_kv_decode_attention)
    decode_kv_shard: bool = False
    # family-specific sub-configs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    vision: Optional[VisionConfig] = None
    audio: Optional[AudioConfig] = None
    # dtypes
    param_dtype: str = "float32"  # master weights
    compute_dtype: str = "bfloat16"

    def __post_init__(self):
        assert self.family in FAMILIES, self.family

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def q_group_size(self) -> int:
        """GQA group size (query heads per KV head)."""
        return max(self.n_heads // max(self.n_kv_heads, 1), 1)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # -- parameter counting (for MODEL_FLOPS = 6*N*D roofline term) --------
    def param_counts(self) -> dict:
        """Analytic parameter counts: total and per-token-active."""
        from repro.models.model import count_params  # lazy, avoids cycle

        return count_params(self)


# ---------------------------------------------------------------------------
# Input shapes (assigned shape suite)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # "train" | "prefill" | "decode"

    @property
    def tokens_per_step(self) -> int:
        if self.kind == "decode":
            return self.global_batch  # one new token per sequence
        return self.seq_len * self.global_batch


SHAPES: Tuple[ShapeSpec, ...] = (
    ShapeSpec("train_4k", seq_len=4_096, global_batch=256, kind="train"),
    ShapeSpec("prefill_32k", seq_len=32_768, global_batch=32, kind="prefill"),
    ShapeSpec("decode_32k", seq_len=32_768, global_batch=128, kind="decode"),
    ShapeSpec("long_500k", seq_len=524_288, global_batch=1, kind="decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def cell_is_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Whether an (arch x shape) cell is runnable per the assignment rules.

    long_500k needs sub-quadratic sequence mixing: run only for ssm/hybrid
    archs; pure full-attention archs skip it (recorded in DESIGN.md).
    """
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return False, "full-attention arch: 500k context needs sub-quadratic mixing"
    return True, ""
