"""Whisper-base backbone: 6-layer encoder + 6-layer decoder, enc-dec
cross-attention.  Conv/mel frontend is a STUB: input_specs supplies frame
embeddings [B, 1500, 512]. [arXiv:2212.04356; unverified]."""
from repro.configs.base import AudioConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-base", family="audio",
        n_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
        d_ff=2048, vocab=51865,
        audio=AudioConfig(n_encoder_layers=6, n_audio_ctx=1500),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-base-smoke", family="audio",
        n_layers=2, d_model=32, n_heads=4, n_kv_heads=4,
        d_ff=64, vocab=128,
        audio=AudioConfig(n_encoder_layers=2, n_audio_ctx=12),
    )
