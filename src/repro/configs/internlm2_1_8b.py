"""InternLM2 1.8B: dense GQA. [arXiv:2403.17297; hf]."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internlm2-1.8b", family="dense",
        n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8,
        d_ff=8192, vocab=92544, rope_theta=1000000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="internlm2-1.8b-smoke", family="dense",
        n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
        d_ff=64, vocab=128,
    )
