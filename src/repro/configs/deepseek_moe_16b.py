"""DeepSeek-MoE 16B: fine-grained MoE, 2 shared + 64 routed top-6.
[arXiv:2401.06066; hf].  d_ff=1408 is the per-(routed-)expert hidden dim."""
from repro.configs.base import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b", family="moe",
        n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1408, vocab=102400, rope_theta=10000.0,
        moe=MoEConfig(n_routed=64, top_k=6, d_expert=1408,
                      n_shared=2, d_shared=2 * 1408),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b-smoke", family="moe",
        n_layers=2, d_model=32, n_heads=4, n_kv_heads=4,
        d_ff=16, vocab=128,
        moe=MoEConfig(n_routed=8, top_k=2, d_expert=16, n_shared=2, d_shared=32),
    )
