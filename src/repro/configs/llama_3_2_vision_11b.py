"""Llama-3.2-Vision 11B backbone: 32 self + 8 gated cross-attn layers (40L).
Vision frontend is a STUB: input_specs supplies ViT patch embeddings.
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]."""
from repro.configs.base import ModelConfig, VisionConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-11b", family="vlm",
        n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab=128256, rope_theta=500000.0,
        vision=VisionConfig(cross_attn_every=5, n_patches=6404, vision_dim=1280),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-11b-smoke", family="vlm",
        n_layers=4, d_model=32, n_heads=4, n_kv_heads=2,
        d_ff=64, vocab=128,
        vision=VisionConfig(cross_attn_every=2, n_patches=8, vision_dim=16),
    )
