"""Mistral-Nemo 12B: dense GQA, 128k context, head_dim=128 (< d_model/H).
[hf:mistralai/Mistral-Nemo-Base-2407; hf]."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mistral-nemo-12b", family="dense",
        n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab=131072, head_dim=128, rope_theta=1000000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mistral-nemo-12b-smoke", family="dense",
        n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
        d_ff=64, vocab=128, head_dim=8,
    )
