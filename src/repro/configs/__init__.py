"""Architecture registry: one module per assigned architecture.

``get_config(name)`` -> full-size ModelConfig (dry-run only — never allocate)
``get_smoke_config(name)`` -> reduced same-family config for CPU smoke tests
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (
    SHAPES,
    SHAPES_BY_NAME,
    AudioConfig,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    ShapeSpec,
    SSMConfig,
    VisionConfig,
    XLSTMConfig,
    cell_is_applicable,
)

ARCH_IDS: List[str] = [
    "deepseek-moe-16b",
    "dbrx-132b",
    "llama-3.2-vision-11b",
    "hymba-1.5b",
    "glm4-9b",
    "minicpm3-4b",
    "internlm2-1.8b",
    "mistral-nemo-12b",
    "xlstm-350m",
    "whisper-base",
]

_MODULES = {
    "deepseek-moe-16b": "deepseek_moe_16b",
    "dbrx-132b": "dbrx_132b",
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
    "hymba-1.5b": "hymba_1_5b",
    "glm4-9b": "glm4_9b",
    "minicpm3-4b": "minicpm3_4b",
    "internlm2-1.8b": "internlm2_1_8b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "xlstm-350m": "xlstm_350m",
    "whisper-base": "whisper_base",
}


def _module(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get_config(name: str) -> ModelConfig:
    return _module(name).config()


def get_smoke_config(name: str) -> ModelConfig:
    return _module(name).smoke_config()


def all_configs() -> Dict[str, ModelConfig]:
    return {n: get_config(n) for n in ARCH_IDS}
