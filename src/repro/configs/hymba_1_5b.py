"""Hymba 1.5B: hybrid blocks with parallel attention + mamba heads,
sliding-window attention + 128 learnable meta tokens. [arXiv:2411.13676; hf]."""
from repro.configs.base import ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b", family="hybrid",
        n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
        d_ff=5504, vocab=32001, head_dim=64,
        sliding_window=1024, n_meta_tokens=128,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b-smoke", family="hybrid",
        n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
        d_ff=64, vocab=128, head_dim=8,
        sliding_window=8, n_meta_tokens=4,
        ssm=SSMConfig(d_state=4, d_conv=3, expand=2),
    )
