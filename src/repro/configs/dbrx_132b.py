"""DBRX 132B: 16 routed experts top-4, GQA kv=8.
[hf:databricks/dbrx-base; unverified]."""
from repro.configs.base import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b", family="moe",
        n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=10752, vocab=100352, rope_theta=500000.0,
        moe=MoEConfig(n_routed=16, top_k=4, d_expert=10752, n_shared=0),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b-smoke", family="moe",
        n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
        d_ff=48, vocab=128,
        moe=MoEConfig(n_routed=4, top_k=2, d_expert=48, n_shared=0),
    )
