"""xLSTM 350M: alternating mLSTM/sLSTM residual blocks, no separate FFN
(d_ff=0; channel mixing lives inside the blocks). [arXiv:2405.04517; unverified]."""
from repro.configs.base import ModelConfig, XLSTMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m", family="ssm",
        n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab=50304,
        xlstm=XLSTMConfig(slstm_every=2, proj_factor_mlstm=2.0,
                          proj_factor_slstm=1.333, conv_width=4),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m-smoke", family="ssm",
        n_layers=4, d_model=32, n_heads=2, n_kv_heads=2,
        d_ff=0, vocab=128,
        xlstm=XLSTMConfig(slstm_every=2, conv_width=3),
    )
