"""Pallas TPU flash-attention forward kernel.

TPU-native schedule: grid = (batch*q_heads, n_q_blocks, n_kv_blocks) with the
KV dimension innermost ("arbitrary" = sequential on TPU), so the online-
softmax running statistics (m, l, acc) live in VMEM scratch and persist
across KV steps.  Block shapes are MXU-aligned (block_q x d and block_k x d,
d padded to 128 by the wrapper) and sized so the working set

    q(bq x d) + k(bk x d) + v(bk x d) + scores(bq x bk) + acc(bq x d)

stays well under the ~16 MiB v5e VMEM (default 512x512x128 fp32 ~= 1.5 MiB).

Supports causal masking, GQA (q-head -> kv-head folding in the index maps),
sliding windows, and always-visible meta tokens (Hymba) — the same
visibility rule as the `ref.py` oracle.  Masked-out KV blocks are skipped
with `pl.when` on the *whole block* when statically... (dynamically) fully
invisible, which is where the causal 2x win comes from.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

NEG_INF = -1e30


def _fwd_kernel(
    q_ref,        # [block_q, d]
    k_ref,        # [block_k, d]
    v_ref,        # [block_k, d]
    o_ref,        # [block_q, d]
    m_ref,        # scratch [block_q]
    l_ref,        # scratch [block_q]
    acc_ref,      # scratch [block_q, d] f32
    *,
    sm_scale: float,
    causal: bool,
    window: int,
    n_meta: int,
    block_q: int,
    block_k: int,
    n_kv_blocks: int,
    kv_len: int,
):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)

    # visibility: in-bounds AND causal AND (window | meta)
    vis = k_pos < kv_len
    if causal:
        vis &= k_pos <= q_pos
    if window > 0:
        in_win = (q_pos - k_pos) < window
        if n_meta > 0:
            in_win |= k_pos < n_meta
        vis &= in_win

    # skip blocks that are fully masked (static causal structure):
    # first visible kv block index for this q block is known only dynamically
    # for windows, so we gate on a cheap dynamic test.
    block_visible = jnp.any(vis)

    @pl.when(block_visible)
    def _step():
        q = q_ref[...].astype(jnp.float32)
        k = k_ref[...].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale
        s = jnp.where(vis, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        m_ref[...] = m_new
        # sanitize out-of-bounds KV rows: OOB loads are undefined (NaN in
        # interpret mode) and 0 * NaN = NaN would poison the whole q block
        kv_valid = (
            kj * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_k, 1), 0)
            < kv_len
        )
        v = jnp.where(kv_valid, v_ref[...].astype(jnp.float32), 0.0)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(kj == n_kv_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(
    q: jax.Array,       # [BH, Sq, d]  (batch x q-heads flattened)
    k: jax.Array,       # [BKV, Skv, d] (batch x kv-heads flattened)
    v: jax.Array,       # [BKV, Skv, d]
    *,
    group: int,         # q heads per kv head (GQA)
    causal: bool = True,
    window: int = 0,
    n_meta: int = 0,
    sm_scale: Optional[float] = None,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    bh, sq, d = q.shape
    bkv, skv, _ = k.shape
    assert bh == bkv * group, (bh, bkv, group)
    sm_scale = sm_scale if sm_scale is not None else d ** -0.5
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    nq = pl.cdiv(sq, block_q)
    nk = pl.cdiv(skv, block_k)

    kernel = functools.partial(
        _fwd_kernel, sm_scale=sm_scale, causal=causal, window=window,
        n_meta=n_meta, block_q=block_q, block_k=block_k, n_kv_blocks=nk,
        kv_len=skv,
    )
    return pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((None, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((None, block_k, d), lambda b, i, j: (b // group, j, 0)),
            pl.BlockSpec((None, block_k, d), lambda b, i, j: (b // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
