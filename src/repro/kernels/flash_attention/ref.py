"""Pure-jnp oracle for the flash-attention kernel (naive full-scores)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(
    q: jax.Array,       # [BH, Sq, d]
    k: jax.Array,       # [BKV, Skv, d]
    v: jax.Array,       # [BKV, Skv, d]
    *,
    group: int,
    causal: bool = True,
    window: int = 0,
    n_meta: int = 0,
    sm_scale: Optional[float] = None,
) -> jax.Array:
    bh, sq, d = q.shape
    bkv, skv, _ = k.shape
    sm_scale = sm_scale if sm_scale is not None else d ** -0.5
    kr = jnp.repeat(k, group, axis=0)
    vr = jnp.repeat(v, group, axis=0)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), kr.astype(jnp.float32))
    s = s * sm_scale
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(skv)[None, :]
    vis = jnp.ones((sq, skv), bool)
    if causal:
        vis &= k_pos <= q_pos
    if window > 0:
        in_win = (q_pos - k_pos) < window
        if n_meta > 0:
            in_win |= k_pos < n_meta
        vis &= in_win
    s = jnp.where(vis[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, vr.astype(jnp.float32)).astype(q.dtype)
