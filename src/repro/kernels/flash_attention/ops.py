"""Jit'd public wrapper around the flash-attention Pallas kernel.

Handles layout (model-stack [B, S, H, D] <-> kernel [B*H, S, D]), head-dim
padding to the 128-lane MXU width, and backend selection: the Pallas kernel
on TPU, interpret-mode on CPU (correctness validation), with the pure-jnp
reference available for differentiation (the kernel is forward-only; the
training path uses the rematerialized chunked-jnp attention in
`repro.models.attention`).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ref import attention_ref


def _pad_head_dim(x: jax.Array, multiple: int = 128):
    d = x.shape[-1]
    target = -(-d // multiple) * multiple
    if target == d:
        return x, d
    pad = [(0, 0)] * (x.ndim - 1) + [(0, target - d)]
    return jnp.pad(x, pad), d


@partial(
    jax.jit,
    static_argnames=("causal", "window", "n_meta", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,       # [B, Sq, H, D] (model-stack layout)
    k: jax.Array,       # [B, Skv, KVH, D]
    v: jax.Array,       # [B, Skv, KVH, D]
    *,
    causal: bool = True,
    window: int = 0,
    n_meta: int = 0,
    block_q: int = 512,
    block_k: int = 512,
    interpret: Optional[bool] = None,
) -> jax.Array:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    group = h // kvh
    sm_scale = d ** -0.5  # scale by the TRUE head dim, not the padded one

    qt = jnp.moveaxis(q, 2, 1).reshape(b * h, sq, d)
    kt = jnp.moveaxis(k, 2, 1).reshape(b * kvh, k.shape[1], d)
    vt = jnp.moveaxis(v, 2, 1).reshape(b * kvh, v.shape[1], d)
    qt, _ = _pad_head_dim(qt)
    kt, _ = _pad_head_dim(kt)
    vt, _ = _pad_head_dim(vt)

    out = flash_attention_fwd(
        qt, kt, vt, group=group, causal=causal, window=window, n_meta=n_meta,
        sm_scale=sm_scale, block_q=block_q, block_k=block_k, interpret=interpret,
    )
    out = out[..., :d].reshape(b, h, sq, d)
    return jnp.moveaxis(out, 1, 2)


def flash_attention_reference(q, k, v, *, causal=True, window=0, n_meta=0):
    """Same layout contract as ``flash_attention`` but the jnp oracle."""
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    qt = jnp.moveaxis(q, 2, 1).reshape(b * h, sq, d)
    kt = jnp.moveaxis(k, 2, 1).reshape(b * kvh, k.shape[1], d)
    vt = jnp.moveaxis(v, 2, 1).reshape(b * kvh, v.shape[1], d)
    out = attention_ref(
        qt, kt, vt, group=h // kvh, causal=causal, window=window, n_meta=n_meta)
    return jnp.moveaxis(out.reshape(b, h, sq, d), 1, 2)
