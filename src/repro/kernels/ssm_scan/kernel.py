"""Pallas TPU kernel: Mamba selective-scan, chunked over time.

TPU adaptation of the CUDA selective-scan: the GPU kernel threads over
channels with registers holding h; on TPU we tile channels into VMEM blocks
and make the *chunk* dimension the innermost (sequential) grid axis so the
[bd, d_state] state lives in VMEM scratch across chunks.  Within a chunk the
recurrence is a ``fori_loop`` whose per-step work is [bd, d_state]
element-wise math + a [bd]-wide reduction — VPU work, with all HBM traffic
(inputs delta/B/C/x, output y) streamed once per chunk.

Grid: (batch, d_inner / bd, n_chunks); chunks innermost = sequential.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams


def _ssm_kernel(
    delta_ref,    # [chunk, bd]
    b_ref,        # [chunk, ds]
    c_ref,        # [chunk, ds]
    x_ref,        # [chunk, bd]
    a_ref,        # [bd, ds]     (A = -exp(a_log), precomputed by wrapper)
    h0_ref,       # [bd, ds]     initial state for this (batch, d-block)
    y_ref,        # [chunk, bd]  output
    hout_ref,     # [bd, ds]     final state
    h_ref,        # scratch [bd, ds] f32
    *,
    chunk: int,
    seq_len: int,
    n_chunks: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = h0_ref[...].astype(jnp.float32)

    a = a_ref[...].astype(jnp.float32)                     # [bd, ds]

    def step(t, h_prev):
        dl = delta_ref[t, :].astype(jnp.float32)           # [bd]
        bt = b_ref[t, :].astype(jnp.float32)               # [ds]
        ct = c_ref[t, :].astype(jnp.float32)               # [ds]
        xt = x_ref[t, :].astype(jnp.float32)               # [bd]
        decay = jnp.exp(dl[:, None] * a)                   # [bd, ds]
        h_new = decay * h_prev + (dl * xt)[:, None] * bt[None, :]
        y = jnp.sum(h_new * ct[None, :], axis=1)           # [bd]
        valid = (ci * chunk + t) < seq_len                 # ragged tail guard
        y_ref[t, :] = jnp.where(valid, y, 0.0).astype(y_ref.dtype)
        # padded steps must not advance the state (streaming correctness)
        return jnp.where(valid, h_new, h_prev)

    h = jax.lax.fori_loop(0, chunk, step, h_ref[...])
    h_ref[...] = h

    @pl.when(ci == n_chunks - 1)
    def _final():
        hout_ref[...] = h_ref[...].astype(hout_ref.dtype)


def ssm_scan(
    delta: jax.Array,   # [B, S, d_inner] f32
    b: jax.Array,       # [B, S, d_state]
    c: jax.Array,       # [B, S, d_state]
    x: jax.Array,       # [B, S, d_inner]
    a: jax.Array,       # [d_inner, d_state] (A = -exp(a_log))
    h0: jax.Array,      # [B, d_inner, d_state]
    *,
    chunk: int = 128,
    block_d: int = 512,
    interpret: bool = False,
):
    """Returns (y [B, S, d_inner], h_final [B, d_inner, d_state])."""
    bsz, s, di = delta.shape
    ds = b.shape[-1]
    chunk = min(chunk, s)
    bd = min(block_d, di)
    n_chunks = pl.cdiv(s, chunk)
    grid = (bsz, pl.cdiv(di, bd), n_chunks)
    kernel = functools.partial(
        _ssm_kernel, chunk=chunk, seq_len=s, n_chunks=n_chunks)
    y, hout = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, chunk, bd), lambda bb, dd, cc: (bb, cc, dd)),
            pl.BlockSpec((None, chunk, ds), lambda bb, dd, cc: (bb, cc, 0)),
            pl.BlockSpec((None, chunk, ds), lambda bb, dd, cc: (bb, cc, 0)),
            pl.BlockSpec((None, chunk, bd), lambda bb, dd, cc: (bb, cc, dd)),
            pl.BlockSpec((bd, ds), lambda bb, dd, cc: (dd, 0)),
            pl.BlockSpec((None, bd, ds), lambda bb, dd, cc: (bb, dd, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, chunk, bd), lambda bb, dd, cc: (bb, cc, dd)),
            pl.BlockSpec((None, bd, ds), lambda bb, dd, cc: (bb, dd, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, di), delta.dtype),
            jax.ShapeDtypeStruct((bsz, di, ds), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bd, ds), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(delta, b, c, x, a, h0)
    return y, hout
