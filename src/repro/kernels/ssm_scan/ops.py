"""Jit'd wrapper for the selective-scan kernel (interpret on CPU)."""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.ssm_scan.kernel import ssm_scan
from repro.kernels.ssm_scan.ref import ssm_scan_ref


@partial(jax.jit, static_argnames=("chunk", "block_d", "interpret"))
def selective_scan(delta, b, c, x, a, h0, *, chunk: int = 128,
                   block_d: int = 512, interpret: Optional[bool] = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return ssm_scan(delta, b, c, x, a, h0, chunk=chunk, block_d=block_d,
                    interpret=interpret)


selective_scan_ref = ssm_scan_ref
