"""Pure-jnp oracle for the mamba selective scan (sequential over time)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssm_scan_ref(delta, b, c, x, a, h0):
    """delta/x: [B,S,di]; b/c: [B,S,ds]; a: [di,ds]; h0: [B,di,ds].
    Returns (y [B,S,di], h_final)."""

    def step(h, inp):
        dl, bt, ct, xt = inp
        decay = jnp.exp(dl[:, :, None] * a)
        h = decay * h + (dl * xt)[:, :, None] * bt[:, None, :]
        y = jnp.einsum("bds,bs->bd", h, ct)
        return h, y

    xs = tuple(jnp.moveaxis(v, 1, 0) for v in (delta, b, c, x))
    h, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1).astype(delta.dtype), h
