"""Version-gated Pallas-TPU compat layer shared by every kernel.

The kernels target the modern ``pltpu.CompilerParams`` API; the pinned
jax 0.4.37 still spells it ``TPUCompilerParams`` (the rename landed in a
later jax).  Importing ``CompilerParams`` from here resolves whichever
name the installed jax provides — same constructor signature either way
(``dimension_semantics`` is all the kernels pass) — so the kernel modules
stay written against the current API and un-break on the pinned version
(this is what let the 22 kernel entries leave tests/known_failures.toml).
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as _pltpu

try:
    CompilerParams = _pltpu.CompilerParams
except AttributeError:          # jax <= 0.4.x: pre-rename spelling
    CompilerParams = _pltpu.TPUCompilerParams

__all__ = ["CompilerParams"]
