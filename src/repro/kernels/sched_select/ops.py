"""Jit'd wrapper for the fused victim-select/placement kernel.

`plan_evictions_fused` is what `core/omfs_jax.plan_evictions` dispatches
to when ``SchedulerConfig.kernel_backend`` selects the pallas path.  The
wrapper pads the columns to a power-of-two ``[1, Jp]`` tile (Jp >= 128,
pad rows carry ``evictable=0`` so the in-kernel mask retires them),
splits the ``[J, T]`` effective save lattice into T tile rows, packs the
``2 + 2T`` scalars, and scatters the sorted-position outputs back to row
order — the only pieces kept outside the kernel, all O(J).

Outputs are bit-identical to `ref.plan_evictions_ref` (and hence to the
lax path) by construction: the kernel's masked total order restricted to
the evictable rows equals the lexsort order restricted to them, and the
planned/placement decisions depend on nothing else — padding and
non-evictable rows contribute zero CPUs and can never be planned.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.sched_select.kernel import sched_select_kernel
from repro.kernels.sched_select.ref import plan_evictions_ref  # noqa: F401

#: minimum padded tile — one TPU lane row
MIN_TILE = 128


def _padded_len(j: int) -> int:
    return max(MIN_TILE, 1 << max(0, j - 1).bit_length())


@partial(jax.jit, static_argnames=("cheap", "tiered", "bounded", "interpret"))
def plan_evictions_fused(prio, run_start, jid, key_cost, evictable, cpus,
                         state_mib, is_ckpt, save_lat, idle, cpus_needed,
                         occ, cap, *, cheap: bool = False,
                         tiered: bool = False, bounded: bool = False,
                         interpret: bool = True):
    """Fused plan over bare columns.

    ``planned`` is the paper's minimal victim prefix (lines 32-36) in the
    requested victim-key order (``key_cost`` — the delta-aware effective
    tier-0 save cost — leads the key when ``cheap``), ``enough`` the
    feasibility bit, and ``tier`` the greedy cheapest-feasible placement
    of the checkpointable planned victims over the ``[J, T]`` effective
    save lattice (all-zero when ``tiered=False``).  ``occ``/``cap`` are
    ``[T]`` per-tier occupancy/capacity vectors (``cap[k] < 0`` =
    unbounded); ``bounded`` is the static "some tier has finite capacity"
    flag.  Returns ``(planned[J] bool, enough bool, tier[J] int32)``.
    """
    j = prio.shape[0]
    jp = _padded_len(j)
    n_tiers = save_lat.shape[1]

    def col(x):
        x = jnp.asarray(x, jnp.int32).reshape(1, j)
        return jnp.pad(x, ((0, 0), (0, jp - j)))

    lat_cols = [col(save_lat[:, k]) for k in range(n_tiers)]
    scal = jnp.concatenate([
        jnp.stack([jnp.asarray(idle, jnp.int32),
                   jnp.asarray(cpus_needed, jnp.int32)]),
        jnp.asarray(occ, jnp.int32).reshape(n_tiers),
        jnp.asarray(cap, jnp.int32).reshape(n_tiers),
    ]).reshape(1, 2 + 2 * n_tiers)
    kern = partial(sched_select_kernel, cheap=cheap, tiered=tiered,
                   bounded=bounded, n_tiers=n_tiers)
    tile = jax.ShapeDtypeStruct((1, jp), jnp.int32)
    row_s, planned_s, tier_s, enough = pl.pallas_call(
        kern,
        out_shape=[tile, tile, tile, jax.ShapeDtypeStruct((1, 1), jnp.int32)],
        interpret=interpret,
    )(col(prio), col(run_start), col(jid), col(key_cost), col(evictable),
      col(cpus), col(state_mib), col(is_ckpt), *lat_cols, scal)
    planned = jnp.zeros((jp,), jnp.int32).at[row_s[0]].set(planned_s[0])[:j]
    tier = jnp.zeros((jp,), jnp.int32).at[row_s[0]].set(tier_s[0])[:j]
    return planned.astype(bool), enough[0, 0].astype(bool), tier
