"""Pallas kernel: fused victim-select + tier-placement (paper lines 32-36).

One ``pallas_call`` fuses the whole per-eviction decision that
``core/omfs_jax.py`` otherwise spells as ``jnp.lexsort`` + gather + cumsum
+ ``lax.scan``:

* masked victim keys — non-evictable rows pushed to ``MASK`` so the sort
  brings the victim candidates to the front in victim-key order
  (faithful ``(priority, run_start, jid)`` or cheap-victim
  ``(cost_save, priority, run_start, jid)``), with the row index as a
  final tie-break so the order is total;
* a bitonic sort over the padded power-of-two tile, written as roll-based
  compare-exchange (partner ``i ^ j`` = ``roll(x, -j)`` where bit ``j`` of
  ``i`` is clear, ``roll(x, +j)`` where set) so it is gather-free — VPU
  selects and lane rotations only, the layout Mosaic lowers well;
* a Hillis-Steele log-step prefix sum of the freed CPUs and the paper's
  minimal-prefix capacity cutoff;
* the greedy cheapest-feasible fast-tier placement scan, bounded by the
  last planned position (the victim prefix), not the full tile.

Everything is int32 on ``[1, Jp]`` tiles (`Jp` = padded length, a multiple
of 128), so the kernel inherits the engine's integer-grid bit-exactness:
there is no arithmetic here that could round differently from the lax
path.  The stage loops carry traced ``(k, j)`` shift amounts, so the
traced program is O(1) in ``Jp`` — only the runtime loop trip counts grow.

On CPU (and in CI) the kernel runs in interpret mode; the roll/select
formulation is chosen for the TPU lowering, where the fused kernel keeps
the whole decision in VMEM for one HBM round-trip (see the roofline entry
in ``bench_sched_scale``).  Single-block kernel: ``Jp`` tiles above ~64k
rows exceed VMEM on real TPUs and would need a multi-block variant.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

#: key for masked (non-evictable / padding) rows — sorts after any real key
MASK = jnp.iinfo(jnp.int32).max


def _lex_lt(a, b):
    """Elementwise lexicographic ``a < b`` over equal-length key tuples."""
    lt = jnp.zeros(a[0].shape, jnp.bool_)
    eq = jnp.ones(a[0].shape, jnp.bool_)
    for ai, bi in zip(a, b):
        lt = lt | (eq & (ai < bi))
        eq = eq & (ai == bi)
    return lt


def sched_select_kernel(prio_ref, rstart_ref, jid_ref, csave_ref, evict_ref,
                        cpus_ref, mib_ref, want0_ref, scal_ref,
                        row_ref, planned_ref, take_ref, enough_ref,
                        *, cheap: bool, tiered: bool, bounded: bool):
    """Fused plan: sorted-order rows, victim mask, fast-tier placement.

    Inputs are ``[1, Jp]`` int32 (Jp a power of two >= 128); ``scal_ref``
    is ``[1, 4]`` packing (idle, cpus_needed, occ0, cap0).  Outputs:
    ``row_ref``/``planned_ref``/``take_ref`` are the sorted-position row
    index / planned-victim flag / fast-tier flag (scattered back to row
    order by the wrapper), ``enough_ref`` is the scalar feasibility bit.
    """
    shape = prio_ref.shape
    jp = shape[1]
    idx = jax.lax.broadcasted_iota(jnp.int32, shape, 1)
    evict = evict_ref[...]
    is_victim = evict == 1

    def masked(ref):
        return jnp.where(is_victim, ref[...], MASK)

    # most-significant first; idx makes the order total (bitonic is not
    # stable, but every real tie is already broken by the unique jid)
    keys = [masked(prio_ref), masked(rstart_ref), masked(jid_ref), idx]
    if cheap:
        keys.insert(0, masked(csave_ref))
    n_keys = len(keys)
    vals = [evict, cpus_ref[...]]
    if tiered:
        vals += [mib_ref[...], want0_ref[...]]
    arrays = tuple(keys + vals)

    def partner(x, j):
        # value at index i ^ j, j a power of two: i + j where bit j of i is
        # clear (roll left), i - j where it is set (roll right)
        return jnp.where((idx & j) == 0,
                         jnp.roll(x, -j, axis=1), jnp.roll(x, j, axis=1))

    def stage(_, carry):
        k, j, arrs = carry
        part = tuple(partner(a, j) for a in arrs)
        # ascending blocks of size k: position i keeps the smaller element
        # iff its direction bit and pair side agree
        want_min = ((idx & k) == 0) == ((idx & j) == 0)
        take_other = jnp.where(want_min, _lex_lt(part[:n_keys], arrs[:n_keys]),
                               _lex_lt(arrs[:n_keys], part[:n_keys]))
        arrs = tuple(jnp.where(take_other, p, a) for p, a in zip(part, arrs))
        j = j // 2
        k = jnp.where(j == 0, k * 2, k)
        j = jnp.where(j == 0, k // 2, j)
        return k, j, arrs

    log2 = jp.bit_length() - 1
    n_stages = log2 * (log2 + 1) // 2
    _, _, arrays = jax.lax.fori_loop(
        0, n_stages, stage, (jnp.int32(2), jnp.int32(1), arrays))

    row_s = arrays[n_keys - 1]
    live = arrays[n_keys] == 1
    freed = jnp.where(live, arrays[n_keys + 1], 0)

    def pfx(s, x):             # Hillis-Steele inclusive prefix sum
        d = jnp.left_shift(jnp.int32(1), s)
        return x + jnp.where(idx >= d, jnp.roll(x, d, axis=1), 0)

    cum = jax.lax.fori_loop(0, log2, pfx, freed)

    idle = scal_ref[0, 0]
    cpus_needed = scal_ref[0, 1]
    need = jnp.maximum(cpus_needed - idle, 0)
    planned = live & (cum - freed < need)      # the minimal victim prefix
    enough_ref[0, 0] = (idle + cum[0, jp - 1] >= cpus_needed).astype(jnp.int32)

    if not tiered:
        take = jnp.zeros(shape, jnp.int32)
    else:
        want = planned & (arrays[n_keys + 3] == 1)
        if not bounded:                        # unbounded fast tier
            take = want.astype(jnp.int32)
        else:
            occ0 = scal_ref[0, 2]
            cap0 = scal_ref[0, 3]
            mib_s = arrays[n_keys + 2]
            want_i = want.astype(jnp.int32)
            # greedy is sequential by nature (a skipped victim frees space a
            # later smaller one may claim) but only over the victim prefix
            stop = jnp.max(jnp.where(planned, idx + 1, 0))

            def greedy(i, carry):
                occ, take = carry
                w = jax.lax.dynamic_slice(want_i, (0, i), (1, 1))[0, 0]
                m = jax.lax.dynamic_slice(mib_s, (0, i), (1, 1))[0, 0]
                ok = (w == 1) & (occ + m <= cap0)
                occ = occ + jnp.where(ok, m, 0)
                take = jax.lax.dynamic_update_slice(
                    take, ok.astype(jnp.int32)[None, None], (0, i))
                return occ, take

            _, take = jax.lax.fori_loop(
                0, stop, greedy, (occ0, jnp.zeros(shape, jnp.int32)))

    row_ref[...] = row_s
    planned_ref[...] = planned.astype(jnp.int32)
    take_ref[...] = take
