"""Pallas kernel: fused victim-select + tier-placement (paper lines 32-36).

One ``pallas_call`` fuses the whole per-eviction decision that
``core/omfs_jax.py`` otherwise spells as ``jnp.lexsort`` + gather + cumsum
+ ``lax.scan``:

* masked victim keys — non-evictable rows pushed to ``MASK`` so the sort
  brings the victim candidates to the front in victim-key order
  (faithful ``(priority, run_start, jid)`` or cheap-victim
  ``(save_cost, priority, run_start, jid)`` — the save cost being the
  delta-aware effective tier-0 column), with the row index as a final
  tie-break so the order is total;
* a bitonic sort over the padded power-of-two tile, written as roll-based
  compare-exchange (partner ``i ^ j`` = ``roll(x, -j)`` where bit ``j`` of
  ``i`` is clear, ``roll(x, +j)`` where set) so it is gather-free — VPU
  selects and lane rotations only, the layout Mosaic lowers well;
* a Hillis-Steele log-step prefix sum of the freed CPUs and the paper's
  minimal-prefix capacity cutoff;
* the greedy cheapest-feasible T-tier placement over the ``[J, T]``
  effective save-cost lattice (the T columns ride the sort as extra value
  rows), bounded by the last planned position (the victim prefix), not
  the full tile.  Tier choice is a static ascending strict-``<`` argmin —
  first-occurrence semantics, bit-identical to
  `TieredCRCostModel.choose_tier` (ties toward the faster tier).

Everything is int32 on ``[1, Jp]`` tiles (`Jp` = padded length, a multiple
of 128), so the kernel inherits the engine's integer-grid bit-exactness:
there is no arithmetic here that could round differently from the lax
path.  The stage loops carry traced ``(k, j)`` shift amounts, so the
traced program is O(1) in ``Jp``; the per-tier placement unroll is O(T) —
T is a small static (2-4 in practice).

On CPU (and in CI) the kernel runs in interpret mode; the roll/select
formulation is chosen for the TPU lowering, where the fused kernel keeps
the whole decision in VMEM for one HBM round-trip (see the roofline entry
in ``bench_sched_scale``).  Single-block kernel: ``Jp`` tiles above ~64k
rows exceed VMEM on real TPUs and would need a multi-block variant.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

#: key for masked (non-evictable / padding) rows — sorts after any real key;
#: also the infeasible-tier sentinel in the placement argmin
MASK = jnp.iinfo(jnp.int32).max


def _lex_lt(a, b):
    """Elementwise lexicographic ``a < b`` over equal-length key tuples."""
    lt = jnp.zeros(a[0].shape, jnp.bool_)
    eq = jnp.ones(a[0].shape, jnp.bool_)
    for ai, bi in zip(a, b):
        lt = lt | (eq & (ai < bi))
        eq = eq & (ai == bi)
    return lt


def sched_select_kernel(prio_ref, rstart_ref, jid_ref, key_ref, evict_ref,
                        cpus_ref, mib_ref, ckpt_ref, *rest,
                        cheap: bool, tiered: bool, bounded: bool,
                        n_tiers: int):
    """Fused plan: sorted-order rows, victim mask, T-tier placement.

    Inputs are ``[1, Jp]`` int32 (Jp a power of two >= 128): the victim-key
    columns, the evictable/cpus columns, ``mib_ref``/``ckpt_ref`` (state
    size and checkpointability) and — in ``rest`` — the ``n_tiers``
    effective save-lattice columns followed by ``scal_ref``, a
    ``[1, 2 + 2T]`` pack of (idle, cpus_needed, occ[0..T-1], cap[0..T-1]).
    Outputs (the tail of ``rest``): ``row_ref``/``planned_ref``/``tier_ref``
    are the sorted-position row index / planned-victim flag / placed tier
    (scattered back to row order by the wrapper), ``enough_ref`` is the
    scalar feasibility bit.
    """
    lat_refs = rest[:n_tiers]
    scal_ref = rest[n_tiers]
    row_ref, planned_ref, tier_ref, enough_ref = rest[n_tiers + 1:]
    shape = prio_ref.shape
    jp = shape[1]
    idx = jax.lax.broadcasted_iota(jnp.int32, shape, 1)
    evict = evict_ref[...]
    is_victim = evict == 1

    def masked(ref):
        return jnp.where(is_victim, ref[...], MASK)

    # most-significant first; idx makes the order total (bitonic is not
    # stable, but every real tie is already broken by the unique jid)
    keys = [masked(prio_ref), masked(rstart_ref), masked(jid_ref), idx]
    if cheap:
        keys.insert(0, masked(key_ref))
    n_keys = len(keys)
    vals = [evict, cpus_ref[...]]
    if tiered:
        vals += [mib_ref[...], ckpt_ref[...]]
        vals += [r[...] for r in lat_refs]
    arrays = tuple(keys + vals)

    def partner(x, j):
        # value at index i ^ j, j a power of two: i + j where bit j of i is
        # clear (roll left), i - j where it is set (roll right)
        return jnp.where((idx & j) == 0,
                         jnp.roll(x, -j, axis=1), jnp.roll(x, j, axis=1))

    def stage(_, carry):
        k, j, arrs = carry
        part = tuple(partner(a, j) for a in arrs)
        # ascending blocks of size k: position i keeps the smaller element
        # iff its direction bit and pair side agree
        want_min = ((idx & k) == 0) == ((idx & j) == 0)
        take_other = jnp.where(want_min, _lex_lt(part[:n_keys], arrs[:n_keys]),
                               _lex_lt(arrs[:n_keys], part[:n_keys]))
        arrs = tuple(jnp.where(take_other, p, a) for p, a in zip(part, arrs))
        j = j // 2
        k = jnp.where(j == 0, k * 2, k)
        j = jnp.where(j == 0, k // 2, j)
        return k, j, arrs

    log2 = jp.bit_length() - 1
    n_stages = log2 * (log2 + 1) // 2
    _, _, arrays = jax.lax.fori_loop(
        0, n_stages, stage, (jnp.int32(2), jnp.int32(1), arrays))

    row_s = arrays[n_keys - 1]
    live = arrays[n_keys] == 1
    freed = jnp.where(live, arrays[n_keys + 1], 0)

    def pfx(s, x):             # Hillis-Steele inclusive prefix sum
        d = jnp.left_shift(jnp.int32(1), s)
        return x + jnp.where(idx >= d, jnp.roll(x, d, axis=1), 0)

    cum = jax.lax.fori_loop(0, log2, pfx, freed)

    idle = scal_ref[0, 0]
    cpus_needed = scal_ref[0, 1]
    need = jnp.maximum(cpus_needed - idle, 0)
    planned = live & (cum - freed < need)      # the minimal victim prefix
    enough_ref[0, 0] = (idle + cum[0, jp - 1] >= cpus_needed).astype(jnp.int32)

    if not tiered:
        tier = jnp.zeros(shape, jnp.int32)
    else:
        mib_s = arrays[n_keys + 2]
        want = planned & (arrays[n_keys + 3] == 1)
        lats = arrays[n_keys + 4:]
        if not bounded:            # every tier unbounded: elementwise argmin
            best_c, best_t = lats[0], jnp.zeros(shape, jnp.int32)
            for k in range(1, n_tiers):
                better = lats[k] < best_c      # strict: ties keep lower k
                best_c = jnp.where(better, lats[k], best_c)
                best_t = jnp.where(better, k, best_t)
            tier = jnp.where(want, best_t, 0)
        else:
            want_i = want.astype(jnp.int32)
            occs = tuple(scal_ref[0, 2 + k] for k in range(n_tiers))
            caps = tuple(scal_ref[0, 2 + n_tiers + k] for k in range(n_tiers))
            # greedy is sequential by nature (a skipped victim frees space a
            # later smaller one may claim) but only over the victim prefix
            stop = jnp.max(jnp.where(planned, idx + 1, 0))

            def at(x, i):
                return jax.lax.dynamic_slice(x, (0, i), (1, 1))[0, 0]

            def greedy(i, carry):
                occs, tier = carry
                w = at(want_i, i)
                m = at(mib_s, i)
                best_c = jnp.int32(MASK)
                best_t = jnp.int32(0)
                for k in range(n_tiers):       # static unroll, T is small
                    feas = (caps[k] < 0) | (occs[k] + m <= caps[k])
                    c = jnp.where(feas, at(lats[k], i), MASK)
                    better = c < best_c        # strict: ties keep lower k
                    best_c = jnp.where(better, c, best_c)
                    best_t = jnp.where(better, k, best_t)
                occs = tuple(
                    occs[k] + jnp.where((w == 1) & (best_t == k), m, 0)
                    for k in range(n_tiers))
                tier = jax.lax.dynamic_update_slice(
                    tier, jnp.where(w == 1, best_t, 0)[None, None], (0, i))
                return occs, tier

            _, tier = jax.lax.fori_loop(
                0, stop, greedy, (occs, jnp.zeros(shape, jnp.int32)))

    row_ref[...] = row_s
    planned_ref[...] = planned.astype(jnp.int32)
    tier_ref[...] = tier
