"""Pure-jnp reference for the fused victim-select/placement kernel.

Spells the exact ``jnp.lexsort`` + cumsum + ``lax.scan`` sequence that
``core/omfs_jax.py``'s ``victim_order`` / ``select_victims`` /
``place_checkpoints`` perform, but over bare columns — the oracle the
kernel's property tests compare against without importing the JobTable.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


@partial(jax.jit, static_argnames=("cheap", "tiered", "bounded"))
def plan_evictions_ref(prio, run_start, jid, cost_save, evictable, cpus,
                       state_mib, want0, idle, cpus_needed, occ0, cap0,
                       *, cheap: bool = False, tiered: bool = False,
                       bounded: bool = False):
    """Returns ``(planned[J], enough, take_fast[J])`` — see ops.py."""
    keys = ((jid, run_start, prio, cost_save) if cheap
            else (jid, run_start, prio))
    order = jnp.lexsort(keys)
    evictable = evictable.astype(bool)
    evict_sorted = evictable[order]
    cpus_sorted = jnp.where(evict_sorted, cpus[order], 0)
    freed_cum = jnp.cumsum(cpus_sorted)
    need = jnp.maximum(cpus_needed - idle, 0)
    planned_sorted = evict_sorted & (freed_cum - cpus_sorted < need)
    enough = idle + freed_cum[-1] >= cpus_needed
    planned = jnp.zeros_like(evictable).at[order].set(planned_sorted)
    if not tiered:
        return planned, enough, jnp.zeros_like(evictable)
    want_sorted = planned_sorted & want0.astype(bool)[order]
    if not bounded:
        take_sorted = want_sorted
    else:
        mib_sorted = jnp.where(want_sorted, state_mib[order], 0)

        def place(occ, x):
            want, mib = x
            take = want & (occ + mib <= cap0)
            return occ + jnp.where(take, mib, 0), take

        _, take_sorted = jax.lax.scan(
            place, jnp.asarray(occ0, jnp.int32), (want_sorted, mib_sorted))
    take_fast = jnp.zeros_like(evictable).at[order].set(take_sorted)
    return planned, enough, take_fast
