"""Pure-jnp reference for the fused victim-select/placement kernel.

Spells the exact ``jnp.lexsort`` + cumsum + ``lax.scan`` sequence that
``core/omfs_jax.py``'s ``victim_order`` / ``select_victims`` /
``place_checkpoints`` perform, but over bare columns — the oracle the
kernel's property tests compare against without importing the JobTable.

Placement is T-tier: ``save_lat`` is the ``[J, T]`` effective save-cost
lattice (delta-aware — the caller already selected first vs recurrent
rows), ``occ``/``cap`` are ``[T]`` occupancy/capacity vectors, and the
chosen tier per victim is the first-occurrence argmin over feasible
columns (`TieredCRCostModel.choose_tier` semantics: ties toward the
faster tier, the last tier always feasible).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

MASK = jnp.int32(jnp.iinfo(jnp.int32).max)


@partial(jax.jit, static_argnames=("cheap", "tiered", "bounded"))
def plan_evictions_ref(prio, run_start, jid, key_cost, evictable, cpus,
                       state_mib, is_ckpt, save_lat, idle, cpus_needed,
                       occ, cap, *, cheap: bool = False, tiered: bool = False,
                       bounded: bool = False):
    """Returns ``(planned[J], enough, tier[J])`` — see ops.py."""
    keys = ((jid, run_start, prio, key_cost) if cheap
            else (jid, run_start, prio))
    order = jnp.lexsort(keys)
    evictable = evictable.astype(bool)
    evict_sorted = evictable[order]
    cpus_sorted = jnp.where(evict_sorted, cpus[order], 0)
    freed_cum = jnp.cumsum(cpus_sorted)
    need = jnp.maximum(cpus_needed - idle, 0)
    planned_sorted = evict_sorted & (freed_cum - cpus_sorted < need)
    enough = idle + freed_cum[-1] >= cpus_needed
    planned = jnp.zeros_like(evictable).at[order].set(planned_sorted)
    if not tiered:
        return planned, enough, jnp.zeros_like(jid)
    n_tiers = save_lat.shape[1]
    cap = jnp.asarray(cap, jnp.int32)
    want_sorted = planned_sorted & is_ckpt.astype(bool)[order]
    lat_sorted = save_lat[order]
    if not bounded:                 # every tier unbounded: pure row-argmin
        tier_sorted = jnp.argmin(lat_sorted, axis=1).astype(jnp.int32)
    else:
        mib_sorted = jnp.where(want_sorted, state_mib[order], 0)

        def place(o, x):
            want, mib, costs = x
            feasible = (cap < 0) | (o + mib <= cap)
            t = jnp.argmin(jnp.where(feasible, costs, MASK)).astype(jnp.int32)
            taken = jnp.where(want & (jnp.arange(n_tiers) == t), mib, 0)
            return o + taken, t

        _, tier_sorted = jax.lax.scan(
            place, jnp.asarray(occ, jnp.int32),
            (want_sorted, mib_sorted, lat_sorted))
    tier_sorted = jnp.where(want_sorted, tier_sorted, 0)
    tier = jnp.zeros_like(jid).at[order].set(tier_sorted)
    return planned, enough, tier
