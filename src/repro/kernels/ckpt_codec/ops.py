"""Public API: quantize/dequantize arbitrary pytree leaves for the fast
checkpoint tier.  Pads flat arrays to the 128-lane layout, runs the Pallas
codec (interpret on CPU), and exposes round-trip helpers used by
checkpoint.manager when ``quantize_fast_tier`` is enabled."""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ckpt_codec.kernel import LANE, dequantize_blocks, quantize_blocks


@partial(jax.jit, static_argnames=("interpret",))
def quantize_array(x: jax.Array, *, interpret: Optional[bool] = None):
    """Any-shape fp array -> (int8 [R,128], scales [R], meta) round-trippable."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    flat = x.reshape(-1)
    n = flat.shape[0]
    rows = -(-n // LANE)
    flat = jnp.pad(flat, (0, rows * LANE - n)).reshape(rows, LANE)
    q, s = quantize_blocks(flat.astype(jnp.float32), interpret=interpret)
    return q, s


@partial(jax.jit, static_argnames=("shape", "dtype", "interpret"))
def dequantize_array(q, s, *, shape, dtype=jnp.float32,
                     interpret: Optional[bool] = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    flat = dequantize_blocks(q, s, out_dtype=dtype, interpret=interpret)
    n = int(np.prod(shape)) if shape else 1
    return flat.reshape(-1)[:n].reshape(shape)


def roundtrip_error(x: jax.Array) -> float:
    """Max relative error of one quantize/dequantize round trip."""
    q, s = quantize_array(x)
    y = dequantize_array(q, s, shape=x.shape, dtype=x.dtype)
    denom = jnp.maximum(jnp.abs(x).max(), 1e-12)
    return float(jnp.abs(y - x).max() / denom)
