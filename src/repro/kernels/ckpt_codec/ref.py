"""Pure-jnp oracle for the checkpoint int8 block codec."""
from __future__ import annotations

import jax
import jax.numpy as jnp

LANE = 128


def quantize_ref(x: jax.Array):
    """x [R, 128] -> (int8 [R, 128], scales [R])."""
    xf = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xf), axis=1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0]


def dequantize_ref(q: jax.Array, scales: jax.Array, out_dtype=jnp.float32):
    return (q.astype(jnp.float32) * scales[:, None]).astype(out_dtype)
