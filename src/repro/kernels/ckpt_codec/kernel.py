"""Pallas TPU kernel: blockwise int8 quantization for fast checkpoints.

The paper attacks C/R thrashing cost with NVM; we additionally shrink the
bytes: optimizer moments (fp32) quantize to int8 with one fp32 scale per
128-lane block at <1e-2 relative error — 4x smaller fast-tier snapshots, so
preemption costs 4x less write bandwidth.  The kernel is a pure streaming
(memory-bound) op: each grid step loads a [rows, 128] tile from HBM,
computes the per-row absmax scale in VMEM and stores int8 + scales.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANE = 128


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)                    # [rows, LANE]
    absmax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q_ref[...] = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    s_ref[...] = scale[:, 0]


def _dequant_kernel(q_ref, s_ref, x_ref):
    q = q_ref[...].astype(jnp.float32)
    x_ref[...] = (q * s_ref[...][:, None]).astype(x_ref.dtype)


def quantize_blocks(x: jax.Array, *, rows_per_step: int = 1024,
                    interpret: bool = False):
    """x: [R, 128] fp32 -> (int8 [R, 128], scales fp32 [R])."""
    r, lane = x.shape
    assert lane == LANE
    rows = min(rows_per_step, r)
    grid = (pl.cdiv(r, rows),)
    return pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rows, LANE), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((rows,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, LANE), jnp.int8),
            jax.ShapeDtypeStruct((r,), jnp.float32),
        ],
        interpret=interpret,
    )(x)


def dequantize_blocks(q: jax.Array, scales: jax.Array, *,
                      rows_per_step: int = 1024, out_dtype=jnp.float32,
                      interpret: bool = False):
    r, lane = q.shape
    rows = min(rows_per_step, r)
    return pl.pallas_call(
        _dequant_kernel,
        grid=(pl.cdiv(r, rows),),
        in_specs=[
            pl.BlockSpec((rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((rows,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((rows, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, LANE), out_dtype),
        interpret=interpret,
    )(q, scales)
