"""Pure-jnp oracle for the grouped expert matmul."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def grouped_matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """x [E, C, d], w [E, d, f] -> [E, C, f] (fp32 accumulation)."""
    return jnp.einsum(
        "ecd,edf->ecf", x, w, preferred_element_type=jnp.float32
    ).astype(x.dtype)
