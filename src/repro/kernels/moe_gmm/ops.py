"""Jit'd wrapper: expert-capacity SwiGLU using the grouped-matmul kernel.

Used by distributed.moe_ep on TPU in place of ragged_dot when the capacity
layout is dense (kernel path); interpret-mode on CPU for validation."""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.moe_gmm.kernel import grouped_matmul
from repro.kernels.moe_gmm.ref import grouped_matmul_ref


@partial(jax.jit, static_argnames=("interpret",))
def expert_swiglu(
    x: jax.Array,        # [E, C, d] capacity buffers
    w_gate: jax.Array,   # [E, d, f]
    w_up: jax.Array,     # [E, d, f]
    w_down: jax.Array,   # [E, f, d]
    *,
    interpret: Optional[bool] = None,
) -> jax.Array:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    gate = grouped_matmul(x, w_gate.astype(x.dtype), interpret=interpret)
    up = grouped_matmul(x, w_up.astype(x.dtype), interpret=interpret)
    h = jax.nn.silu(gate) * up
    return grouped_matmul(h, w_down.astype(x.dtype), interpret=interpret)


def expert_swiglu_ref(x, w_gate, w_up, w_down):
    gate = grouped_matmul_ref(x, w_gate.astype(x.dtype))
    up = grouped_matmul_ref(x, w_up.astype(x.dtype))
    return grouped_matmul_ref(jax.nn.silu(gate) * up, w_down.astype(x.dtype))
