"""Pallas TPU kernel: grouped expert matmul over capacity buffers.

TPU adaptation of the fine-grained-MoE hotspot: after the EP dispatch
(`distributed.moe_ep`) tokens live in a dense [E_local, C, d] capacity
buffer, so the expert FFN is a *batched* matmul with MXU-aligned tiles —
no dynamic group boundaries inside the kernel (those were resolved by the
sort/compaction on dispatch).  Grid = (E, C/bc, f/bf, d/bd) with the
contraction dim innermost and an fp32 VMEM accumulator.

Default tiles (bc, bd, bf) = (128, 512, 512): working set
x(128x512) + w(512x512) + acc(128x512) fp32 ~= 1.6 MiB << 16 MiB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams


def _gmm_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k_blocks: int):
    kk = pl.program_id(3)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(kk == n_k_blocks - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def grouped_matmul(
    x: jax.Array,       # [E, C, d]  capacity buffers
    w: jax.Array,       # [E, d, f]  per-expert weights
    *,
    block_c: int = 128,
    block_d: int = 512,
    block_f: int = 512,
    interpret: bool = False,
) -> jax.Array:
    e, c, d = x.shape
    _, _, f = w.shape
    bc, bd, bf = min(block_c, c), min(block_d, d), min(block_f, f)
    grid = (e, pl.cdiv(c, bc), pl.cdiv(f, bf), pl.cdiv(d, bd))
    kernel = functools.partial(_gmm_kernel, n_k_blocks=grid[3])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, bc, bd), lambda e_, i, j, kk: (e_, i, kk)),
            pl.BlockSpec((None, bd, bf), lambda e_, i, j, kk: (e_, kk, j)),
        ],
        out_specs=pl.BlockSpec((None, bc, bf), lambda e_, i, j, kk: (e_, i, j)),
        out_shape=jax.ShapeDtypeStruct((e, c, f), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, w)
