"""Pure-jnp oracle: sequential stabilized mLSTM recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mlstm_scan_ref(q, k, v, lf, li):
    """q/k/v: [BH, S, dh] (k pre-scaled); lf/li: [BH, S].
    Zero initial state.  Returns (h [BH,S,dh], (C, n, m))."""
    bh, s, dh = q.shape
    c0 = jnp.zeros((bh, dh, dh), jnp.float32)
    n0 = jnp.zeros((bh, dh), jnp.float32)
    m0 = jnp.full((bh,), -1e30, jnp.float32)

    def step(carry, inp):
        c, n, m = carry
        qt, kt, vt, lft, lit = [a.astype(jnp.float32) for a in inp]
        m_new = jnp.maximum(lft + m, lit)
        i_g = jnp.exp(lit - m_new)[:, None, None]
        f_g = jnp.exp(lft + m - m_new)[:, None, None]
        c = f_g * c + i_g * vt[:, :, None] * kt[:, None, :]
        n = f_g[:, :, 0] * n + i_g[:, :, 0] * kt
        num = jnp.einsum("bde,be->bd", c, qt)
        qn = jnp.einsum("bd,bd->b", n, qt)
        denom = jnp.maximum(jnp.abs(qn), jnp.exp(-m_new))
        h = num / denom[:, None]
        return (c, n, m_new), h

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (q, k, v, lf, li))
    (c, n, m), hs = jax.lax.scan(step, (c0, n0, m0), xs)
    return jnp.moveaxis(hs, 0, 1).astype(q.dtype), (c, n, m[:, None])
