"""Pallas TPU kernel: chunked-parallel mLSTM (xLSTM matrix memory).

Same schedule as `models.xlstm.mlstm_forward`: within a chunk the output is
an attention-like pair of [L, L] / [L, dh] matmuls weighted by stabilized
exponential gates; across chunks the [dh, dh] matrix state, the [dh]
normalizer and the scalar max-stabilizer are carried in VMEM scratch (the
chunk grid axis is sequential).

TPU-specific choices: the in-chunk cumulative sums/maxes are computed with
a lower-triangular matmul (MXU) and a log2(L)-step doubling max (VPU) —
no 1D sequential scans in the kernel body.

Grid: (batch*heads, n_chunks).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

NEG_BIG = -1e30


def _cumsum_tri(x: jax.Array, tri: jax.Array) -> jax.Array:
    """Inclusive cumsum over axis 0 of [L] via lower-tri matmul (MXU)."""
    return jax.lax.dot_general(
        tri, x[:, None], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)[:, 0]


def _cummax_doubling(x: jax.Array, length: int) -> jax.Array:
    """Inclusive running max over a [L] vector via log2(L) shifted maxes."""
    off = 1
    while off < length:
        shifted = jnp.concatenate([jnp.full((off,), NEG_BIG, x.dtype), x[:-off]])
        x = jnp.maximum(x, shifted)
        off *= 2
    return x


def _mlstm_kernel(
    q_ref, k_ref, v_ref,      # [chunk, dh]
    lf_ref, li_ref,           # [chunk]  log-forget / input-gate preacts
    h_out_ref,                # [chunk, dh]
    c_out_ref, n_out_ref, m_out_ref,   # final state outputs
    c_ref, n_ref, m_ref,      # scratch: [dh, dh], [dh], [1]
    *,
    chunk: int,
    seq_len: int,
    n_chunks: int,
):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)
        n_ref[...] = jnp.zeros_like(n_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_BIG)

    pos_valid = ci * chunk + jax.lax.iota(jnp.int32, chunk) < seq_len
    lf = jnp.where(pos_valid, lf_ref[...].astype(jnp.float32), 0.0)
    li = jnp.where(pos_valid, li_ref[...].astype(jnp.float32), NEG_BIG)
    q = q_ref[...].astype(jnp.float32)
    k = jnp.where(pos_valid[:, None], k_ref[...].astype(jnp.float32), 0.0)
    v = jnp.where(pos_valid[:, None], v_ref[...].astype(jnp.float32), 0.0)

    tri = (
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
        >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    ).astype(jnp.float32)

    m0 = m_ref[0]
    c0 = c_ref[...]
    n0 = n_ref[...]

    b = _cumsum_tri(lf, tri)                               # [L]
    g = jnp.maximum(m0, _cummax_doubling(li - b, chunk))   # [L]
    m_i = b + g
    # intra weights D[i,t] = exp(li_t - b_t - g_i), t <= i
    lt = (li - b)[None, :] - g[:, None]
    d_w = jnp.where(tri > 0, jnp.exp(lt), 0.0)
    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    w_it = scores * d_w
    inter = jnp.exp(m0 - g)                                # [L]
    h_num = (
        jax.lax.dot_general(w_it, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
        + jax.lax.dot_general(q, c0, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
        * inter[:, None]
    )
    # normalizer uses the decay weights only (no q.k scores)
    n_i = (
        jax.lax.dot_general(d_w, k, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
        + n0[None, :] * inter[:, None]
    )
    qn = jnp.sum(q * n_i, axis=1)
    denom = jnp.maximum(jnp.abs(qn), jnp.exp(-m_i))
    h_out_ref[...] = (h_num / denom[:, None]).astype(h_out_ref.dtype)

    # carry
    g_l = g[chunk - 1]
    m_new = m_i[chunk - 1]
    wc = jnp.exp(li - b - g_l)                             # [L]
    c_new = c0 * jnp.exp(m0 - g_l) + jax.lax.dot_general(
        v * wc[:, None], k, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                # [dh(v), dh(k)]
    n_new = n0 * jnp.exp(m0 - g_l) + jnp.sum(k * wc[:, None], axis=0)
    c_ref[...] = c_new
    n_ref[...] = n_new
    m_ref[0] = m_new

    @pl.when(ci == n_chunks - 1)
    def _final():
        c_out_ref[...] = c_ref[...]
        n_out_ref[...] = n_ref[...]
        m_out_ref[...] = m_ref[...]


def mlstm_scan(
    q: jax.Array,     # [BH, S, dh]   (k pre-scaled by 1/sqrt(dh))
    k: jax.Array,
    v: jax.Array,
    lf: jax.Array,    # [BH, S] logsigmoid(f-preact)
    li: jax.Array,    # [BH, S] input-gate preact
    *,
    chunk: int = 256,
    interpret: bool = False,
):
    """Zero initial state (the wrapper streams states via carry chunks).

    Returns (h [BH, S, dh], (C [BH, dh, dh], n [BH, dh], m [BH, 1]))."""
    bh, s, dh = q.shape
    chunk = min(chunk, s)
    n_chunks = pl.cdiv(s, chunk)
    kernel = functools.partial(
        _mlstm_kernel, chunk=chunk, seq_len=s, n_chunks=n_chunks)
    h, c, n, m = pl.pallas_call(
        kernel,
        grid=(bh, n_chunks),
        in_specs=[
            pl.BlockSpec((None, chunk, dh), lambda b, cc: (b, cc, 0)),
            pl.BlockSpec((None, chunk, dh), lambda b, cc: (b, cc, 0)),
            pl.BlockSpec((None, chunk, dh), lambda b, cc: (b, cc, 0)),
            pl.BlockSpec((None, chunk), lambda b, cc: (b, cc)),
            pl.BlockSpec((None, chunk), lambda b, cc: (b, cc)),
        ],
        out_specs=[
            pl.BlockSpec((None, chunk, dh), lambda b, cc: (b, cc, 0)),
            pl.BlockSpec((None, dh, dh), lambda b, cc: (b, 0, 0)),
            pl.BlockSpec((None, dh), lambda b, cc: (b, 0)),
            pl.BlockSpec((None, 1), lambda b, cc: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, dh), q.dtype),
            jax.ShapeDtypeStruct((bh, dh, dh), jnp.float32),
            jax.ShapeDtypeStruct((bh, dh), jnp.float32),
            jax.ShapeDtypeStruct((bh, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((dh, dh), jnp.float32),
            pltpu.VMEM((dh,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v, lf, li)
    return h, (c, n, m)
