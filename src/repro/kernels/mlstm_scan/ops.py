"""Jit'd wrapper for the chunked mLSTM kernel (interpret on CPU)."""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax

from repro.kernels.mlstm_scan.kernel import mlstm_scan
from repro.kernels.mlstm_scan.ref import mlstm_scan_ref


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def mlstm_chunked(q, k, v, lf, li, *, chunk: int = 256,
                  interpret: Optional[bool] = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return mlstm_scan(q, k, v, lf, li, chunk=chunk, interpret=interpret)


mlstm_reference = mlstm_scan_ref
