"""AdamW with sharded state, built directly in JAX (no optax dependency).

State layout mirrors the parameter pytree (so the same PartitionSpecs apply
to m/v), plus a scalar step.  Update math in fp32 regardless of param dtype.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array   # [] int32
    m: Any            # pytree like params, fp32
    v: Any            # pytree like params, fp32


def init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def update(
    params,
    grads,
    state: AdamWState,
    *,
    lr: jax.Array,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
) -> Tuple[Any, AdamWState]:
    """One AdamW step; returns (new_params, new_state)."""
    step = state.step + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m2 / c1
        vhat = v2 / c2
        pf = p.astype(jnp.float32)
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * pf
        return (pf - lr * delta).astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)
    ))


def clip_by_global_norm(grads, max_norm: float) -> Tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


# -- schedules -----------------------------------------------------------------


def cosine_schedule(base_lr: float, warmup: int, total: int) -> Callable[[jax.Array], jax.Array]:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * jnp.minimum(step / max(warmup, 1), 1.0)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * base_lr * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)

    return lr
