"""Error-feedback int8 gradient compression (beyond-paper, flag-gated).

For bandwidth-bound DP meshes the gradient all-reduce dominates the
collective term; block-int8 with error feedback cuts those bytes 4x while
keeping convergence (the residual re-enters the next step's gradient, so
the compression error is O(lr^2) in the trajectory — standard EF-SGD
argument).

Composition with the sharded train step: ``compress_tree`` runs *before*
the optimizer (the psum'd gradients are quantized + dequantized with the
per-job residual carried in the optimizer extras).  On a real fleet the
quantized payload is what crosses the ICI; in the single-controller dry-run
the collective-term saving is modeled in EXPERIMENTS.md SSPerf.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: Any   # pytree like grads, fp32


def init_ef(params) -> EFState:
    return EFState(residual=jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


def _quantize_block(x: jax.Array, block: int = 256) -> Tuple[jax.Array, jax.Array]:
    flat = x.reshape(-1)
    n = flat.shape[0]
    rows = -(-n // block)
    padded = jnp.pad(flat, (0, rows * block - n)).reshape(rows, block)
    scale = jnp.maximum(jnp.abs(padded).max(axis=1, keepdims=True), 1e-12) / 127.0
    q = jnp.clip(jnp.round(padded / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_block(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def compress_tree(grads, ef: EFState) -> Tuple[Any, EFState, dict]:
    """Quantize grads+residual to int8 blocks; return (dequantized grads,
    new residual, stats).  The dequantized value is exactly what every
    worker would reconstruct after the compressed all-reduce."""
    def one(g, r):
        x = g.astype(jnp.float32) + r
        if x.size < 256:
            return x, jnp.zeros_like(x)
        q, scale = _quantize_block(x)
        deq = _dequantize_block(q, scale, x.shape)
        return deq, x - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(ef.residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    new_g = treedef.unflatten([o[0] for o in out])
    new_r = treedef.unflatten([o[1] for o in out])
    bytes_raw = sum(g.size * 4 for g in flat_g)
    bytes_q = sum(g.size * 1 + -(-g.size // 256) * 4 if g.size >= 256 else g.size * 4
                  for g in flat_g)
    stats = {"compress_ratio": bytes_q / max(bytes_raw, 1)}
    return new_g, EFState(residual=new_r), stats
