"""OMFS driving *real* JAX training jobs: the paper's mechanism end-to-end.

``ClusterExecutor`` is a thin adapter over ``core.engine.tick_python`` —
the same tick kernel the simulator uses — but with real work: every RUNNING
job advances ``steps_per_tick`` real optimizer steps on the local device
pool (the engine's ``work_fn`` hook); any registered policy decides
admission/eviction; the engine's transition report drives the C/R hooks:
eviction of a checkpointable job triggers a **fast-tier checkpoint**
(params, optimizer, RNG, data cursor) and a restart restores it
**transparently** — the user's train loop (`TrainJob`) contains zero
checkpoint logic of its own, which is the DMTCP property the paper builds
on.

The executor is cooperative and single-process (the container has one CPU
device); scheduler accounting still runs on the job's declared `cpus`, so
the schedule is exactly what a fleet would produce — tests assert both the
scheduling behaviour and the bitwise-equality of preempted vs. uninterrupted
loss curves.

C/R accounting closes the loop with the simulator's cost model
(`core.crcost`): with ``tick_seconds`` set, every real checkpoint/restore
is timed and charged to the job's ``overhead`` in whole ticks
(`CRCostModel.ticks_from_seconds`); the first real snapshot feeds its
measured ``state_bytes`` back into the descriptor; and ``calibrate()``
turns the fleet's measured `CheckpointService` traffic into a
`CRCostModel` for what-if simulation at fleet scale.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from pathlib import Path
from typing import Callable, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager, ManagerConfig
from repro.checkpoint.service import CheckpointService, CRStats
from repro.checkpoint.tiers import TierStats
from repro.core import engine
from repro.core.crcost import UNBOUNDED, CRCostModel, TieredCRCostModel
from repro.core.omfs import scheduler_pass
from repro.core.types import ClusterState, Job, JobState, SchedulerConfig, User
from repro.data.pipeline import DataConfig, SyntheticLM, shard_batch
from repro.models.model import Model, build_model
from repro.train.state import TrainState, init_train_state, train_state_shapes
from repro.train.steps import TrainConfig, make_train_step


class TrainJob:
    """A user training job — *unmodified* train loop; no checkpoint code."""

    def __init__(self, model: Model, tcfg: TrainConfig, data_cfg: DataConfig,
                 seed: int = 0):
        self.model = model
        self.tcfg = tcfg
        self.data = SyntheticLM(data_cfg)
        self.seed = seed
        self._step_fn = jax.jit(make_train_step(model, tcfg), donate_argnums=(0,))
        self.state: Optional[TrainState] = None
        self.losses: List[float] = []

    # -- the four hooks the adapter exposes to the cluster -------------------
    def cold_start(self) -> None:
        self.state = init_train_state(
            self.model.init(jax.random.PRNGKey(self.seed)), self.seed)

    def run_step(self) -> float:
        cursor = int(self.state.data_cursor)
        batch = shard_batch(self.data.batch_at(cursor))
        self.state, metrics = self._step_fn(self.state, batch)
        loss = float(metrics["loss"])
        self.losses.append(loss)
        return loss

    def snapshot_state(self) -> TrainState:
        return self.state

    def restore_state(self, state: TrainState) -> None:
        self.state = state

    def release(self) -> None:
        self.state = None


@dataclasses.dataclass
class ManagedJob:
    descriptor: Job               # the scheduler-visible job (cpus, class, ...)
    train_job: TrainJob
    # CheckpointManager or CheckpointService — same save/restore duck type;
    # the service additionally exposes stats() for calibration
    ckpt: CheckpointManager
    restores: int = 0
    checkpoints: int = 0
    measured_cr_ticks: int = 0    # wall-time-derived overhead actually charged

    def template(self):
        return train_state_shapes(self.train_job.model, self.train_job.seed)


class ClusterExecutor:
    def __init__(
        self,
        users: List[User],
        config: SchedulerConfig,
        *,
        steps_per_tick: int = 1,
        policy: Callable = scheduler_pass,
        tick_seconds: Optional[float] = None,
    ):
        """``tick_seconds`` turns on measured C/R accounting: each real
        checkpoint save / restore is timed and its wall time, converted to
        whole ticks through `CRCostModel.ticks_from_seconds`, is charged to
        the job's ``overhead`` — the executed-on-hardware analogue of the
        simulator's predicted `cr_cost` charge (use a zero `cfg.cr_cost`
        with it, or the job pays both the prediction and the measurement).
        ``None`` (default) keeps accounting purely predictive."""
        self.state = ClusterState(config=config, users={u.name: u for u in users})
        self.jobs: Dict[int, ManagedJob] = {}
        self.steps_per_tick = steps_per_tick
        self.policy = policy
        self.tick_seconds = tick_seconds
        self.events: List[str] = []
        # typed lifecycle log: the same per-tick diff schema the simulator
        # backends record (repro.obs), so executor runs feed the same
        # metrics registry / trace exporter as simulations
        from repro.obs.bus import EventBus
        self.bus = EventBus()

    def submit(self, mj: ManagedJob) -> None:
        d = mj.descriptor
        d.state = JobState.UNSUBMITTED
        self.state.jobs[d.id] = d
        self.jobs[d.id] = mj

    # -- one tick ---------------------------------------------------------------
    def tick(self) -> None:
        """One engine tick: real work rides the ``work_fn`` hook, C/R rides
        the transition report — the tick loop itself lives in core.engine."""
        st = self.state
        t = st.time

        def work_fn(d: Job) -> None:
            mj = self.jobs[d.id]
            for _ in range(self.steps_per_tick):
                mj.train_job.run_step()

        def on_complete(d: Job) -> None:
            self.events.append(f"t={t} job{d.id} DONE")
            self.jobs[d.id].train_job.release()

        self.bus.snapshot(st.jobs)
        _, transitions = engine.tick_python(
            st, self.policy, work_fn=work_fn, on_complete=on_complete)
        self.bus.record_tick(st.jobs, t)

        for d, was, now in transitions:
            mj = self.jobs[d.id]
            if was == JobState.RUNNING and now in (JobState.PENDING, JobState.KILLED):
                # evicted: transparent checkpoint if the class allows it
                if now == JobState.PENDING and mj.train_job.state is not None:
                    t0 = time.perf_counter()
                    mj.ckpt.save(int(mj.train_job.state.step),
                                 mj.train_job.snapshot_state())
                    self._charge_measured(mj, time.perf_counter() - t0)
                    mj.checkpoints += 1
                    # feed the real image size back into the descriptor so
                    # the scheduler's predictive cost model sees measured
                    # bytes from the first checkpoint on
                    measured = getattr(
                        getattr(mj.ckpt, "manager", mj.ckpt),
                        "last_save_bytes", 0)
                    if measured and d.state_bytes == 0:
                        d.state_bytes = measured
                    self.events.append(f"t={t} job{d.id} CHECKPOINTED+EVICTED")
                else:
                    self.events.append(f"t={t} job{d.id} KILLED")
                mj.train_job.release()
            elif was != JobState.RUNNING and now == JobState.RUNNING:
                # (re)started: restore transparently if a snapshot exists
                if mj.ckpt.latest_step() is not None:
                    # drain pending async durable writes untimed — they are
                    # save-side I/O, not part of the restore being charged
                    drain = getattr(mj.ckpt, "drain", None) or getattr(
                        getattr(mj.ckpt, "manager", None), "drain", None)
                    if drain is not None:
                        drain()
                    t0 = time.perf_counter()
                    state, name = mj.ckpt.restore(mj.template())
                    self._charge_measured(mj, time.perf_counter() - t0)
                    mj.train_job.restore_state(state)
                    mj.restores += 1
                    self.events.append(f"t={t} job{d.id} RESTORED {name}")
                elif mj.train_job.state is None:
                    mj.train_job.cold_start()
                    self.events.append(f"t={t} job{d.id} COLD START")
        st.time += 1

    def _charge_measured(self, mj: ManagedJob, seconds: float) -> None:
        """Measured C/R wall time -> work units on the job, via the model's
        unit conversion, so real and simulated accounting agree."""
        if self.tick_seconds is None:
            return
        ticks = CRCostModel.ticks_from_seconds(seconds, self.tick_seconds)
        mj.descriptor.overhead += ticks
        mj.measured_cr_ticks += ticks

    def run(self, horizon: int) -> None:
        for _ in range(horizon):
            self.tick()

    # -- measured-cost introspection -----------------------------------------
    def cr_stats(self) -> CRStats:
        """Aggregate measured C/R traffic over every managed job whose
        checkpoint backend is a `CheckpointService`."""
        agg = CRStats()
        for mj in self.jobs.values():
            if isinstance(mj.ckpt, CheckpointService):
                s = mj.ckpt.stats()
                agg.saves += s.saves
                agg.restores += s.restores
                agg.bytes_saved += s.bytes_saved
                agg.bytes_restored += s.bytes_restored
                agg.save_seconds += s.save_seconds
                agg.restore_seconds += s.restore_seconds
        return agg

    def calibrate(self, tick_seconds: Optional[float] = None, *,
                  tiers: Optional[Sequence[str]] = None, **kw):
        """A cost model from the fleet's measured save/restore traffic —
        run real jobs under the executor, calibrate, then drive what-if
        sweeps on the JAX backend with simulation and execution agreeing on
        the cost units.  The unified entry (the `CheckpointService` twin):
        ``tiers=None`` prices the service-level aggregate into a flat
        `CRCostModel`; ``tiers`` as tier names from ``tier_stats()``
        (fastest first, e.g. ``("mem", "disk")``) returns the
        `TieredCRCostModel` lattice, with the fast-tier capacity the
        smallest MemTier across managed jobs (conservative: the simulator
        never places more than the tightest real host holds)."""
        ts = tick_seconds if tick_seconds is not None else self.tick_seconds
        if not ts:
            raise ValueError("calibrate() needs tick_seconds")
        if tiers is None:
            return CRCostModel.from_stats(self.cr_stats(), tick_seconds=ts,
                                          **kw)
        caps = [mj.ckpt.manager.fast_capacity_mib
                for mj in self.jobs.values()
                if isinstance(mj.ckpt, CheckpointService)]
        if not caps:
            raise ValueError("no managed CheckpointService to calibrate from")
        stats = self.tier_stats()
        cap_of = {"mem": min(caps), "disk": UNBOUNDED}
        return TieredCRCostModel.from_stats(
            [stats[name] for name in tiers], tick_seconds=ts,
            capacity_mib=[cap_of.get(name, UNBOUNDED) for name in tiers],
            **kw)

    def tier_stats(self) -> Dict[str, TierStats]:
        """Fleet-wide per-tier traffic: every managed `CheckpointService`'s
        MemTier/DiskTier counters summed (the split ``calibrate(tiers=...)``
        prices the tiers from)."""
        agg = {"mem": TierStats(), "disk": TierStats()}
        for mj in self.jobs.values():
            if isinstance(mj.ckpt, CheckpointService):
                for key, st in mj.ckpt.tier_stats().items():
                    a = agg[key]
                    for f in dataclasses.fields(TierStats):
                        setattr(a, f.name,
                                getattr(a, f.name) + getattr(st, f.name))
        return agg

    def calibrate_tiered(self, tick_seconds: Optional[float] = None,
                         **kw) -> TieredCRCostModel:
        """Deprecated shim: use ``calibrate(tiers=("mem", "disk"))``."""
        warnings.warn(
            "ClusterExecutor.calibrate_tiered is deprecated; use "
            "calibrate(tiers=('mem', 'disk'))", DeprecationWarning,
            stacklevel=2)
        return self.calibrate(tick_seconds, tiers=("mem", "disk"), **kw)


def small_train_job(tmpdir: Path, *, arch_cfg, vocab=None, seq=64, batch=8,
                    lr=1e-3, seed=0) -> TrainJob:
    """Convenience: a small real TrainJob on the smoke config of an arch."""
    model = build_model(arch_cfg, q_chunk=32, kv_chunk=32)
    tcfg = TrainConfig(lr=lr, warmup_steps=10, total_steps=1000)
    dcfg = DataConfig(vocab=arch_cfg.vocab, seq_len=seq, global_batch=batch, seed=seed)
    return TrainJob(model, tcfg, dcfg, seed=seed)
