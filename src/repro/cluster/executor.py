"""OMFS driving *real* JAX training jobs: the paper's mechanism end-to-end.

``ClusterExecutor`` is a thin adapter over ``core.engine.tick_python`` —
the same tick kernel the simulator uses — but with real work: every RUNNING
job advances ``steps_per_tick`` real optimizer steps on the local device
pool (the engine's ``work_fn`` hook); any registered policy decides
admission/eviction; the engine's transition report drives the C/R hooks:
eviction of a checkpointable job triggers a **fast-tier checkpoint**
(params, optimizer, RNG, data cursor) and a restart restores it
**transparently** — the user's train loop (`TrainJob`) contains zero
checkpoint logic of its own, which is the DMTCP property the paper builds
on.

The executor is cooperative and single-process (the container has one CPU
device); scheduler accounting still runs on the job's declared `cpus`, so
the schedule is exactly what a fleet would produce — tests assert both the
scheduling behaviour and the bitwise-equality of preempted vs. uninterrupted
loss curves.
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager, ManagerConfig
from repro.core import engine
from repro.core.omfs import scheduler_pass
from repro.core.types import ClusterState, Job, JobState, SchedulerConfig, User
from repro.data.pipeline import DataConfig, SyntheticLM, shard_batch
from repro.models.model import Model, build_model
from repro.train.state import TrainState, init_train_state, train_state_shapes
from repro.train.steps import TrainConfig, make_train_step


class TrainJob:
    """A user training job — *unmodified* train loop; no checkpoint code."""

    def __init__(self, model: Model, tcfg: TrainConfig, data_cfg: DataConfig,
                 seed: int = 0):
        self.model = model
        self.tcfg = tcfg
        self.data = SyntheticLM(data_cfg)
        self.seed = seed
        self._step_fn = jax.jit(make_train_step(model, tcfg), donate_argnums=(0,))
        self.state: Optional[TrainState] = None
        self.losses: List[float] = []

    # -- the four hooks the adapter exposes to the cluster -------------------
    def cold_start(self) -> None:
        self.state = init_train_state(
            self.model.init(jax.random.PRNGKey(self.seed)), self.seed)

    def run_step(self) -> float:
        cursor = int(self.state.data_cursor)
        batch = shard_batch(self.data.batch_at(cursor))
        self.state, metrics = self._step_fn(self.state, batch)
        loss = float(metrics["loss"])
        self.losses.append(loss)
        return loss

    def snapshot_state(self) -> TrainState:
        return self.state

    def restore_state(self, state: TrainState) -> None:
        self.state = state

    def release(self) -> None:
        self.state = None


@dataclasses.dataclass
class ManagedJob:
    descriptor: Job               # the scheduler-visible job (cpus, class, ...)
    train_job: TrainJob
    ckpt: CheckpointManager
    restores: int = 0
    checkpoints: int = 0

    def template(self):
        return train_state_shapes(self.train_job.model, self.train_job.seed)


class ClusterExecutor:
    def __init__(
        self,
        users: List[User],
        config: SchedulerConfig,
        *,
        steps_per_tick: int = 1,
        policy: Callable = scheduler_pass,
    ):
        self.state = ClusterState(config=config, users={u.name: u for u in users})
        self.jobs: Dict[int, ManagedJob] = {}
        self.steps_per_tick = steps_per_tick
        self.policy = policy
        self.events: List[str] = []

    def submit(self, mj: ManagedJob) -> None:
        d = mj.descriptor
        d.state = JobState.UNSUBMITTED
        self.state.jobs[d.id] = d
        self.jobs[d.id] = mj

    # -- one tick ---------------------------------------------------------------
    def tick(self) -> None:
        """One engine tick: real work rides the ``work_fn`` hook, C/R rides
        the transition report — the tick loop itself lives in core.engine."""
        st = self.state
        t = st.time

        def work_fn(d: Job) -> None:
            mj = self.jobs[d.id]
            for _ in range(self.steps_per_tick):
                mj.train_job.run_step()

        def on_complete(d: Job) -> None:
            self.events.append(f"t={t} job{d.id} DONE")
            self.jobs[d.id].train_job.release()

        _, transitions = engine.tick_python(
            st, self.policy, work_fn=work_fn, on_complete=on_complete)

        for d, was, now in transitions:
            mj = self.jobs[d.id]
            if was == JobState.RUNNING and now in (JobState.PENDING, JobState.KILLED):
                # evicted: transparent checkpoint if the class allows it
                if now == JobState.PENDING and mj.train_job.state is not None:
                    mj.ckpt.save(int(mj.train_job.state.step), mj.train_job.snapshot_state())
                    mj.checkpoints += 1
                    self.events.append(f"t={t} job{d.id} CHECKPOINTED+EVICTED")
                else:
                    self.events.append(f"t={t} job{d.id} KILLED")
                mj.train_job.release()
            elif was != JobState.RUNNING and now == JobState.RUNNING:
                # (re)started: restore transparently if a snapshot exists
                if mj.ckpt.latest_step() is not None:
                    state, name = mj.ckpt.restore(mj.template())
                    mj.train_job.restore_state(state)
                    mj.restores += 1
                    self.events.append(f"t={t} job{d.id} RESTORED {name}")
                elif mj.train_job.state is None:
                    mj.train_job.cold_start()
                    self.events.append(f"t={t} job{d.id} COLD START")
        st.time += 1

    def run(self, horizon: int) -> None:
        for _ in range(horizon):
            self.tick()


def small_train_job(tmpdir: Path, *, arch_cfg, vocab=None, seq=64, batch=8,
                    lr=1e-3, seed=0) -> TrainJob:
    """Convenience: a small real TrainJob on the smoke config of an arch."""
    model = build_model(arch_cfg, q_chunk=32, kv_chunk=32)
    tcfg = TrainConfig(lr=lr, warmup_steps=10, total_steps=1000)
    dcfg = DataConfig(vocab=arch_cfg.vocab, seq_len=seq, global_batch=batch, seed=seed)
    return TrainJob(model, tcfg, dcfg, seed=seed)
