"""Roofline-term extraction from a compiled dry-run artifact.

Three terms, all in seconds-per-step on TPU v5e, computed per device:

  compute    = HLO_FLOPs_per_device / PEAK_FLOPS
  memory     = HLO_bytes_per_device / HBM_BW
  collective = collective_bytes_per_device / (links * ICI_BW)

``compiled.cost_analysis()`` (verified to report per-device, post-SPMD
numbers) supplies FLOPs and bytes.  Collective bytes are parsed from the
post-SPMD HLO text: we sum the result-shape bytes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute, count
async ``-start`` ops once, and weight all-reduce 2x (ring RS+AG).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

from repro.launch.mesh import (
    HBM_BW,
    ICI_BW_PER_LINK,
    ICI_LINKS_2D,
    PEAK_FLOPS_BF16,
)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?P<type>[^=]*?)\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<suffix>-start|-done)?\(",
)
_SHAPE_RE = re.compile(r"(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")


def _bytes_of_type(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device bytes moved by each collective kind (result-shape sized)."""
    out: Dict[str, float] = {}
    for m in _COLL_RE.finditer(hlo_text):
        if m.group("suffix") == "-done":
            continue  # counted at -start
        op = m.group("op")
        nbytes = _bytes_of_type(m.group("type"))
        # ring all-reduce = reduce-scatter + all-gather over the same payload
        weight = 2.0 if op == "all-reduce" else 1.0
        out[op] = out.get(op, 0.0) + nbytes * weight
    return out


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    coll_breakdown: Dict[str, float]
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: Optional[float] = None      # 6*N*D (or 2*N*D for inference)
    model_flops_ratio: Optional[float] = None  # model_flops / (flops*chips)

    def row(self) -> dict:
        d = dataclasses.asdict(self)
        d.pop("coll_breakdown")
        return d


def analyze(
    compiled,
    hlo_text: Optional[str] = None,
    *,
    n_devices: int,
    model_flops: Optional[float] = None,
    links: int = ICI_LINKS_2D,
    cost_scale: float = 1.0,
) -> Roofline:
    """``cost_scale`` multiplies all three terms — used when the costing
    compile lowers one microbatch of a grad_accum=N step (terms x N)."""
    ca = compiled.cost_analysis()
    flops = float(ca.get("flops", 0.0)) * cost_scale
    nbytes = float(ca.get("bytes accessed", 0.0)) * cost_scale
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = {k: v * cost_scale for k, v in collective_bytes(text).items()}
    coll_total = float(sum(coll.values()))

    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = nbytes / HBM_BW
    collective_s = coll_total / (links * ICI_BW_PER_LINK)
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)

    ratio = None
    if model_flops is not None and flops > 0:
        ratio = model_flops / (flops * n_devices)

    return Roofline(
        flops_per_device=flops,
        bytes_per_device=nbytes,
        coll_bytes_per_device=coll_total,
        coll_breakdown=coll,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        bottleneck=bottleneck,
        model_flops=model_flops,
        model_flops_ratio=ratio,
    )


def memory_stats(compiled) -> dict:
    m = compiled.memory_analysis()
    return {
        "argument_bytes": int(m.argument_size_in_bytes),
        "output_bytes": int(m.output_size_in_bytes),
        "temp_bytes": int(m.temp_size_in_bytes),
        "alias_bytes": int(m.alias_size_in_bytes),
        "peak_estimate_bytes": int(
            m.argument_size_in_bytes + m.output_size_in_bytes
            + m.temp_size_in_bytes - m.alias_size_in_bytes
        ),
    }


def raw_costs(compiled) -> dict:
    """Raw per-device totals from one compiled artifact (pre-extrapolation)."""
    ca = compiled.cost_analysis()
    text = compiled.as_text()
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll": collective_bytes(text),
    }


def analyze_extrapolated(
    cost_a: dict,
    cost_b: dict,
    depth_a: int,
    depth_b: int,
    depth_full: int,
    *,
    n_devices: int,
    model_flops: Optional[float] = None,
    links: int = ICI_LINKS_2D,
    cost_scale: float = 1.0,
) -> Roofline:
    """Linear-in-depth extrapolation: cost(L) = base + L * per_layer.

    Valid because every per-layer cost (matmuls, attention, FSDP gathers,
    grad reduce-scatters) is depth-independent; the base captures embedding,
    CE loss, and optimizer scalars.  Negative per-layer deltas (numerical
    noise on tiny terms) are clamped to zero.
    """
    def extrap(va: float, vb: float) -> float:
        per_layer = max((vb - va) / (depth_b - depth_a), 0.0)
        base = max(va - per_layer * depth_a, 0.0)
        return base + per_layer * depth_full

    flops = extrap(cost_a["flops"], cost_b["flops"]) * cost_scale
    nbytes = extrap(cost_a["bytes"], cost_b["bytes"]) * cost_scale
    coll = {}
    for op in set(cost_a["coll"]) | set(cost_b["coll"]):
        coll[op] = extrap(cost_a["coll"].get(op, 0.0),
                          cost_b["coll"].get(op, 0.0)) * cost_scale
    coll_total = float(sum(coll.values()))

    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = nbytes / HBM_BW
    collective_s = coll_total / (links * ICI_BW_PER_LINK)
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    ratio = None
    if model_flops is not None and flops > 0:
        ratio = model_flops / (flops * n_devices)
    return Roofline(
        flops_per_device=flops, bytes_per_device=nbytes,
        coll_bytes_per_device=coll_total, coll_breakdown=coll,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        bottleneck=bottleneck, model_flops=model_flops, model_flops_ratio=ratio,
    )
