"""Fine-grained Mixture-of-Experts FFN (DeepSeek-MoE / DBRX style).

Routing path (baseline, pure pjit): top-k router -> flatten (token, slot)
pairs -> sort by expert -> ``jax.lax.ragged_dot`` grouped matmuls -> weighted
scatter-add back.  This never builds a [tokens, experts, capacity] one-hot
dispatch tensor, so it scales to the 1M-token train_4k cells.  The Pallas
``moe_gmm`` kernel is the TPU-target version of the grouped matmul; this is
its reference.  The hillclimbed EP path (shard_map + all_to_all) lives in
``repro.distributed.collectives``.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.layers import dense_init, swiglu, swiglu_params


def moe_params_spec(d_model: int, moe: MoEConfig, dtype) -> dict:
    spec = {
        "router": ((d_model, moe.n_routed), dense_init, jnp.float32),
        "w_gate": ((moe.n_routed, d_model, moe.d_expert), dense_init, dtype),
        "w_up": ((moe.n_routed, d_model, moe.d_expert), dense_init, dtype),
        "w_down": ((moe.n_routed, moe.d_expert, d_model), dense_init, dtype),
    }
    if moe.n_shared:
        d_sh = moe.d_shared or moe.d_expert * moe.n_shared
        spec["shared"] = swiglu_params(d_model, d_sh, dtype)
    return spec


def route_topk(router_logits: jax.Array, top_k: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Softmax-then-topk routing (DeepSeek-MoE).

    router_logits: [T, E] float32.
    Returns (weights [T, k] — renormalized, experts [T, k] int32, probs [T, E]).
    """
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    weights, experts = jax.lax.top_k(probs, top_k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    return weights, experts.astype(jnp.int32), probs


def load_balance_loss(probs: jax.Array, experts: jax.Array, n_experts: int) -> jax.Array:
    """Switch-style auxiliary loss: E * sum_e f_e * p_e.

    f_e = fraction of routed (token, slot) pairs sent to e, p_e = mean router
    probability of e.  Equals 1 at a perfectly uniform router.
    """
    t = probs.shape[0] * experts.shape[-1]
    counts = jnp.zeros((n_experts,), jnp.float32).at[experts.reshape(-1)].add(1.0)
    f = counts / t
    p = probs.mean(axis=0)
    return n_experts * jnp.sum(f * p)


def grouped_expert_ffn(
    xs: jax.Array,            # [T*k, d] tokens sorted by expert
    group_sizes: jax.Array,   # [E] int32
    w_gate: jax.Array,        # [E, d, f]
    w_up: jax.Array,
    w_down: jax.Array,        # [E, f, d]
) -> jax.Array:
    """SwiGLU over expert groups via ragged_dot -> [T*k, d]."""
    dt = xs.dtype
    gate = jax.lax.ragged_dot(xs, w_gate.astype(dt), group_sizes)
    up = jax.lax.ragged_dot(xs, w_up.astype(dt), group_sizes)
    h = jax.nn.silu(gate) * up
    return jax.lax.ragged_dot(h, w_down.astype(dt), group_sizes)


def moe_ffn(moe: MoEConfig, params: dict, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """MoE FFN over x [..., d].  Returns (y [..., d], aux_loss scalar)."""
    lead = x.shape[:-1]
    d = x.shape[-1]
    xf = x.reshape(-1, d)
    t = xf.shape[0]
    k = moe.top_k
    e = moe.n_routed

    logits = jnp.einsum(
        "td,de->te", xf.astype(jnp.float32), params["router"].astype(jnp.float32)
    )
    weights, experts, probs = route_topk(logits, k)
    aux = load_balance_loss(probs, experts, e) * moe.router_aux_coef

    # flatten (token, slot) pairs and sort by destination expert
    flat_exp = experts.reshape(-1)                      # [T*k]
    order = jnp.argsort(flat_exp)                       # [T*k]
    token_src = order // k                               # originating token
    xs = jnp.take(xf, token_src, axis=0)                 # [T*k, d]
    group_sizes = jnp.zeros((e,), jnp.int32).at[flat_exp].add(1)

    ys = grouped_expert_ffn(
        xs, group_sizes, params["w_gate"], params["w_up"], params["w_down"]
    )

    w_sorted = jnp.take(weights.reshape(-1), order)      # [T*k]
    y = jnp.zeros((t, d), jnp.float32)
    y = y.at[token_src].add(ys.astype(jnp.float32) * w_sorted[:, None])

    if moe.n_shared:
        y = y + swiglu(params["shared"], xf).astype(jnp.float32)

    return y.reshape(*lead, d).astype(x.dtype), aux
