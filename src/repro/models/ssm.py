"""Mamba-style selective SSM branch (Hymba hybrid blocks).

Training/prefill uses a memory-bounded *nested* scan: an outer
``jax.checkpoint``-ed scan over time chunks carrying the [B, d_inner,
d_state] state, an inner ``lax.scan`` over steps — the per-step
[B, d_inner, d_state] decay tensor is never materialized for the whole
sequence, so 4k-seq cells fit.  Decode is a single recurrence step with an
explicit (h, conv window) state — O(1) per token, which is what makes the
hymba long_500k cell runnable.  ``repro.kernels.ssm_scan`` is the TPU-target
chunked kernel; this module is its reference.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.models.layers import dense_init, zeros_init


def _a_log_init(key, shape, dtype):
    # S4D-real init: A = -[1..d_state] per channel (works for stacked [L, ...])
    d_state = shape[-1]
    a = jnp.broadcast_to(jnp.arange(1, d_state + 1, dtype=jnp.float32), shape)
    return jnp.log(a).astype(dtype)


def _dt_bias_init(key, shape, dtype):
    # bias so softplus(dt) starts in [1e-3, 1e-1] (mamba reference init)
    u = jax.random.uniform(key, shape, jnp.float32)
    dt = jnp.exp(u * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
    return (dt + jnp.log(-jnp.expm1(-dt))).astype(dtype)  # inverse softplus


def ssm_params_spec(d_model: int, ssm: SSMConfig, dtype) -> dict:
    d_inner = ssm.expand * d_model
    dt_rank = ssm.dt_rank or -(-d_model // 16)
    return {
        "w_in": ((d_model, 2 * d_inner), dense_init, dtype),
        "conv_w": ((ssm.d_conv, d_inner), dense_init, dtype),
        "conv_b": ((d_inner,), zeros_init, dtype),
        "w_xproj": ((d_inner, dt_rank + 2 * ssm.d_state), dense_init, dtype),
        "w_dt": ((dt_rank, d_inner), dense_init, dtype),
        "dt_bias": ((d_inner,), _dt_bias_init, jnp.float32),
        "a_log": ((d_inner, ssm.d_state), _a_log_init, jnp.float32),
        "d_skip": ((d_inner,), lambda k, s, d: jnp.ones(s, d), jnp.float32),
        "w_out": ((d_inner, d_model), dense_init, dtype),
    }


class SSMState(NamedTuple):
    h: jax.Array       # [B, d_inner, d_state] float32
    conv: jax.Array    # [B, d_conv - 1, d_inner] trailing conv window

    @staticmethod
    def init(batch: int, d_model: int, ssm: SSMConfig, dtype=jnp.float32):
        d_inner = ssm.expand * d_model
        return SSMState(
            h=jnp.zeros((batch, d_inner, ssm.d_state), jnp.float32),
            conv=jnp.zeros((batch, ssm.d_conv - 1, d_inner), dtype),
        )


def _causal_conv(x: jax.Array, conv_w: jax.Array, conv_b: jax.Array, prefix: jax.Array):
    """Depthwise causal conv over time.  x [B,T,C]; prefix [B,W-1,C]."""
    w = conv_w.shape[0]
    xp = jnp.concatenate([prefix.astype(x.dtype), x], axis=1)
    out = jnp.zeros_like(x)
    for i in range(w):
        out = out + xp[:, i : i + x.shape[1]] * conv_w[i].astype(x.dtype)
    return out + conv_b.astype(x.dtype), xp[:, -(w - 1) :] if w > 1 else prefix


def _dbc(ssm: SSMConfig, dt_rank: int, params, xc):
    """delta [.., d_inner] f32, B [.., d_state] f32, C [.., d_state] f32."""
    proj = jnp.einsum("...c,cr->...r", xc, params["w_xproj"].astype(xc.dtype))
    dt = proj[..., :dt_rank]
    b = proj[..., dt_rank : dt_rank + ssm.d_state].astype(jnp.float32)
    c = proj[..., dt_rank + ssm.d_state :].astype(jnp.float32)
    delta = jax.nn.softplus(
        jnp.einsum("...r,rc->...c", dt, params["w_dt"].astype(xc.dtype)).astype(jnp.float32)
        + params["dt_bias"]
    )
    return delta, b, c


def ssm_forward(
    ssm: SSMConfig,
    params: dict,
    x: jax.Array,                 # [B, T, d_model]
    state: SSMState,
    *,
    chunk: int = 128,
) -> Tuple[jax.Array, SSMState]:
    """Full-sequence selective scan.  Returns (y [B,T,d_model], final state)."""
    b_sz, t, d_model = x.shape
    d_inner = ssm.expand * d_model
    dt_rank = ssm.dt_rank or -(-d_model // 16)
    a = -jnp.exp(params["a_log"])                    # [d_inner, d_state] f32

    xz = jnp.einsum("btd,dc->btc", x, params["w_in"].astype(x.dtype))
    xi, z = jnp.split(xz, 2, axis=-1)
    xc, conv_tail = _causal_conv(xi, params["conv_w"], params["conv_b"], state.conv)
    xc = jax.nn.silu(xc)
    delta, bmat, cmat = _dbc(ssm, dt_rank, params, xc)   # [B,T,*]

    chunk = min(chunk, t)
    n_chunks = -(-t // chunk)
    pad = n_chunks * chunk - t

    def pad_t(arr):
        return jnp.pad(arr, [(0, 0), (0, pad)] + [(0, 0)] * (arr.ndim - 2)) if pad else arr

    xs = jax.tree.map(
        lambda v: pad_t(v).reshape(b_sz, n_chunks, chunk, *v.shape[2:]),
        (delta, bmat, cmat, xc.astype(jnp.float32)),
    )

    def step(h, inp):
        dl, bt, ct, xt = inp                       # [B,d_inner], [B,ds], [B,ds], [B,d_inner]
        decay = jnp.exp(dl[:, :, None] * a)        # [B, d_inner, d_state]
        h = decay * h + (dl * xt)[:, :, None] * bt[:, None, :]
        y = jnp.einsum("bcs,bs->bc", h, ct)
        return h, y

    @jax.checkpoint
    def chunk_body(h, inp):
        dl, bt, ct, xt = inp                       # [B, chunk, ...]
        h, ys = jax.lax.scan(step, h, (
            jnp.moveaxis(dl, 1, 0), jnp.moveaxis(bt, 1, 0),
            jnp.moveaxis(ct, 1, 0), jnp.moveaxis(xt, 1, 0),
        ))
        return h, jnp.moveaxis(ys, 0, 1)           # [B, chunk, d_inner]

    h_final, ys = jax.lax.scan(
        chunk_body, state.h, jax.tree.map(lambda v: jnp.moveaxis(v, 1, 0), xs)
    )
    y = jnp.moveaxis(ys, 0, 1).reshape(b_sz, n_chunks * chunk, d_inner)[:, :t]
    y = y + params["d_skip"] * xc.astype(jnp.float32)
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = jnp.einsum("btc,cd->btd", y, params["w_out"].astype(x.dtype))
    return out, SSMState(h=h_final, conv=conv_tail)


def ssm_decode_step(
    ssm: SSMConfig, params: dict, x: jax.Array, state: SSMState
) -> Tuple[jax.Array, SSMState]:
    """One-token recurrence.  x [B, 1, d_model] -> (y [B, 1, d_model], state)."""
    out, new_state = ssm_forward(ssm, params, x, state, chunk=1)
    return out, new_state
