"""Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3).

Train/prefill decompress the latent into per-head K/V and run the shared
chunked flash attention.  Decode uses the *absorbed* formulation: the cache
holds only the compressed latent ``c_kv`` [B, S, r_kv] plus the shared rotary
key [B, S, d_rope] — this is the memory-roofline win MLA exists for (cache
bytes/token: r_kv + d_rope instead of 2*H*d_head).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig
from repro.models.attention import NEG_INF, chunked_attention, visibility_mask
from repro.models.layers import apply_rope, dense_init, ones_init, rms_norm


def mla_params_spec(d_model: int, n_heads: int, mla: MLAConfig, dtype) -> dict:
    qk = mla.qk_nope_head_dim + mla.qk_rope_head_dim
    return {
        "w_dq": ((d_model, mla.q_lora_rank), dense_init, dtype),
        "q_norm": ((mla.q_lora_rank,), ones_init, jnp.float32),
        "w_uq": ((mla.q_lora_rank, n_heads * qk), dense_init, dtype),
        "w_dkv": ((d_model, mla.kv_lora_rank), dense_init, dtype),
        "kv_norm": ((mla.kv_lora_rank,), ones_init, jnp.float32),
        "w_uk": ((mla.kv_lora_rank, n_heads * mla.qk_nope_head_dim), dense_init, dtype),
        "w_uv": ((mla.kv_lora_rank, n_heads * mla.v_head_dim), dense_init, dtype),
        "w_kr": ((d_model, mla.qk_rope_head_dim), dense_init, dtype),
        "w_o": ((n_heads * mla.v_head_dim, d_model), dense_init, dtype),
    }


def _project_q(mla: MLAConfig, n_heads: int, params, x, positions, rope_theta):
    """-> q_nope [B,T,H,dn], q_rope [B,T,H,dr] (rope applied)."""
    b, t, _ = x.shape
    qk = mla.qk_nope_head_dim + mla.qk_rope_head_dim
    cq = jnp.einsum("btd,dr->btr", x, params["w_dq"].astype(x.dtype))
    cq = rms_norm(cq, params["q_norm"])
    q = jnp.einsum("btr,rh->bth", cq, params["w_uq"].astype(x.dtype))
    q = q.reshape(b, t, n_heads, qk)
    q_nope = q[..., : mla.qk_nope_head_dim]
    q_rope = apply_rope(q[..., mla.qk_nope_head_dim :], positions, rope_theta)
    return q_nope, q_rope


def mla_latents(mla: MLAConfig, params, x, positions, rope_theta):
    """Compressed latent + shared rotary key (what the decode cache stores)."""
    ckv = jnp.einsum("btd,dr->btr", x, params["w_dkv"].astype(x.dtype))
    ckv = rms_norm(ckv, params["kv_norm"])
    kr = jnp.einsum("btd,dr->btr", x, params["w_kr"].astype(x.dtype))
    kr = apply_rope(kr[:, :, None, :], positions, rope_theta)[:, :, 0, :]
    return ckv, kr


def mla_attention_full(
    mla: MLAConfig,
    n_heads: int,
    params: dict,
    x: jax.Array,             # [B, T, d]
    positions: jax.Array,     # [B, T]
    rope_theta: float,
    *,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Train/prefill path: decompress + flash attention.

    Returns (attn_out [B,T,d], (c_kv, k_rope) latents for the cache).
    """
    b, t, _ = x.shape
    h = n_heads
    q_nope, q_rope = _project_q(mla, h, params, x, positions, rope_theta)
    ckv, kr = mla_latents(mla, params, x, positions, rope_theta)

    k_nope = jnp.einsum("btr,rh->bth", ckv, params["w_uk"].astype(x.dtype))
    k_nope = k_nope.reshape(b, t, h, mla.qk_nope_head_dim)
    v = jnp.einsum("btr,rh->bth", ckv, params["w_uv"].astype(x.dtype))
    v = v.reshape(b, t, h, mla.v_head_dim)

    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(kr[:, :, None, :], q_rope.shape)], axis=-1)
    out = chunked_attention(
        q, k, v, positions, positions, causal=True, q_chunk=q_chunk, kv_chunk=kv_chunk
    )
    out = jnp.einsum(
        "btf,fd->btd", out.reshape(b, t, h * mla.v_head_dim), params["w_o"].astype(x.dtype)
    )
    return out, (ckv, kr)


def mla_attention_decode(
    mla: MLAConfig,
    n_heads: int,
    params: dict,
    x: jax.Array,             # [B, Tq, d] (Tq small)
    positions: jax.Array,     # [B, Tq]
    ckv_cache: jax.Array,     # [B, S, r_kv]  (includes current tokens)
    kr_cache: jax.Array,      # [B, S, d_rope]
    kv_pos: jax.Array,        # [B, S]
    rope_theta: float,
) -> jax.Array:
    """Absorbed decode: score and read directly in latent space."""
    b, tq, _ = x.shape
    h = n_heads
    dn, dr = mla.qk_nope_head_dim, mla.qk_rope_head_dim
    r = mla.kv_lora_rank
    q_nope, q_rope = _project_q(mla, h, params, x, positions, rope_theta)

    w_uk = params["w_uk"].astype(x.dtype).reshape(r, h, dn)
    # absorb W_uk into the query:  q_abs[b,t,h,r] = sum_n q_nope[b,t,h,n] W_uk[r,h,n]
    q_abs = jnp.einsum("bthn,rhn->bthr", q_nope, w_uk)

    scale = 1.0 / math.sqrt(dn + dr)
    s = (
        jnp.einsum("bthr,bsr->bhts", q_abs, ckv_cache, preferred_element_type=jnp.float32)
        + jnp.einsum("bthp,bsp->bhts", q_rope, kr_cache, preferred_element_type=jnp.float32)
    ) * scale
    vis = visibility_mask(positions, kv_pos, causal=True)
    s = jnp.where(vis[:, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # no preferred_element_type: bf16xbf16->f32 batched dots are unimplemented
    # in the XLA:CPU thunk runtime (TPU MXU accumulates in f32 regardless);
    # p is normalized so bf16 output is safe.
    o_latent = jnp.einsum(
        "bhts,bsr->bthr", p.astype(ckv_cache.dtype), ckv_cache
    ).astype(x.dtype)
    w_uv = params["w_uv"].astype(x.dtype).reshape(r, h, mla.v_head_dim)
    o = jnp.einsum("bthr,rhv->bthv", o_latent, w_uv)
    return jnp.einsum(
        "btf,fd->btd", o.reshape(b, tq, h * mla.v_head_dim), params["w_o"].astype(x.dtype)
    )
