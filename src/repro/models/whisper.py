"""Whisper-style encoder-decoder backbone.

The conv/mel frontend is a STUB per the assignment: ``input_specs`` provides
pre-computed frame embeddings [B, n_audio_ctx, d_model]; the encoder adds
sinusoidal positions and runs bidirectional self-attention.  The decoder is
causal self-attention + cross-attention to the encoder output, LayerNorm +
GELU MLP throughout (Whisper uses pre-LN transformers with biases on q/v/out
projections).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.attention import (
    cache_write,
    chunked_attention,
    decode_attention,
)
from repro.models.layers import (
    dense_init,
    gelu_mlp,
    gelu_mlp_params,
    layer_norm,
    ones_init,
    sinusoidal_positions,
    zeros_init,
)


def _attn_spec(cfg: ModelConfig, dtype) -> dict:
    hd = cfg.resolved_head_dim
    d = cfg.d_model
    return {
        "w_q": ((d, cfg.n_heads * hd), dense_init, dtype),
        "b_q": ((cfg.n_heads * hd,), zeros_init, dtype),
        "w_k": ((d, cfg.n_kv_heads * hd), dense_init, dtype),
        "w_v": ((d, cfg.n_kv_heads * hd), dense_init, dtype),
        "b_v": ((cfg.n_kv_heads * hd,), zeros_init, dtype),
        "w_o": ((cfg.n_heads * hd, d), dense_init, dtype),
        "b_o": ((d,), zeros_init, dtype),
    }


def _ln_spec(d: int) -> dict:
    return {"scale": ((d,), ones_init, jnp.float32), "bias": ((d,), zeros_init, jnp.float32)}


def enc_block_spec(cfg: ModelConfig, dtype) -> dict:
    return {
        "ln_attn": _ln_spec(cfg.d_model),
        "attn": _attn_spec(cfg, dtype),
        "ln_mlp": _ln_spec(cfg.d_model),
        "mlp": gelu_mlp_params(cfg.d_model, cfg.d_ff, dtype),
    }


def dec_block_spec(cfg: ModelConfig, dtype) -> dict:
    return {
        "ln_self": _ln_spec(cfg.d_model),
        "self": _attn_spec(cfg, dtype),
        "ln_cross": _ln_spec(cfg.d_model),
        "cross": _attn_spec(cfg, dtype),
        "ln_mlp": _ln_spec(cfg.d_model),
        "mlp": gelu_mlp_params(cfg.d_model, cfg.d_ff, dtype),
    }


def _project(cfg: ModelConfig, p: dict, xq: jax.Array, xkv: jax.Array):
    hd = cfg.resolved_head_dim
    bq, tq = xq.shape[:2]
    bk, tk = xkv.shape[:2]
    q = (jnp.einsum("btd,dh->bth", xq, p["w_q"].astype(xq.dtype)) + p["b_q"].astype(xq.dtype))
    k = jnp.einsum("btd,dh->bth", xkv, p["w_k"].astype(xq.dtype))
    v = (jnp.einsum("btd,dh->bth", xkv, p["w_v"].astype(xq.dtype)) + p["b_v"].astype(xq.dtype))
    return (
        q.reshape(bq, tq, cfg.n_heads, hd),
        k.reshape(bk, tk, cfg.n_kv_heads, hd),
        v.reshape(bk, tk, cfg.n_kv_heads, hd),
    )


def _out(cfg: ModelConfig, p: dict, o: jax.Array) -> jax.Array:
    b, t = o.shape[:2]
    flat = o.reshape(b, t, cfg.n_heads * cfg.resolved_head_dim)
    return jnp.einsum("btf,fd->btd", flat, p["w_o"].astype(o.dtype)) + p["b_o"].astype(o.dtype)


def encoder_forward(cfg: ModelConfig, enc_params: dict, frames: jax.Array,
                    *, remat: bool = True, unroll: bool = False) -> jax.Array:
    """frames: [B, n_audio_ctx, d_model] stub embeddings -> encoder states."""
    b, t, d = frames.shape
    x = frames + sinusoidal_positions(t, d).astype(frames.dtype)
    pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))

    def body(h, p_l):
        a = layer_norm(h, p_l["ln_attn"]["scale"], p_l["ln_attn"]["bias"], cfg.norm_eps)
        q, k, v = _project(cfg, p_l["attn"], a, a)
        o = chunked_attention(q, k, v, pos, pos, causal=False, q_chunk=512, kv_chunk=512)
        h = h + _out(cfg, p_l["attn"], o)
        m = layer_norm(h, p_l["ln_mlp"]["scale"], p_l["ln_mlp"]["bias"], cfg.norm_eps)
        return h + gelu_mlp(p_l["mlp"], m), None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, enc_params["blocks"],
                        unroll=cfg.audio.n_encoder_layers if unroll else 1)
    return layer_norm(x, enc_params["ln_f"]["scale"], enc_params["ln_f"]["bias"], cfg.norm_eps)


def decoder_forward(
    cfg: ModelConfig,
    dec_params: dict,             # {"blocks": [L,...], "ln_f": ...}
    x: jax.Array,                 # [B, T, d] token embeddings (+positions)
    positions: jax.Array,         # [B, T]
    enc_out: Optional[jax.Array],  # [B, Te, d] (train/prefill)
    *,
    mode: str,
    cache: Optional[dict] = None,  # {"k","v" [L,B,S,KV,hd], "xk","xv" [L,B,Te,KV,hd]}
    kv_pos: Optional[jax.Array] = None,
    cursor=None,
    remat: bool = True,
    unroll: bool = False,
) -> Tuple[jax.Array, Optional[dict]]:
    b = x.shape[0]

    def body(h, xs):
        p_l, cache_l = xs
        new_cache_l = {}
        s = layer_norm(h, p_l["ln_self"]["scale"], p_l["ln_self"]["bias"], cfg.norm_eps)
        q, k, v = _project(cfg, p_l["self"], s, s)
        if mode == "decode":
            ck, cv = cache_write(cache_l["k"], cache_l["v"], k, v, cursor)
            o = decode_attention(q, ck, cv, positions, kv_pos)
            new_cache_l.update({"k": ck, "v": cv})
        else:
            o = chunked_attention(q, k, v, positions, positions, causal=True,
                                  q_chunk=512, kv_chunk=512)
            if mode == "prefill":
                ck, cv = cache_write(cache_l["k"], cache_l["v"], k, v, cursor)
                new_cache_l.update({"k": ck, "v": cv})
        h = h + _out(cfg, p_l["self"], o)

        c = layer_norm(h, p_l["ln_cross"]["scale"], p_l["ln_cross"]["bias"], cfg.norm_eps)
        if mode == "decode":
            xk, xv = cache_l["xk"], cache_l["xv"]
            qc = _project(cfg, p_l["cross"], c, c)[0]
            te = xk.shape[1]
            o = decode_attention(
                qc, xk, xv, jnp.zeros((b, qc.shape[1]), jnp.int32),
                jnp.zeros((b, te), jnp.int32),
            )
            new_cache_l.update({"xk": xk, "xv": xv})
        else:
            qc, xk, xv = _project(cfg, p_l["cross"], c, enc_out)
            te = xk.shape[1]
            o = chunked_attention(
                qc, xk, xv, jnp.zeros((b, qc.shape[1]), jnp.int32),
                jnp.zeros((b, te), jnp.int32), causal=False, q_chunk=512, kv_chunk=512,
            )
            if mode == "prefill":
                new_cache_l.update({"xk": xk, "xv": xv})
        h = h + _out(cfg, p_l["cross"], o)

        m = layer_norm(h, p_l["ln_mlp"]["scale"], p_l["ln_mlp"]["bias"], cfg.norm_eps)
        h = h + gelu_mlp(p_l["mlp"], m)
        return h, (new_cache_l or None)

    if remat and mode == "train":
        body = jax.checkpoint(body)
    x, new_cache = jax.lax.scan(body, x, (dec_params["blocks"], cache),
                                unroll=cfg.n_layers if unroll else 1)
    x = layer_norm(x, dec_params["ln_f"]["scale"], dec_params["ln_f"]["bias"], cfg.norm_eps)
    return x, new_cache
