"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

TPU adaptation (see DESIGN.md):

* **mLSTM** trains in *chunked parallel* form — within a chunk the output is
  a decay-weighted attention-like matmul (MXU-friendly, [L, L] per chunk
  only), across chunks a [dh, dh] matrix state is carried.  This is exactly
  the schedule the Pallas ``mlstm_scan`` kernel implements; this module is
  its reference.  Exponential gating is max-stabilized (m-state) as in the
  xLSTM paper, eq. (15)-(19).
* **sLSTM** has a true sequential dependence (gates read h_{t-1}), so there
  is no parallel form; we run a nested checkpointed ``lax.scan``.  This is a
  property of the architecture, not the port (the paper's own CUDA kernel is
  sequential too).

Decode for both is a cheap O(1) recurrence — xlstm long_500k cells run as
state updates with no KV cache at all.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import XLSTMConfig
from repro.models.layers import dense_init, ones_init, rms_norm, zeros_init


def _fgate_bias_init(key, shape, dtype):
    # positive forget-gate bias (linspace 3..6 per head), xLSTM reference init
    return jnp.broadcast_to(jnp.linspace(3.0, 6.0, shape[-1]), shape).astype(dtype)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def mlstm_params_spec(d_model: int, n_heads: int, xl: XLSTMConfig, dtype) -> dict:
    di = int(xl.proj_factor_mlstm * d_model)
    return {
        "norm": ((d_model,), ones_init, jnp.float32),
        "w_up": ((d_model, 2 * di), dense_init, dtype),
        "conv_w": ((xl.conv_width, di), dense_init, dtype),
        "conv_b": ((di,), zeros_init, dtype),
        "w_q": ((di, di), dense_init, dtype),
        "w_k": ((di, di), dense_init, dtype),
        "w_v": ((di, di), dense_init, dtype),
        "w_i": ((di, n_heads), dense_init, jnp.float32),
        "b_i": ((n_heads,), zeros_init, jnp.float32),
        "w_f": ((di, n_heads), dense_init, jnp.float32),
        "b_f": ((n_heads,), _fgate_bias_init, jnp.float32),
        "gn": ((di,), ones_init, jnp.float32),
        "w_down": ((di, d_model), dense_init, dtype),
    }


class MLSTMState(NamedTuple):
    c: jax.Array      # [B, H, dh, dh] f32 matrix memory
    n: jax.Array      # [B, H, dh] f32 normalizer
    m: jax.Array      # [B, H] f32 max-stabilizer
    conv: jax.Array   # [B, W-1, di] conv window

    @staticmethod
    def init(batch, d_model, n_heads, xl: XLSTMConfig, dtype=jnp.float32):
        di = int(xl.proj_factor_mlstm * d_model)
        dh = di // n_heads
        return MLSTMState(
            c=jnp.zeros((batch, n_heads, dh, dh), jnp.float32),
            n=jnp.zeros((batch, n_heads, dh), jnp.float32),
            m=jnp.full((batch, n_heads), -1e30, jnp.float32),
            conv=jnp.zeros((batch, xl.conv_width - 1, di), dtype),
        )


def _conv1d(x, conv_w, conv_b, prefix):
    w = conv_w.shape[0]
    xp = jnp.concatenate([prefix.astype(x.dtype), x], axis=1)
    out = jnp.zeros_like(x)
    for i in range(w):
        out = out + xp[:, i : i + x.shape[1]] * conv_w[i].astype(x.dtype)
    return out + conv_b.astype(x.dtype), xp[:, -(w - 1) :]


def _mlstm_chunk(q, k, v, lf, li, state: Tuple[jax.Array, jax.Array, jax.Array]):
    """One chunk of the stabilized chunked-parallel mLSTM.

    q,k,v: [B, H, L, dh] (k pre-scaled by 1/sqrt(dh)); lf, li: [B, H, L]
    log-forget (logsigmoid) and input-gate preactivations.
    Returns (h [B,H,L,dh], new (c, n, m)).
    """
    c0, n0, m0 = state
    b = jnp.cumsum(lf, axis=-1)                       # [B,H,L] inclusive log decay
    # g_i = max(m0, cummax_{t<=i}(li_t - b_t)); m_i = b_i + g_i
    g = jnp.maximum(m0[..., None], jax.lax.cummax(li - b, axis=2))
    m_i = b + g
    # intra-chunk weights: D[i,t] = exp(li_t - b_t - g_i) for t <= i
    lt = (li - b)[..., None, :] - g[..., :, None]     # [B,H,L(i),L(t)]
    tri = jnp.tril(jnp.ones((q.shape[2], q.shape[2]), bool))
    d_w = jnp.where(tri, jnp.exp(lt), 0.0)
    scores = jnp.einsum("bhid,bhtd->bhit", q, k, preferred_element_type=jnp.float32)
    w_it = scores * d_w
    inter_scale = jnp.exp(m0[..., None] - g)          # [B,H,L]
    h_num = (
        jnp.einsum("bhit,bhtd->bhid", w_it, v.astype(jnp.float32))
        + jnp.einsum("bhie,bhde->bhid", q.astype(jnp.float32), c0) * inter_scale[..., None]
    )
    # normalizer uses the decay weights only (n_t = f n + i k has no q.k
    # scores in it; they enter once via the q.n contraction below)
    n_i = (
        jnp.einsum("bhit,bhtd->bhid", d_w, k.astype(jnp.float32))
        + n0[:, :, None, :] * inter_scale[..., None]
    )
    qn = jnp.einsum("bhid,bhid->bhi", q.astype(jnp.float32), n_i)
    denom = jnp.maximum(jnp.abs(qn), jnp.exp(-m_i))
    h = h_num / denom[..., None]
    # carry to next chunk.  The stored state is stabilized by m:
    #   C_L = e^{-m_L} (e^{b_L} Ĉ_0 + Σ_t e^{b_L - b_t + ĩ_t} v_t k_t^T),
    # and m_L = b_L + g_L, so both weights lose the e^{b_L} factor.
    g_l = g[..., -1]
    m_new = m_i[..., -1]
    wc = jnp.exp(li - b - g_l[..., None])             # [B,H,L]
    c_new = c0 * jnp.exp(m0 - g_l)[..., None, None] + jnp.einsum(
        "bhtd,bhte,bht->bhde", v.astype(jnp.float32), k.astype(jnp.float32), wc
    )
    n_new = n0 * jnp.exp(m0 - g_l)[..., None] + jnp.einsum(
        "bhtd,bht->bhd", k.astype(jnp.float32), wc
    )
    return h, (c_new, n_new, m_new)


def mlstm_forward(
    xl: XLSTMConfig,
    n_heads: int,
    params: dict,
    x: jax.Array,               # [B, T, d_model]
    state: MLSTMState,
    *,
    chunk: int = 256,
    unroll: bool = False,
) -> Tuple[jax.Array, MLSTMState]:
    b_sz, t, d_model = x.shape
    di = int(xl.proj_factor_mlstm * d_model)
    dh = di // n_heads
    xin = rms_norm(x, params["norm"])
    up = jnp.einsum("btd,dc->btc", xin, params["w_up"].astype(x.dtype))
    xi, z = jnp.split(up, 2, axis=-1)
    xc, conv_tail = _conv1d(xi, params["conv_w"], params["conv_b"], state.conv)
    xc = jax.nn.silu(xc)

    def heads(v):  # [B,T,di] -> [B,H,T,dh]
        return jnp.moveaxis(v.reshape(b_sz, -1, n_heads, dh), 2, 1)

    q = heads(jnp.einsum("btc,ce->bte", xc, params["w_q"].astype(x.dtype)))
    k = heads(jnp.einsum("btc,ce->bte", xc, params["w_k"].astype(x.dtype))) / math.sqrt(dh)
    v = heads(jnp.einsum("btc,ce->bte", xi, params["w_v"].astype(x.dtype)))
    li = jnp.moveaxis(
        jnp.einsum("btc,ch->bth", xc.astype(jnp.float32), params["w_i"]) + params["b_i"], 2, 1
    )
    lf = jax.nn.log_sigmoid(
        jnp.moveaxis(
            jnp.einsum("btc,ch->bth", xc.astype(jnp.float32), params["w_f"]) + params["b_f"], 2, 1
        )
    )

    chunk = min(chunk, t)
    n_chunks = -(-t // chunk)
    pad = n_chunks * chunk - t
    if pad:
        q, k, v = (jnp.pad(a, [(0, 0), (0, 0), (0, pad), (0, 0)]) for a in (q, k, v))
        li = jnp.pad(li, [(0, 0), (0, 0), (0, pad)], constant_values=-1e30)  # no write
        lf = jnp.pad(lf, [(0, 0), (0, 0), (0, pad)])

    def to_chunks(a):
        return jnp.moveaxis(
            a.reshape(b_sz, n_heads, n_chunks, chunk, *a.shape[3:]), 2, 0
        )

    @jax.checkpoint
    def body(carry, inp):
        qc, kc, vc, lfc, lic = inp
        h, new = _mlstm_chunk(qc, kc, vc, lfc, lic, carry)
        return new, h

    (c_f, n_f, m_f), hs = jax.lax.scan(
        body, (state.c, state.n, state.m),
        (to_chunks(q), to_chunks(k), to_chunks(v), to_chunks(lf), to_chunks(li)),
        unroll=n_chunks if unroll else 1,
    )
    h = jnp.moveaxis(hs, 0, 2).reshape(b_sz, n_heads, n_chunks * chunk, dh)[:, :, :t]
    h = jnp.moveaxis(h, 1, 2).reshape(b_sz, t, di)
    # per-head group norm, then gate and down-project
    h = rms_norm(h.reshape(b_sz, t, n_heads, dh), jnp.ones((dh,))).reshape(b_sz, t, di)
    h = h * params["gn"]
    h = h.astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("btc,cd->btd", h, params["w_down"].astype(x.dtype))
    return out, MLSTMState(c=c_f, n=n_f, m=m_f, conv=conv_tail)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_params_spec(d_model: int, n_heads: int, xl: XLSTMConfig, dtype) -> dict:
    dh = d_model // n_heads
    dff = int(xl.proj_factor_slstm * d_model)
    return {
        "norm": ((d_model,), ones_init, jnp.float32),
        "conv_w": ((xl.conv_width, d_model), dense_init, dtype),
        "conv_b": ((d_model,), zeros_init, dtype),
        "w_gates": ((d_model, 4 * d_model), dense_init, dtype),     # i,f,z,o
        "r_gates": ((n_heads, dh, 4 * dh), dense_init, dtype),      # block-diag recurrent
        "b_gates": ((4 * d_model,), _slstm_bias_init, jnp.float32),
        "gn": ((d_model,), ones_init, jnp.float32),
        "w_up": ((d_model, 2 * dff), dense_init, dtype),
        "w_down": ((dff, d_model), dense_init, dtype),
    }


def _slstm_bias_init(key, shape, dtype):
    d4 = shape[-1] // 4
    b = jnp.zeros((4, d4), jnp.float32)
    b = b.at[1].set(jnp.linspace(3.0, 6.0, d4))  # forget-gate bias positive
    return jnp.broadcast_to(b.reshape(-1), shape).astype(dtype)


class SLSTMState(NamedTuple):
    h: jax.Array      # [B, d]
    c: jax.Array      # [B, d]
    n: jax.Array      # [B, d]
    m: jax.Array      # [B, d]
    conv: jax.Array   # [B, W-1, d]

    @staticmethod
    def init(batch, d_model, xl: XLSTMConfig, dtype=jnp.float32):
        z = lambda: jnp.zeros((batch, d_model), jnp.float32)
        return SLSTMState(
            h=z(), c=z(), n=z(), m=jnp.full((batch, d_model), -1e30, jnp.float32),
            conv=jnp.zeros((batch, xl.conv_width - 1, d_model), dtype),
        )


def slstm_forward(
    xl: XLSTMConfig,
    n_heads: int,
    params: dict,
    x: jax.Array,               # [B, T, d_model]
    state: SLSTMState,
    *,
    chunk: int = 64,
    unroll: bool = False,
) -> Tuple[jax.Array, SLSTMState]:
    b_sz, t, d_model = x.shape
    dh = d_model // n_heads
    xin = rms_norm(x, params["norm"])
    xc, conv_tail = _conv1d(xin, params["conv_w"], params["conv_b"], state.conv)
    xc = jax.nn.silu(xc)
    # input contributions to the 4 gates: i,f from the conv path; z,o raw
    wx = jnp.einsum("btd,dg->btg", xc, params["w_gates"].astype(x.dtype)[:, : 2 * d_model])
    wzo = jnp.einsum("btd,dg->btg", xin, params["w_gates"].astype(x.dtype)[:, 2 * d_model :])
    gates_x = jnp.concatenate([wx, wzo], axis=-1).astype(jnp.float32)  # [B,T,4d]

    r = params["r_gates"].astype(jnp.float32)        # [H, dh, 4dh]
    bias = params["b_gates"]

    def step(carry, inp):
        gx, valid = inp
        h, c, n, m = carry
        hr = h.reshape(b_sz, n_heads, dh)
        rec = jnp.einsum("bhd,hdg->bhg", hr, r).reshape(b_sz, 4 * d_model)
        # both gx and rec are laid out [i | f | z | o] over units
        pre = gx + rec + bias
        pi, pf, pz, po = jnp.split(pre, 4, axis=-1)
        lf = jax.nn.log_sigmoid(pf)
        m_new = jnp.maximum(lf + m, pi)
        i_g = jnp.exp(pi - m_new)
        f_g = jnp.exp(lf + m - m_new)
        c_new = f_g * c + i_g * jnp.tanh(pz)
        n_new = f_g * n + i_g
        h_new = jax.nn.sigmoid(po) * c_new / jnp.maximum(n_new, 1e-6)
        # padded steps must not advance the state (streaming correctness)
        keep = lambda new, old: jnp.where(valid, new, old)
        return (keep(h_new, h), keep(c_new, c), keep(n_new, n), keep(m_new, m)), h_new

    chunk = min(chunk, t)
    n_chunks = -(-t // chunk)
    pad = n_chunks * chunk - t
    gx = jnp.pad(gates_x, [(0, 0), (0, pad), (0, 0)]) if pad else gates_x
    gx = jnp.moveaxis(gx.reshape(b_sz, n_chunks, chunk, -1), 1, 0)
    valid = (jnp.arange(n_chunks * chunk) < t).reshape(n_chunks, chunk)

    @jax.checkpoint
    def chunk_body(carry, inp):
        gchunk, vchunk = inp
        carry, hs = jax.lax.scan(step, carry, (jnp.moveaxis(gchunk, 1, 0), vchunk))
        return carry, jnp.moveaxis(hs, 0, 1)

    (h_f, c_f, n_f, m_f), hs = jax.lax.scan(
        chunk_body, (state.h, state.c, state.n, state.m), (gx, valid),
        unroll=n_chunks if unroll else 1,
    )
    h = jnp.moveaxis(hs, 0, 1).reshape(b_sz, n_chunks * chunk, d_model)[:, :t]
    h = rms_norm(h.reshape(b_sz, t, n_heads, dh), jnp.ones((dh,))).reshape(b_sz, t, d_model)
    h = (h * params["gn"]).astype(x.dtype)
    # gated up/down projection (proj_factor 4/3)
    up = jnp.einsum("btd,dc->btc", h, params["w_up"].astype(x.dtype))
    u, g = jnp.split(up, 2, axis=-1)
    out = jnp.einsum(
        "btc,cd->btd", u * jax.nn.gelu(g, approximate=True), params["w_down"].astype(x.dtype)
    )
    return out, SLSTMState(h=h_f, c=c_f, n=n_f, m=m_f, conv=conv_tail)

# ---------------------------------------------------------------------------
# Stack driver: alternating (mLSTM, sLSTM) residual block pairs
# ---------------------------------------------------------------------------


def xlstm_pair_count(n_layers: int, xl: XLSTMConfig) -> int:
    assert n_layers % xl.slstm_every == 0
    return n_layers // xl.slstm_every


class XLSTMStackState(NamedTuple):
    """Stacked states for the whole trunk ([P, ...] per pair)."""
    m: MLSTMState
    s: SLSTMState

    @staticmethod
    def init(n_pairs, batch, d_model, n_heads, xl: XLSTMConfig, dtype=jnp.float32):
        stack = lambda st: jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_pairs,) + a.shape).copy(), st)
        return XLSTMStackState(
            m=stack(MLSTMState.init(batch, d_model, n_heads, xl, dtype)),
            s=stack(SLSTMState.init(batch, d_model, xl, dtype)),
        )


def xlstm_stack_apply(
    xl: XLSTMConfig,
    n_heads: int,
    params: dict,                 # {"m_blocks": [P,...], "s_blocks": [P,...]}
    x: jax.Array,                 # [B, T, d]
    state: XLSTMStackState,
    *,
    chunk: int = 256,
    slstm_chunk: int = 64,
    remat: bool = True,
    unroll: bool = False,
) -> Tuple[jax.Array, XLSTMStackState]:
    n_pairs = jax.tree.leaves(params["m_blocks"])[0].shape[0]

    # costing builds (unroll=True) run sLSTM as ONE chunk: its strictly
    # sequential recurrence is <1% of the cell FLOPs (see EXPERIMENTS.md
    # costing caveats) and unrolling hundreds of chunk bodies makes the
    # XLA:CPU costing compile pathological (hours).
    s_chunk = 10**9 if unroll else slstm_chunk

    def body(h, xs):
        p_m, p_s, st_m, st_s = xs
        out_m, st_m2 = mlstm_forward(
            xl, n_heads, p_m, h, MLSTMState(*st_m), chunk=chunk, unroll=unroll)
        h = h + out_m
        out_s, st_s2 = slstm_forward(
            xl, n_heads, p_s, h, SLSTMState(*st_s), chunk=s_chunk, unroll=False)
        h = h + out_s
        return h, (tuple(st_m2), tuple(st_s2))

    if remat:
        body = jax.checkpoint(body)
    x, (new_m, new_s) = jax.lax.scan(
        body, x, (params["m_blocks"], params["s_blocks"], tuple(state.m), tuple(state.s)),
        unroll=n_pairs if unroll else 1,
    )
    return x, XLSTMStackState(m=MLSTMState(*new_m), s=SLSTMState(*new_s))
