"""Decoder stacks: dense / MoE / Hymba(hybrid) / VLM assembly.

All stacks scan over layers with stacked [L, ...] parameters — this keeps
the HLO size O(1) in depth (one partitioned layer body), which is what makes
40-layer × 512-device dry-run compiles tractable, and it is also the layout
the FSDP all-gather wants.  Train mode wraps the layer body in
``jax.checkpoint`` (layer-boundary remat).

Modes
-----
``train``   — full sequence, no cache, returns hidden states.
``prefill`` — full sequence, writes KV/state caches, returns hidden states.
``decode``  — T new tokens (usually 1) against caches.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.attention import (
    cache_pos_write,
    cache_write,
    cache_write_single,
    chunked_attention,
    decode_attention,
)
from repro.models.layers import (
    apply_rope,
    build_params,
    dense_init,
    embed_init,
    ones_init,
    rms_norm,
    swiglu,
    swiglu_params,
)

Cache = Dict[str, Any]


# ---------------------------------------------------------------------------
# GQA attention sub-layer
# ---------------------------------------------------------------------------


def gqa_params_spec(cfg: ModelConfig, dtype) -> dict:
    hd = cfg.resolved_head_dim
    return {
        "w_q": ((cfg.d_model, cfg.n_heads * hd), dense_init, dtype),
        "w_k": ((cfg.d_model, cfg.n_kv_heads * hd), dense_init, dtype),
        "w_v": ((cfg.d_model, cfg.n_kv_heads * hd), dense_init, dtype),
        "w_o": ((cfg.n_heads * hd, cfg.d_model), dense_init, dtype),
    }


def gqa_project_qkv(cfg: ModelConfig, p: dict, x: jax.Array, positions: jax.Array):
    from repro.distributed.collectives import constrain_heads

    b, t, _ = x.shape
    hd = cfg.resolved_head_dim
    q = jnp.einsum("btd,dh->bth", x, p["w_q"].astype(x.dtype)).reshape(b, t, cfg.n_heads, hd)
    k = jnp.einsum("btd,dh->bth", x, p["w_k"].astype(x.dtype)).reshape(b, t, cfg.n_kv_heads, hd)
    v = jnp.einsum("btd,dh->bth", x, p["w_v"].astype(x.dtype)).reshape(b, t, cfg.n_kv_heads, hd)
    # explicit constraints: without them GSPMD replicates the score tensors
    # when H doesn't divide the model axis (see collectives.constrain_heads)
    q = constrain_heads(apply_rope(q, positions, cfg.rope_theta))
    k = constrain_heads(apply_rope(k, positions, cfg.rope_theta))
    v = constrain_heads(v)
    return q, k, v


def gqa_attention(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,
    positions: jax.Array,
    *,
    mode: str,
    layer_cache: Optional[dict] = None,
    kv_pos: Optional[jax.Array] = None,
    cursor: Optional[jax.Array] = None,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
) -> Tuple[jax.Array, Optional[dict]]:
    """Self-attention sub-layer (pre-norm residual applied by caller).

    Returns (out [B,T,d], new_layer_cache {k, v} or None).
    """
    b, t, _ = x.shape
    q, k, v = gqa_project_qkv(cfg, p, x, positions)

    new_cache = None
    if mode == "train":
        out = chunked_attention(
            q, k, v, positions, positions,
            causal=True, window=cfg.sliding_window, n_meta=cfg.n_meta_tokens,
            q_chunk=q_chunk, kv_chunk=kv_chunk,
        )
    elif mode == "prefill":
        out = chunked_attention(
            q, k, v, positions, positions,
            causal=True, window=cfg.sliding_window, n_meta=cfg.n_meta_tokens,
            q_chunk=q_chunk, kv_chunk=kv_chunk,
        )
        ck, cv = cache_write(layer_cache["k"], layer_cache["v"], k, v, cursor,
                             n_pinned=cfg.n_meta_tokens)
        new_cache = {"k": ck, "v": cv}
    elif mode == "decode":
        mesh = None
        if cfg.decode_kv_shard and not cfg.sliding_window:
            from repro.distributed.collectives import usable_mesh

            mesh = usable_mesh()
            if mesh is not None and layer_cache["k"].shape[1] % mesh.shape["model"]:
                mesh = None
        if mesh is not None:
            from repro.distributed.collectives import sharded_kv_decode_attention

            out, ck, cv, _ = sharded_kv_decode_attention(
                q, layer_cache["k"], layer_cache["v"], k, v,
                positions, kv_pos, cursor, mesh)
        else:
            ck, cv = cache_write(layer_cache["k"], layer_cache["v"], k, v, cursor,
                                 n_pinned=cfg.n_meta_tokens)
            out = decode_attention(
                q, ck, cv, positions, kv_pos,
                window=cfg.sliding_window, n_meta=cfg.n_meta_tokens,
            )
        new_cache = {"k": ck, "v": cv}
    else:
        raise ValueError(mode)

    hd = cfg.resolved_head_dim
    out = jnp.einsum(
        "btf,fd->btd", out.reshape(b, t, cfg.n_heads * hd), p["w_o"].astype(x.dtype)
    )
    return out, new_cache


# ---------------------------------------------------------------------------
# Layer blocks
# ---------------------------------------------------------------------------


def block_params_spec(cfg: ModelConfig, dtype) -> dict:
    """Parameter spec for one decoder layer of the cfg's family."""
    spec: dict = {"norm_attn": ((cfg.d_model,), ones_init, jnp.float32),
                  "norm_ffn": ((cfg.d_model,), ones_init, jnp.float32)}
    if cfg.mla is not None:
        spec["attn"] = mla_mod.mla_params_spec(cfg.d_model, cfg.n_heads, cfg.mla, dtype)
    else:
        spec["attn"] = gqa_params_spec(cfg, dtype)
    if cfg.moe is not None:
        spec["ffn"] = moe_mod.moe_params_spec(cfg.d_model, cfg.moe, dtype)
    elif cfg.d_ff > 0:
        spec["ffn"] = swiglu_params(cfg.d_model, cfg.d_ff, dtype)
    if cfg.family == "hybrid" and cfg.ssm is not None:
        spec["ssm"] = ssm_mod.ssm_params_spec(cfg.d_model, cfg.ssm, dtype)
        spec["norm_attn_out"] = ((cfg.d_model,), ones_init, jnp.float32)
        spec["norm_ssm_out"] = ((cfg.d_model,), ones_init, jnp.float32)
    return spec


def _moe_dispatch(cfg: ModelConfig, ffn_params: dict, h: jax.Array):
    """Pick the MoE implementation for the ambient mesh.

    Under a multi-device mesh with a 'model' axis, use the explicit
    expert-parallel shard_map path (GSPMD cannot partition the reference
    sort+ragged_dot dispatch and falls back to full replication — measured
    366 GiB/device on dbrx-132b).  Single-device (tests, smoke configs):
    the pure-pjit reference."""
    from repro.distributed.collectives import usable_mesh

    mesh = usable_mesh()     # version-tolerant ambient-mesh probe
    if mesh is not None and cfg.moe.n_routed % mesh.shape["model"] == 0:
        from repro.distributed.moe_ep import moe_ffn_ep

        return moe_ffn_ep(cfg.moe, ffn_params, h, mesh)
    return moe_mod.moe_ffn(cfg.moe, ffn_params, h)


def decoder_block(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,
    positions: jax.Array,
    *,
    mode: str,
    layer_cache: Optional[dict] = None,
    kv_pos: Optional[jax.Array] = None,
    cursor: Optional[jax.Array] = None,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
) -> Tuple[jax.Array, Optional[dict], jax.Array]:
    """One decoder layer.  Returns (x, new_layer_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, p["norm_attn"], cfg.norm_eps)
    new_cache: dict = {}

    # ---- sequence mixing ----
    if cfg.mla is not None:
        if mode == "decode":
            ckv_new, kr_new = mla_mod.mla_latents(cfg.mla, p["attn"], h, positions, cfg.rope_theta)
            ckv = cache_write_single(layer_cache["ckv"], ckv_new, cursor)
            kr = cache_write_single(layer_cache["kr"], kr_new, cursor)
            attn_out = mla_mod.mla_attention_decode(
                cfg.mla, cfg.n_heads, p["attn"], h, positions, ckv, kr, kv_pos, cfg.rope_theta
            )
            new_cache = {"ckv": ckv, "kr": kr}
        else:
            attn_out, (ckv_new, kr_new) = mla_mod.mla_attention_full(
                cfg.mla, cfg.n_heads, p["attn"], h, positions, cfg.rope_theta,
                q_chunk=q_chunk, kv_chunk=kv_chunk,
            )
            if mode == "prefill":
                new_cache = {
                    "ckv": cache_write_single(layer_cache["ckv"], ckv_new, cursor),
                    "kr": cache_write_single(layer_cache["kr"], kr_new, cursor),
                }
    else:
        attn_out, kv_cache = gqa_attention(
            cfg, p["attn"], h, positions, mode=mode,
            layer_cache=layer_cache, kv_pos=kv_pos, cursor=cursor,
            q_chunk=q_chunk, kv_chunk=kv_chunk,
        )
        if kv_cache is not None:
            new_cache.update(kv_cache)

    if cfg.family == "hybrid" and cfg.ssm is not None:
        # Hymba: parallel attention + mamba heads on the same normed input,
        # outputs normalized then averaged.
        if layer_cache is None or "ssm_h" not in (layer_cache or {}):
            st = ssm_mod.SSMState.init(x.shape[0], cfg.d_model, cfg.ssm)
        else:
            st = ssm_mod.SSMState(h=layer_cache["ssm_h"], conv=layer_cache["ssm_conv"])
        ssm_out, st_new = ssm_mod.ssm_forward(cfg.ssm, p["ssm"], h, st)
        mix = 0.5 * (
            rms_norm(attn_out, p["norm_attn_out"], cfg.norm_eps)
            + rms_norm(ssm_out, p["norm_ssm_out"], cfg.norm_eps)
        )
        x = x + mix
        new_cache.update({"ssm_h": st_new.h, "ssm_conv": st_new.conv})
    else:
        x = x + attn_out

    # ---- channel mixing ----
    if cfg.moe is not None:
        h2 = rms_norm(x, p["norm_ffn"], cfg.norm_eps)
        ffn_out, aux = _moe_dispatch(cfg, p["ffn"], h2)
        x = x + ffn_out
    elif cfg.d_ff > 0:
        h2 = rms_norm(x, p["norm_ffn"], cfg.norm_eps)
        x = x + swiglu(p["ffn"], h2)

    return x, (new_cache or None), aux


# ---------------------------------------------------------------------------
# Cross-attention block (VLM)
# ---------------------------------------------------------------------------


def cross_block_params_spec(cfg: ModelConfig, dtype) -> dict:
    hd = cfg.resolved_head_dim
    return {
        "norm_attn": ((cfg.d_model,), ones_init, jnp.float32),
        "norm_ffn": ((cfg.d_model,), ones_init, jnp.float32),
        "w_q": ((cfg.d_model, cfg.n_heads * hd), dense_init, dtype),
        "w_k": ((cfg.d_model, cfg.n_kv_heads * hd), dense_init, dtype),
        "w_v": ((cfg.d_model, cfg.n_kv_heads * hd), dense_init, dtype),
        "w_o": ((cfg.n_heads * hd, cfg.d_model), dense_init, dtype),
        "gate_attn": ((1,), lambda k, s, d: jnp.zeros(s, d), jnp.float32),
        "gate_ffn": ((1,), lambda k, s, d: jnp.zeros(s, d), jnp.float32),
        "ffn": swiglu_params(cfg.d_model, cfg.d_ff, dtype),
    }


def cross_block(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,
    *,
    memory: Optional[jax.Array] = None,       # [B, P, d] vision states (prefill/train)
    mem_kv: Optional[Tuple[jax.Array, jax.Array]] = None,  # cached cross K/V (decode)
    q_chunk: int = 1024,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Gated cross-attention block (Llama-3.2-Vision style)."""
    b, t, _ = x.shape
    hd = cfg.resolved_head_dim
    h = rms_norm(x, p["norm_attn"], cfg.norm_eps)
    q = jnp.einsum("btd,dh->bth", h, p["w_q"].astype(x.dtype)).reshape(b, t, cfg.n_heads, hd)
    if mem_kv is None:
        pm = memory.shape[1]
        k = jnp.einsum("bpd,dh->bph", memory, p["w_k"].astype(x.dtype)).reshape(
            b, pm, cfg.n_kv_heads, hd)
        v = jnp.einsum("bpd,dh->bph", memory, p["w_v"].astype(x.dtype)).reshape(
            b, pm, cfg.n_kv_heads, hd)
    else:
        k, v = mem_kv
    q_pos = jnp.zeros((b, t), jnp.int32)
    kv_pos = jnp.zeros((b, k.shape[1]), jnp.int32)
    out = chunked_attention(
        q, k, v, q_pos, kv_pos, causal=False, q_chunk=q_chunk, kv_chunk=4096
    )
    out = jnp.einsum("btf,fd->btd", out.reshape(b, t, cfg.n_heads * hd), p["w_o"].astype(x.dtype))
    x = x + jnp.tanh(p["gate_attn"]).astype(x.dtype) * out
    h2 = rms_norm(x, p["norm_ffn"], cfg.norm_eps)
    x = x + jnp.tanh(p["gate_ffn"]).astype(x.dtype) * swiglu(p["ffn"], h2)
    return x, (k, v)

# ---------------------------------------------------------------------------
# Stack drivers: scan over stacked [L, ...] layer params (+ cache slices)
# ---------------------------------------------------------------------------


def stack_apply(
    cfg: ModelConfig,
    blocks_params: dict,          # stacked [L, ...]
    x: jax.Array,
    positions: jax.Array,
    *,
    mode: str,
    cache: Optional[dict] = None,  # stacked [L, ...] per-layer cache
    kv_pos: Optional[jax.Array] = None,
    cursor: Optional[jax.Array] = None,
    remat: bool = True,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
    unroll: bool = False,
) -> Tuple[jax.Array, Optional[dict], jax.Array]:
    """Homogeneous decoder stack.  Returns (h, new_cache, aux_loss_sum).

    ``unroll=True`` inlines every layer into the HLO — used ONLY by the
    roofline costing compile (XLA cost_analysis counts a while-loop body
    once, so the production scan would undercount FLOPs by ~n_layers x).
    """

    def body(carry, xs):
        h, aux = carry
        p_l, cache_l = xs
        h, new_cache_l, aux_l = decoder_block(
            cfg, p_l, h, positions, mode=mode, layer_cache=cache_l,
            kv_pos=kv_pos, cursor=cursor, q_chunk=q_chunk, kv_chunk=kv_chunk,
        )
        return (h, aux + aux_l), new_cache_l

    if remat and mode == "train":
        body = jax.checkpoint(body)
    (x, aux), new_cache = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), (blocks_params, cache),
        unroll=cfg.n_layers if unroll else 1,
    )
    return x, new_cache, aux


def vlm_stack_apply(
    cfg: ModelConfig,
    params: dict,                 # {"blocks": [Ls,...], "cross": [Lx,...]}
    x: jax.Array,
    positions: jax.Array,
    *,
    mode: str,
    vision_states: Optional[jax.Array] = None,   # [B, P, d] projected (prefill/train)
    cache: Optional[dict] = None,
    kv_pos: Optional[jax.Array] = None,
    cursor: Optional[jax.Array] = None,
    remat: bool = True,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
    unroll: bool = False,
) -> Tuple[jax.Array, Optional[dict], jax.Array]:
    """Interleaved stack: groups of ``cross_attn_every - 1`` self layers
    followed by one gated cross-attention layer (Llama-3.2-Vision)."""
    per = cfg.vision.cross_attn_every - 1
    n_groups = cfg.n_layers // cfg.vision.cross_attn_every
    reshape_group = lambda t: t.reshape(n_groups, per, *t.shape[1:])
    blocks_g = jax.tree.map(reshape_group, params["blocks"])
    self_cache_g = None
    cross_cache = None
    if cache is not None:
        self_cache_g = jax.tree.map(
            reshape_group, {"k": cache["k"], "v": cache["v"]}
        ) if mode != "train" else None
        cross_cache = {"xk": cache["xk"], "xv": cache["xv"]} if mode != "train" else None

    def group_body(carry, xs):
        h, aux = carry
        p_self, p_cross, cache_self, cache_cross = xs

        def self_body(c2, xs2):
            h2, a2 = c2
            p_l, cache_l = xs2
            h2, new_cache_l, a_l = decoder_block(
                cfg, p_l, h2, positions, mode=mode, layer_cache=cache_l,
                kv_pos=kv_pos, cursor=cursor, q_chunk=q_chunk, kv_chunk=kv_chunk,
            )
            return (h2, a2 + a_l), new_cache_l

        (h, aux), new_self = jax.lax.scan(
            self_body, (h, aux), (p_self, cache_self), unroll=per if unroll else 1)
        if mode == "decode":
            h, xkv = cross_block(
                cfg, p_cross, h,
                mem_kv=(cache_cross["xk"], cache_cross["xv"]), q_chunk=q_chunk,
            )
        else:
            h, xkv = cross_block(cfg, p_cross, h, memory=vision_states, q_chunk=q_chunk)
        new_cross = {"xk": xkv[0], "xv": xkv[1]}
        return (h, aux), (new_self, new_cross)

    if remat and mode == "train":
        group_body = jax.checkpoint(group_body)
    (x, aux), (new_self, new_cross) = jax.lax.scan(
        group_body, (x, jnp.zeros((), jnp.float32)),
        (blocks_g, params["cross"], self_cache_g, cross_cache),
        unroll=n_groups if unroll else 1,
    )
    new_cache = None
    if mode != "train":
        unshape = lambda t: t.reshape(n_groups * per, *t.shape[2:])
        new_cache = {
            "k": unshape(new_self["k"]),
            "v": unshape(new_self["v"]),
            "xk": new_cross["xk"],
            "xv": new_cross["xv"],
        }
        if mode == "decode":
            # cross K/V are read-only at decode; keep the cached ones
            new_cache["xk"], new_cache["xv"] = cache["xk"], cache["xv"]
    return x, new_cache, aux
