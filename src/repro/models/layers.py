"""Shared model primitives: initializers, norms, rotary embeddings, MLPs.

Everything is pure-functional: parameters are nested dicts of ``jnp``
arrays, layers are functions ``(params, x, ...) -> y``.  Parameter
*structure* builders return ShapeDtypeStruct-compatible initializer thunks
so the dry-run can ``jax.eval_shape`` them without allocating.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Initializers.  Each init fn maps (key) -> array; builders compose dicts.
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype):
    """Truncated-normal fan-in init for 2D+ weights laid out [..., in, out].

    Works for stacked per-layer weights [L, in, out] too: fan-in is always
    the second-to-last axis.
    """
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def zeros_init(_key, shape, dtype):
    return jnp.zeros(shape, dtype)


def ones_init(_key, shape, dtype):
    return jnp.ones(shape, dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    """RMSNorm in fp32 accumulation, output in x.dtype."""
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    normed = xf * jax.lax.rsqrt(var + eps)
    return (normed * scale.astype(jnp.float32)).astype(dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    normed = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (normed * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies for RoPE, shape [head_dim // 2], float32."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Apply rotary embedding.

    x: [..., seq, heads, head_dim]; positions: [..., seq] int32 (broadcastable
    against x's batch/seq leading dims).
    """
    head_dim = x.shape[-1]
    inv_freq = rope_frequencies(head_dim, theta)             # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * inv_freq  # [..., seq, hd/2]
    cos = jnp.cos(angles)[..., None, :]                      # [..., seq, 1, hd/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n_positions: int, dim: int) -> jax.Array:
    """Whisper-style fixed sinusoidal positional embedding [n, dim]."""
    half = dim // 2
    log_timescale = math.log(10000.0) / max(half - 1, 1)
    inv = jnp.exp(-log_timescale * jnp.arange(half, dtype=jnp.float32))
    pos = jnp.arange(n_positions, dtype=jnp.float32)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(pos), jnp.cos(pos)], axis=-1)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def swiglu(params: dict, x: jax.Array) -> jax.Array:
    """Gated SwiGLU MLP: params {w_gate [d,f], w_up [d,f], w_down [f,d]}."""
    gate = jnp.einsum("...d,df->...f", x, params["w_gate"].astype(x.dtype))
    up = jnp.einsum("...d,df->...f", x, params["w_up"].astype(x.dtype))
    h = jax.nn.silu(gate) * up
    return jnp.einsum("...f,fd->...d", h, params["w_down"].astype(x.dtype))


def gelu_mlp(params: dict, x: jax.Array) -> jax.Array:
    """Plain GELU MLP (Whisper): params {w_in [d,f], b_in, w_out [f,d], b_out}."""
    h = jnp.einsum("...d,df->...f", x, params["w_in"].astype(x.dtype))
    h = jax.nn.gelu(h + params["b_in"].astype(x.dtype), approximate=True)
    out = jnp.einsum("...f,fd->...d", h, params["w_out"].astype(x.dtype))
    return out + params["b_out"].astype(x.dtype)


def swiglu_params(d_model: int, d_ff: int, dtype) -> dict:
    """Shape/init spec for a SwiGLU MLP (see builders in model.py)."""
    return {
        "w_gate": ((d_model, d_ff), dense_init, dtype),
        "w_up": ((d_model, d_ff), dense_init, dtype),
        "w_down": ((d_ff, d_model), dense_init, dtype),
    }


def gelu_mlp_params(d_model: int, d_ff: int, dtype) -> dict:
    return {
        "w_in": ((d_model, d_ff), dense_init, dtype),
        "b_in": ((d_ff,), zeros_init, dtype),
        "w_out": ((d_ff, d_model), dense_init, dtype),
        "b_out": ((d_model,), zeros_init, dtype),
    }


# ---------------------------------------------------------------------------
# Spec-dict -> params materialization (shared by all model builders)
# ---------------------------------------------------------------------------


def build_params(spec: dict, key: jax.Array):
    """Materialize a nested spec dict {name: (shape, init, dtype) | subdict}.

    Deterministic: the key is folded with a stable hash of each leaf path, so
    adding parameters does not reshuffle the init of existing ones.
    """
    leaves = []

    def _walk(node, path):
        if isinstance(node, dict):
            for k in sorted(node):
                _walk(node[k], path + (k,))
        else:
            leaves.append((path, node))

    _walk(spec, ())

    out = {}
    for path, (shape, init, dtype) in leaves:
        leaf_key = jax.random.fold_in(key, _stable_hash("/".join(path)))
        node = out
        for p in path[:-1]:
            node = node.setdefault(p, {})
        node[path[-1]] = init(leaf_key, shape, dtype)
    return out


def _stable_hash(s: str) -> int:
    h = 2166136261
    for ch in s.encode():
        h = (h ^ ch) * 16777619 % (1 << 31)
    return h


def stack_specs(spec: dict, n: int) -> dict:
    """Prepend a leading stack dimension of size n to every leaf of a spec."""
    if isinstance(spec, dict):
        return {k: stack_specs(v, n) for k, v in spec.items()}
    shape, init, dtype = spec
    return ((n,) + tuple(shape), init, dtype)
