"""Attention: memory-efficient chunked attention + GQA/MLA/cross variants.

Design notes
------------
* ``chunked_attention`` is the single training/prefill attention primitive.
  It is a pure-``lax`` flash-attention (online softmax over KV chunks inside
  a scan over Q chunks) so the HLO **never materializes [Sq, Skv]** — this is
  what makes the 32k-prefill dry-run cells compile with sane memory.  The
  Pallas kernel in ``repro.kernels.flash_attention`` is the TPU-target
  version of the same math; this module is also its ``ref``erence oracle.
* ``decode_attention`` attends one (or few) query tokens against a padded KV
  cache — scores are [B, H, Skv], no chunking needed.
* Visibility is computed from explicit *position* arrays, which uniformly
  encodes causal masks, sliding windows, always-visible meta tokens
  (Hymba), cache padding, and cross-attention (no mask).
"""
from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def visibility_mask(
    q_pos: jax.Array,        # [..., Sq] int32
    kv_pos: jax.Array,       # [..., Skv] int32 (-1 marks invalid cache slots)
    *,
    causal: bool,
    window: int = 0,
    n_meta: int = 0,
) -> jax.Array:
    """Boolean [..., Sq, Skv] visibility."""
    qp = q_pos[..., :, None]
    kp = kv_pos[..., None, :]
    vis = kp >= 0
    if causal:
        vis = jnp.logical_and(vis, kp <= qp)
    if window > 0:
        in_window = (qp - kp) < window
        if n_meta > 0:
            in_window = jnp.logical_or(in_window, kp < n_meta)
        vis = jnp.logical_and(vis, in_window)
    return vis


def _pad_axis(x: jax.Array, axis: int, multiple: int, value=0.0):
    n = x.shape[axis]
    target = -(-n // multiple) * multiple
    if target == n:
        return x, n
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, target - n)
    return jnp.pad(x, pad, constant_values=value), n


@partial(
    jax.jit,
    static_argnames=("causal", "window", "n_meta", "q_chunk", "kv_chunk"),
)
def chunked_attention(
    q: jax.Array,             # [B, Sq, H, Dk]
    k: jax.Array,             # [B, Skv, KVH, Dk]
    v: jax.Array,             # [B, Skv, KVH, Dv]
    q_pos: jax.Array,         # [B, Sq] int32
    kv_pos: jax.Array,        # [B, Skv] int32
    *,
    causal: bool = True,
    window: int = 0,
    n_meta: int = 0,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
) -> jax.Array:
    """Flash-style attention; returns [B, Sq, H, Dv] in q.dtype.

    GQA: H must be a multiple of KVH.  fp32 softmax accumulation.
    """
    B, Sq, H, Dk = q.shape
    _, Skv, KVH, _ = k.shape
    Dv = v.shape[-1]
    G = H // KVH
    scale = 1.0 / math.sqrt(Dk)
    out_dtype = q.dtype

    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)

    q, _ = _pad_axis(q, 1, q_chunk)
    q_pos_p, _ = _pad_axis(q_pos, 1, q_chunk, value=0)
    k, _ = _pad_axis(k, 1, kv_chunk)
    v, _ = _pad_axis(v, 1, kv_chunk)
    kv_pos_p, _ = _pad_axis(kv_pos, 1, kv_chunk, value=-1)  # padded slots invisible

    nq = q.shape[1] // q_chunk
    nk = k.shape[1] // kv_chunk

    # [B, nq, qc, KVH, G, Dk] etc.
    qr = q.reshape(B, nq, q_chunk, KVH, G, Dk)
    qpr = q_pos_p.reshape(B, nq, q_chunk)
    kr = k.reshape(B, nk, kv_chunk, KVH, Dk)
    vr = v.reshape(B, nk, kv_chunk, KVH, Dv)
    kpr = kv_pos_p.reshape(B, nk, kv_chunk)

    def one_q_chunk(qc, qp):
        """qc: [B, qc, KVH, G, Dk]; qp: [B, qc] -> [B, qc, KVH, G, Dv]."""

        def kv_step(carry, inp):
            m, l, acc = carry
            kc, vc, kp = inp                      # [B,ck,KVH,Dk], [B,ck,KVH,Dv], [B,ck]
            s = jnp.einsum(
                "bqkgd,bckd->bkgqc", qc, kc, preferred_element_type=jnp.float32
            ) * scale                              # [B,KVH,G,qc,ck]
            vis = visibility_mask(qp, kp, causal=causal, window=window, n_meta=n_meta)
            s = jnp.where(vis[:, None, None, :, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum(
                "bkgqc,bckd->bkgqd", p.astype(vc.dtype), vc,
                preferred_element_type=jnp.float32,
            )
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KVH, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KVH, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, KVH, G, q_chunk, Dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step,
            (m0, l0, a0),
            (
                jnp.moveaxis(kr, 1, 0),
                jnp.moveaxis(vr, 1, 0),
                jnp.moveaxis(kpr, 1, 0),
            ),
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return jnp.moveaxis(out, 3, 1).astype(out_dtype)  # [B, qc, KVH, G, Dv]

    # remat each q-chunk: backward recomputes the inner KV scan, so residual
    # memory is O(Sq * Dv) instead of O(Sq * Skv).
    one_q_chunk = jax.checkpoint(one_q_chunk)

    def scan_q(_, inp):
        qc, qp = inp
        return None, one_q_chunk(qc, qp)

    _, outs = jax.lax.scan(
        scan_q, None, (jnp.moveaxis(qr, 1, 0), jnp.moveaxis(qpr, 1, 0))
    )
    out = jnp.moveaxis(outs, 0, 1).reshape(B, nq * q_chunk, H, Dv)
    return out[:, :Sq]


def decode_attention(
    q: jax.Array,            # [B, Tq, H, Dk]   (Tq small, usually 1)
    k_cache: jax.Array,      # [B, S, KVH, Dk]
    v_cache: jax.Array,      # [B, S, KVH, Dv]
    q_pos: jax.Array,        # [B, Tq] int32
    kv_pos: jax.Array,       # [B, S] int32 (-1 = empty slot)
    *,
    window: int = 0,
    n_meta: int = 0,
) -> jax.Array:
    """Single/few-token attention against a padded KV cache -> [B, Tq, H, Dv]."""
    B, Tq, H, Dk = q.shape
    KVH = k_cache.shape[2]
    G = H // KVH
    scale = 1.0 / math.sqrt(Dk)
    qr = q.reshape(B, Tq, KVH, G, Dk)
    s = jnp.einsum(
        "bqkgd,bskd->bkgqs", qr, k_cache, preferred_element_type=jnp.float32
    ) * scale
    vis = visibility_mask(q_pos, kv_pos, causal=True, window=window, n_meta=n_meta)
    s = jnp.where(vis[:, None, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # no preferred_element_type: bf16xbf16->f32 batched dots are unimplemented
    # in the XLA:CPU thunk runtime; p is normalized so bf16 output is safe.
    out = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v_cache.dtype), v_cache)
    return out.reshape(B, Tq, H, v_cache.shape[-1]).astype(q.dtype)


# ---------------------------------------------------------------------------
# KV cache
# ---------------------------------------------------------------------------


class KVCache(NamedTuple):
    """Per-layer-stacked KV cache.

    k, v: [L, B, S, KVH, D]; pos: [B, S] int32 slot positions (-1 empty);
    length: [] int32 — write cursor (same for all batch rows).
    """

    k: jax.Array
    v: jax.Array
    pos: jax.Array
    length: jax.Array

    @staticmethod
    def init(n_layers, batch, max_seq, n_kv, d_k, d_v=None, dtype=jnp.bfloat16):
        d_v = d_k if d_v is None else d_v
        return KVCache(
            k=jnp.zeros((n_layers, batch, max_seq, n_kv, d_k), dtype),
            v=jnp.zeros((n_layers, batch, max_seq, n_kv, d_v), dtype),
            pos=jnp.full((batch, max_seq), -1, jnp.int32),
            length=jnp.zeros((), jnp.int32),
        )


def ring_slots(cursor: jax.Array, n_new: int, size: int, n_pinned: int = 0) -> jax.Array:
    """Slot indices for writing ``n_new`` entries at ``cursor`` into a cache
    of ``size`` slots whose first ``n_pinned`` slots are never recycled
    (always-visible meta tokens) and whose remaining ``size - n_pinned``
    slots form a ring.

    Entries that would be overwritten by a *later* entry in the same write
    (ring wrap with n_new > ring) are redirected to slot ``size`` — combined
    with ``mode="drop"`` scatters this yields last-writer-wins semantics.
    For full-length caches the modulo is a no-op.
    """
    idx = cursor + jnp.arange(n_new, dtype=jnp.int32)
    ring = max(size - n_pinned, 1)
    slot = jnp.where(
        idx < n_pinned, idx, n_pinned + jnp.mod(idx - n_pinned, ring))
    keep = (idx < n_pinned) | (idx >= cursor + n_new - ring)
    return jnp.where(keep, slot, size)


def cache_write(cache_k, cache_v, k_new, v_new, cursor, n_pinned: int = 0):
    """Scatter [B, T, KVH, D] new K/V into the cache at ``cursor``.

    One code path for full caches (S == max_seq), sliding-window ring caches
    (S == window + n_meta) and pinned meta-token slots.  Returns (k, v)."""
    S = cache_k.shape[1]
    slots = ring_slots(cursor, k_new.shape[1], S, n_pinned)
    ck = cache_k.at[:, slots].set(k_new.astype(cache_k.dtype), mode="drop")
    cv = cache_v.at[:, slots].set(v_new.astype(cache_v.dtype), mode="drop")
    return ck, cv


def cache_write_single(cache: jax.Array, new: jax.Array, cursor, n_pinned: int = 0):
    """Scatter one [B, T, ...] array into a [B, S, ...] ring cache."""
    slots = ring_slots(cursor, new.shape[1], cache.shape[1], n_pinned)
    return cache.at[:, slots].set(new.astype(cache.dtype), mode="drop")


def cache_pos_write(pos: jax.Array, new_pos: jax.Array, cursor, n_pinned: int = 0):
    """Scatter new absolute positions [B, T] into the pos ring [B, S]."""
    slots = ring_slots(cursor, new_pos.shape[1], pos.shape[1], n_pinned)
    return pos.at[:, slots].set(new_pos.astype(pos.dtype), mode="drop")
