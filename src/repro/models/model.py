"""Unified model facade: one API over the 10 assigned architectures.

``build_model(cfg)`` returns a :class:`Model` with pure functions:

* ``init(key)``            — materialize parameters (master dtype).
* ``loss(params, batch)``  — causal-LM loss (chunked CE, never materializes
                             the full [B, T, V] logits).
* ``prefill(params, batch, cache)``  — populate caches, return last logits.
* ``decode_step(params, cache, tokens)`` — one serve step.
* ``init_cache(batch, max_seq)`` — family-specific cache pytree.

The dry-run only ever touches these through ``jax.eval_shape`` /
``jit(...).lower`` — no device allocation at full size.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import cached_property, partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models import transformer as tfm
from repro.models import whisper as whisper_mod
from repro.models import xlstm as xlstm_mod
from repro.models.attention import cache_pos_write
from repro.models.layers import (
    build_params,
    dense_init,
    embed_init,
    ones_init,
    rms_norm,
    stack_specs,
)

Batch = Dict[str, jax.Array]
Cache = Dict[str, Any]


# ---------------------------------------------------------------------------
# Chunked cross-entropy (the [B,T,V] logits are never materialized)
# ---------------------------------------------------------------------------


def chunked_ce_loss(
    h: jax.Array,            # [B, T, d]
    unembed: jax.Array,      # [d, V]
    labels: jax.Array,       # [B, T] int32, -1 = ignore
    *,
    chunk: int = 512,
    unroll: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (sum of token losses, token count), fp32."""
    b, t, d = h.shape
    chunk = min(chunk, t)
    n = -(-t // chunk)
    pad = n * chunk - t
    if pad:
        h = jnp.pad(h, [(0, 0), (0, pad), (0, 0)])
        labels = jnp.pad(labels, [(0, 0), (0, pad)], constant_values=-1)
    hc = jnp.moveaxis(h.reshape(b, n, chunk, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(b, n, chunk), 1, 0)

    @jax.checkpoint
    def body(carry, inp):
        loss_sum, count = carry
        hx, lx = inp
        logits = jnp.einsum(
            "btd,dv->btv", hx, unembed.astype(hx.dtype), preferred_element_type=jnp.float32
        )
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(
            logits, jnp.maximum(lx, 0)[..., None], axis=-1
        )[..., 0]
        mask = (lx >= 0).astype(jnp.float32)
        return (loss_sum + ((lse - ll) * mask).sum(), count + mask.sum()), None

    (loss_sum, count), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)), (hc, lc),
        unroll=n if unroll else 1,
    )
    return loss_sum, count


def _logits_last(h_last: jax.Array, unembed: jax.Array) -> jax.Array:
    """h_last [B, T, d] -> logits [B, T, V] (small T only)."""
    return jnp.einsum(
        "btd,dv->btv", h_last, unembed.astype(h_last.dtype),
        preferred_element_type=jnp.float32,
    )


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    # attention chunk sizes (tunable per shape by the launcher)
    q_chunk: int = 1024
    kv_chunk: int = 1024
    # unroll all layer scans: ONLY for the roofline costing compile (XLA
    # cost_analysis counts while-loop bodies once; see launch/dryrun.py)
    unroll: bool = False

    # -- parameters ---------------------------------------------------------

    def param_spec(self) -> dict:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.param_dtype)
        spec: dict = {
            "embed": ((cfg.vocab, cfg.d_model), embed_init, dtype),
            "norm_f": ((cfg.d_model,), ones_init, jnp.float32),
        }
        if not cfg.tie_embeddings:
            spec["unembed"] = ((cfg.d_model, cfg.vocab), dense_init, dtype)
        if cfg.n_meta_tokens:
            spec["meta"] = ((cfg.n_meta_tokens, cfg.d_model), embed_init, dtype)

        if cfg.family in ("dense", "moe", "hybrid"):
            spec["blocks"] = stack_specs(tfm.block_params_spec(cfg, dtype), cfg.n_layers)
        elif cfg.family == "vlm":
            per = cfg.vision.cross_attn_every
            n_groups = cfg.n_layers // per
            n_self = n_groups * (per - 1)
            spec["blocks"] = stack_specs(tfm.block_params_spec(cfg, dtype), n_self)
            spec["cross"] = stack_specs(tfm.cross_block_params_spec(cfg, dtype), n_groups)
            spec["vision_proj"] = ((cfg.vision.vision_dim, cfg.d_model), dense_init, dtype)
        elif cfg.family == "ssm":
            n_pairs = xlstm_mod.xlstm_pair_count(cfg.n_layers, cfg.xlstm)
            spec["m_blocks"] = stack_specs(
                xlstm_mod.mlstm_params_spec(cfg.d_model, cfg.n_heads, cfg.xlstm, dtype), n_pairs)
            spec["s_blocks"] = stack_specs(
                xlstm_mod.slstm_params_spec(cfg.d_model, cfg.n_heads, cfg.xlstm, dtype), n_pairs)
        elif cfg.family == "audio":
            spec["enc"] = {
                "blocks": stack_specs(
                    whisper_mod.enc_block_spec(cfg, dtype), cfg.audio.n_encoder_layers),
                "ln_f": whisper_mod._ln_spec(cfg.d_model),
            }
            spec["dec"] = {
                "blocks": stack_specs(whisper_mod.dec_block_spec(cfg, dtype), cfg.n_layers),
                "ln_f": whisper_mod._ln_spec(cfg.d_model),
            }
        else:
            raise ValueError(cfg.family)
        return spec

    def init(self, key: jax.Array):
        return build_params(self.param_spec(), key)

    def param_shapes(self):
        return jax.eval_shape(lambda: build_params(self.param_spec(), jax.random.PRNGKey(0)))

    # -- embedding helpers ----------------------------------------------------

    def _embed(self, params, tokens):
        cfg = self.cfg
        from repro.distributed.collectives import dp_tp_axes, usable_mesh

        mesh = usable_mesh()
        table = params["embed"]
        if (mesh is not None
                and table.shape[-1] % mesh.shape["model"] == 0
                and tokens.shape[0] % _dp_size_of(mesh) == 0):
            from repro.distributed.collectives import embed_lookup

            x = embed_lookup(table, tokens, mesh)
        else:
            x = jnp.take(table, tokens, axis=0)
        return x.astype(jnp.dtype(cfg.compute_dtype))

    def _unembed_matrix(self, params):
        if self.cfg.tie_embeddings:
            return params["embed"].T
        return params["unembed"]

    def _positions(self, batch_size: int, start, length: int) -> jax.Array:
        pos = start + jnp.arange(length, dtype=jnp.int32)[None, :]
        return jnp.broadcast_to(pos, (batch_size, length))

    # -- trunk dispatch -------------------------------------------------------

    def _trunk(self, params, x, positions, *, mode, cache, batch=None):
        """Run the layer stack.  Returns (h, new_layer_cache, aux)."""
        cfg = self.cfg
        kv_pos = cache["pos"] if (cache is not None and "pos" in cache) else None
        cursor = cache["length"] if cache is not None else None
        layers_cache = cache["layers"] if cache is not None else None
        remat = mode == "train"
        aux = jnp.zeros((), jnp.float32)

        if cfg.family in ("dense", "moe", "hybrid"):
            h, new_layers, aux = tfm.stack_apply(
                cfg, params["blocks"], x, positions, mode=mode, cache=layers_cache,
                kv_pos=kv_pos, cursor=cursor, remat=remat,
                q_chunk=self.q_chunk, kv_chunk=self.kv_chunk, unroll=self.unroll,
            )
        elif cfg.family == "vlm":
            vision_states = None
            if mode != "decode":
                frontend = batch["frontend"].astype(x.dtype)
                vision_states = jnp.einsum(
                    "bpe,ed->bpd", frontend, params["vision_proj"].astype(x.dtype))
            h, new_layers, aux = tfm.vlm_stack_apply(
                cfg, {"blocks": params["blocks"], "cross": params["cross"]},
                x, positions, mode=mode, vision_states=vision_states,
                cache=layers_cache, kv_pos=kv_pos, cursor=cursor, remat=remat,
                q_chunk=self.q_chunk, kv_chunk=self.kv_chunk, unroll=self.unroll,
            )
        elif cfg.family == "ssm":
            state = layers_cache
            if state is None:
                n_pairs = xlstm_mod.xlstm_pair_count(cfg.n_layers, cfg.xlstm)
                state = xlstm_mod.XLSTMStackState.init(
                    n_pairs, x.shape[0], cfg.d_model, cfg.n_heads, cfg.xlstm,
                    jnp.dtype(cfg.compute_dtype))
            h, new_layers = xlstm_mod.xlstm_stack_apply(
                cfg.xlstm, cfg.n_heads, params, x, state, remat=remat,
                unroll=self.unroll)
        elif cfg.family == "audio":
            if mode == "decode":
                enc_out = None
            else:
                enc_out = batch["frontend"].astype(x.dtype)
                enc_out = whisper_mod.encoder_forward(
                    cfg, params["enc"], enc_out, remat=remat, unroll=self.unroll)
            h, new_layers = whisper_mod.decoder_forward(
                cfg, params["dec"], x, positions, enc_out, mode=mode,
                cache=layers_cache, kv_pos=kv_pos, cursor=cursor, remat=remat,
                unroll=self.unroll)
            return h, new_layers, aux
        else:
            raise ValueError(cfg.family)

        if cfg.family != "audio":
            h = rms_norm(h, params["norm_f"], cfg.norm_eps)
        return h, new_layers, aux

    # -- training -------------------------------------------------------------

    def loss(self, params, batch: Batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        cfg = self.cfg
        tokens, labels = batch["tokens"], batch["labels"]
        b, t = tokens.shape
        x = self._embed(params, tokens)
        nm = cfg.n_meta_tokens
        if nm:
            meta = jnp.broadcast_to(
                params["meta"].astype(x.dtype)[None], (b, nm, cfg.d_model))
            x = jnp.concatenate([meta, x], axis=1)
        positions = self._positions(b, 0, t + nm)
        h, _, aux = self._trunk(params, x, positions, mode="train", cache=None, batch=batch)
        if nm:
            h = h[:, nm:]
        loss_sum, count = chunked_ce_loss(
            h, self._unembed_matrix(params), labels, unroll=self.unroll)
        loss = loss_sum / jnp.maximum(count, 1.0)
        total = loss + aux / max(cfg.n_layers, 1)
        return total, {"ce_loss": loss, "aux_loss": aux, "tokens": count}

    # -- serving ----------------------------------------------------------------

    def prefill(self, params, batch: Batch, cache: Cache) -> Tuple[Cache, jax.Array]:
        """Populate caches from a [B, S] prompt; returns (cache, last-token logits)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        b, t = tokens.shape
        x = self._embed(params, tokens)
        nm = cfg.n_meta_tokens
        if nm:
            meta = jnp.broadcast_to(
                params["meta"].astype(x.dtype)[None], (b, nm, cfg.d_model))
            x = jnp.concatenate([meta, x], axis=1)
        positions = self._positions(b, 0, t + nm)
        h, new_layers, _ = self._trunk(params, x, positions, mode="prefill",
                                       cache=cache, batch=batch)
        new_cache = dict(cache)
        new_cache["layers"] = new_layers
        if "pos" in cache:
            new_cache["pos"] = cache_pos_write(
                cache["pos"], positions, cache["length"], n_pinned=nm)
        new_cache["length"] = cache["length"] + t + nm
        logits = _logits_last(h[:, -1:], self._unembed_matrix(params))
        return new_cache, logits

    def decode_step(self, params, cache: Cache, tokens: jax.Array) -> Tuple[Cache, jax.Array]:
        """One decode step: tokens [B, T_small] -> (cache, logits [B, T_small, V])."""
        cfg = self.cfg
        b, t = tokens.shape
        x = self._embed(params, tokens)
        positions = self._positions(b, cache["length"], t)
        new_cache = dict(cache)
        if "pos" in cache:
            # write positions first so self-attention sees the new token slots
            new_cache["pos"] = cache_pos_write(
                cache["pos"], positions, cache["length"], n_pinned=cfg.n_meta_tokens)
            cache = dict(cache, pos=new_cache["pos"])
        h, new_layers, _ = self._trunk(params, x, positions, mode="decode",
                                       cache=cache, batch=None)
        new_cache["layers"] = new_layers
        new_cache["length"] = cache["length"] + t
        logits = _logits_last(h, self._unembed_matrix(params))
        return new_cache, logits

    # -- caches -----------------------------------------------------------------

    def cache_slots(self, max_seq: int) -> int:
        cfg = self.cfg
        if cfg.sliding_window:
            return min(max_seq, cfg.sliding_window + cfg.n_meta_tokens)
        return max_seq

    def init_cache(self, batch_size: int, max_seq: int, dtype=jnp.bfloat16) -> Cache:
        cfg = self.cfg
        hd = cfg.resolved_head_dim
        s = self.cache_slots(max_seq + cfg.n_meta_tokens)
        cache: Cache = {"length": jnp.zeros((), jnp.int32)}
        b = batch_size

        if cfg.family in ("dense", "moe", "hybrid", "vlm"):
            n_self = cfg.n_layers
            layers: dict = {}
            if cfg.mla is not None:
                layers["ckv"] = jnp.zeros((n_self, b, s, cfg.mla.kv_lora_rank), dtype)
                layers["kr"] = jnp.zeros((n_self, b, s, cfg.mla.qk_rope_head_dim), dtype)
            else:
                if cfg.family == "vlm":
                    per = cfg.vision.cross_attn_every
                    n_self = cfg.n_layers // per * (per - 1)
                layers["k"] = jnp.zeros((n_self, b, s, cfg.n_kv_heads, hd), dtype)
                layers["v"] = jnp.zeros((n_self, b, s, cfg.n_kv_heads, hd), dtype)
            if cfg.family == "hybrid":
                di = cfg.ssm.expand * cfg.d_model
                layers["ssm_h"] = jnp.zeros((cfg.n_layers, b, di, cfg.ssm.d_state), jnp.float32)
                layers["ssm_conv"] = jnp.zeros(
                    (cfg.n_layers, b, cfg.ssm.d_conv - 1, di), dtype)
            if cfg.family == "vlm":
                n_groups = cfg.n_layers // cfg.vision.cross_attn_every
                layers["xk"] = jnp.zeros(
                    (n_groups, b, cfg.vision.n_patches, cfg.n_kv_heads, hd), dtype)
                layers["xv"] = jnp.zeros(
                    (n_groups, b, cfg.vision.n_patches, cfg.n_kv_heads, hd), dtype)
            cache["layers"] = layers
            cache["pos"] = jnp.full((b, s), -1, jnp.int32)
        elif cfg.family == "ssm":
            n_pairs = xlstm_mod.xlstm_pair_count(cfg.n_layers, cfg.xlstm)
            cache["layers"] = xlstm_mod.XLSTMStackState.init(
                n_pairs, b, cfg.d_model, cfg.n_heads, cfg.xlstm, dtype)
        elif cfg.family == "audio":
            layers = {
                "k": jnp.zeros((cfg.n_layers, b, s, cfg.n_kv_heads, hd), dtype),
                "v": jnp.zeros((cfg.n_layers, b, s, cfg.n_kv_heads, hd), dtype),
                "xk": jnp.zeros((cfg.n_layers, b, cfg.audio.n_audio_ctx, cfg.n_kv_heads, hd), dtype),
                "xv": jnp.zeros((cfg.n_layers, b, cfg.audio.n_audio_ctx, cfg.n_kv_heads, hd), dtype),
            }
            cache["layers"] = layers
            cache["pos"] = jnp.full((b, s), -1, jnp.int32)
        else:
            raise ValueError(cfg.family)
        return cache

    def cache_shapes(self, batch_size: int, max_seq: int, dtype=jnp.bfloat16):
        return jax.eval_shape(partial(self.init_cache, batch_size, max_seq, dtype))

    # -- input specs (dry-run stand-ins) ----------------------------------------

    def input_specs(self, shape: ShapeSpec) -> Dict[str, jax.ShapeDtypeStruct]:
        """ShapeDtypeStruct stand-ins for every model input of this shape."""
        cfg = self.cfg
        b = shape.global_batch
        specs: Dict[str, jax.ShapeDtypeStruct] = {}
        if shape.kind == "train":
            specs["tokens"] = jax.ShapeDtypeStruct((b, shape.seq_len), jnp.int32)
            specs["labels"] = jax.ShapeDtypeStruct((b, shape.seq_len), jnp.int32)
        elif shape.kind == "prefill":
            specs["tokens"] = jax.ShapeDtypeStruct((b, shape.seq_len), jnp.int32)
        elif shape.kind == "decode":
            specs["tokens"] = jax.ShapeDtypeStruct((b, 1), jnp.int32)
        if cfg.family == "vlm" and shape.kind != "decode":
            specs["frontend"] = jax.ShapeDtypeStruct(
                (b, cfg.vision.n_patches, cfg.vision.vision_dim), jnp.bfloat16)
        if cfg.family == "audio" and shape.kind != "decode":
            specs["frontend"] = jax.ShapeDtypeStruct(
                (b, cfg.audio.n_audio_ctx, cfg.d_model), jnp.bfloat16)
        return specs


def _dp_size_of(mesh) -> int:
    from repro.distributed.collectives import dp_tp_axes

    dp, _ = dp_tp_axes(mesh)
    n = 1
    for a in dp:
        n *= mesh.shape[a]
    return n


def build_model(cfg: ModelConfig, **kw) -> Model:
    return Model(cfg, **kw)


# ---------------------------------------------------------------------------
# Parameter counting (roofline MODEL_FLOPS)
# ---------------------------------------------------------------------------


def count_params(cfg: ModelConfig) -> dict:
    """Analytic counts from the parameter spec (exact — derived from shapes)."""
    model = Model(cfg)
    shapes = jax.eval_shape(lambda: build_params(model.param_spec(), jax.random.PRNGKey(0)))
    leaves = jax.tree.leaves(shapes)
    total = int(sum(np.prod(l.shape) for l in leaves))

    embed = cfg.vocab * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    active = total
    if cfg.moe is not None:
        per_expert = 3 * cfg.d_model * cfg.moe.d_expert
        routed = cfg.moe.n_routed * per_expert * cfg.n_layers
        active = total - routed + cfg.moe.top_k * per_expert * cfg.n_layers
    # "active" for FLOPs excludes the input embedding gather (not a matmul)
    active_flops = active - cfg.vocab * cfg.d_model
    return {"total": total, "active": active, "active_flops": active_flops,
            "embedding": embed}


def model_flops_per_step(cfg: ModelConfig, shape: ShapeSpec, backward: bool) -> float:
    """MODEL_FLOPS = 6*N*D (training) or 2*N*D (inference) with N = active
    matmul params, D = tokens processed in the step."""
    n = count_params(cfg)["active_flops"]
    d = shape.tokens_per_step
    return (6.0 if backward else 2.0) * n * d
