"""Deterministic, resumable, sharded synthetic LM data pipeline.

Transparent C/R requires the data stream to be a pure function of
``(seed, cursor)`` — restoring a checkpoint's cursor and re-entering the
loop reproduces the exact token stream a never-preempted run would have
seen (asserted bitwise in tests/test_e2e_train.py).

The synthetic corpus is a Zipf-ish Markov token stream with enough
structure for a ~100M-param model to show a decreasing loss curve in the
e2e example (pure noise would pin the loss at log V).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # synthetic-structure knobs
    n_patterns: int = 512          # distinct repeated motifs
    pattern_len: int = 16
    zipf_a: float = 1.3


class SyntheticLM:
    """Batch factory: ``batch_at(cursor)`` is a pure function of cursor."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        base = np.random.default_rng(cfg.seed)
        # motif table: patterns of tokens the stream stitches together
        self._patterns = base.integers(
            0, cfg.vocab, size=(cfg.n_patterns, cfg.pattern_len), dtype=np.int32)
        ranks = np.arange(1, cfg.n_patterns + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_a)
        self._pattern_p = p / p.sum()

    def batch_at(self, cursor: int) -> Dict[str, np.ndarray]:
        """The ``cursor``-th global batch: {tokens, labels} [B, S] int32."""
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed << 20) ^ int(cursor))
        n_motifs = cfg.seq_len // cfg.pattern_len + 2
        idx = rng.choice(
            cfg.n_patterns, size=(cfg.global_batch, n_motifs), p=self._pattern_p)
        stream = self._patterns[idx].reshape(cfg.global_batch, -1)
        # light noise so the mapping isn't trivially memorizable
        noise_mask = rng.random(stream.shape) < 0.05
        noise = rng.integers(0, cfg.vocab, size=stream.shape, dtype=np.int32)
        stream = np.where(noise_mask, noise, stream)
        tokens = stream[:, : cfg.seq_len]
        labels = stream[:, 1 : cfg.seq_len + 1]
        return {"tokens": tokens.astype(np.int32), "labels": labels.astype(np.int32)}

    def iterator(self, start_cursor: int = 0) -> Iterator[Tuple[int, Dict[str, np.ndarray]]]:
        cursor = start_cursor
        while True:
            yield cursor, self.batch_at(cursor)
            cursor += 1


def shard_batch(batch: Dict[str, np.ndarray], shardings=None) -> Dict[str, jax.Array]:
    """Host batch -> device arrays (optionally with explicit shardings)."""
    if shardings is None:
        return {k: jnp.asarray(v) for k, v in batch.items()}
    return {
        k: jax.device_put(v, shardings[k]) if k in shardings else jnp.asarray(v)
        for k, v in batch.items()
    }
