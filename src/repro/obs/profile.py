"""ProfileTimers: named wall-clock section accounting for the host side of
the engine — where ticks are cheap and the interesting costs are compile
vs. dispatch vs. host compaction in the streaming loop.

Deliberately tiny: `time.perf_counter` deltas accumulated per section name.
`core.engine.simulate_stream` takes an optional instance and charges three
sections (``compile``, ``dispatch``, ``compaction``);
`benchmarks.bench_sched_scale` snapshots them into the bench JSON and the
CI step summary.  Sections nest (each level is charged its own wall time,
so nested sections double-count by design — they answer "how long was this
section open", not "exclusive self time").
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from typing import Dict, Iterator


class ProfileTimers:
    """Accumulates ``(total_seconds, calls)`` per named section."""

    def __init__(self) -> None:
        self.total_s: Dict[str, float] = {}
        self.calls: Dict[str, int] = {}

    @contextmanager
    def section(self, name: str) -> Iterator[None]:
        start = time.perf_counter()
        try:
            yield
        finally:
            dt = time.perf_counter() - start
            self.total_s[name] = self.total_s.get(name, 0.0) + dt
            self.calls[name] = self.calls.get(name, 0) + 1

    def snapshot(self) -> Dict[str, Dict[str, float]]:
        """``{section: {"total_s": ..., "calls": ...}}`` — JSON-ready."""
        return {
            name: {"total_s": self.total_s[name], "calls": self.calls[name]}
            for name in sorted(self.total_s)
        }

    def clear(self) -> None:
        self.total_s.clear()
        self.calls.clear()
