"""The event schema: typed per-job lifecycle events, defined ONCE as rules
over the tick-boundary state diff.

Both backends must emit bit-identical logs, so the schema is deliberately
NOT "emit at the call site" (call sites differ across backends and can see
intra-tick transients the other backend never materializes — e.g. a
quantum-0 admit-then-evict inside one pass).  Instead every event is a
predicate over ``(pre, post, t)`` where ``pre``/``post`` are the job's
states at the tick boundary:

======== ==================================================== ===========
event    rule over the tick diff                              arg
======== ==================================================== ===========
SUBMIT   pre.state == UNSUBMITTED and pre.submit <= t         cpus
START    post.state == RUNNING and post.run_start == t        cpus
RESTORE  START rule and pre.n_ckpt > 0                        max(pre.ckpt_tier, 0)
EVICT    post.n_preempt > pre.n_preempt                       cpus
SAVE     post.n_ckpt > pre.n_ckpt                             post.ckpt_tier
SPILL    post.n_spill > pre.n_spill                           post.ckpt_tier
FINISH   post.state == DONE and post.finish == t              post.progress
DEFER    post.state == PENDING                                cpus
======== ==================================================== ===========

Within a tick at most ONE of each type fires per job (the scheduling pass
snapshots eligibility, so a job cannot be admitted twice or evicted twice
in one tick), and at most `MAX_EVENTS_PER_JOB_PER_TICK` fire in total
(the worst case is EVICT+SAVE+SPILL+DEFER) — which is what makes
``lossless_ring_size`` a hard bound for the JAX backend's bounded ring
(`obs.jax_capture`).  A killed job emits EVICT without SAVE and no FINISH
(FINISH is strictly DONE); the trace exporter closes its span at the
EVICT.  DEFER fires for every job still waiting after the pass — one
DEFER per job per waited tick, so wait time is literally the DEFER count.

The canonical per-tick order is ``(tick, etype, jid)``: the Python emitter
generates it directly, the JAX ring is written in (etype, table-row) order
and re-sorted host-side at decode (row order == jid order for monolithic
tables but not for the streaming engine's recycled slots).

`events_from_diff` below is the Python implementation of the table above;
`obs.jax_capture.capture_tick` is the vectorized twin.  The analysis rule
``event-schema`` (`repro.analysis.event_schema`) checks that every type
declared here is referenced by both implementations and by at least one
consumer — declared ⟺ emitted ⟺ consumed.
"""
from __future__ import annotations

import enum
from typing import Dict, Iterable, List, NamedTuple

from repro.core.types import Job, JobState


class EventType(enum.IntEnum):
    """Per-job lifecycle events, int codes stable across backends."""

    SUBMIT = 0     # arrived: UNSUBMITTED -> PENDING
    START = 1      # admitted: began (or resumed) running this tick
    RESTORE = 2    # the START consumed an existing checkpoint
    EVICT = 3      # preempted (checkpointed victims) or killed
    SAVE = 4       # eviction wrote a checkpoint (arg = placed tier)
    SPILL = 5      # the SAVE landed beyond the fast tier
    FINISH = 6     # completed all work (state DONE)
    DEFER = 7      # still PENDING after the scheduling pass (waiting)


EVENT_TYPE_NAMES = tuple(e.name for e in EventType)
N_EVENT_TYPES = len(EventType)

#: hard per-job per-tick bound (EVICT+SAVE+SPILL+DEFER is the worst case);
#: a ring of MAX_EVENTS_PER_JOB_PER_TICK * J rows can never drop an event.
MAX_EVENTS_PER_JOB_PER_TICK = 4


def lossless_ring_size(n_jobs: int) -> int:
    """Smallest per-tick ring capacity that can never overflow for a
    ``n_jobs``-row table (see MAX_EVENTS_PER_JOB_PER_TICK)."""
    return max(8, MAX_EVENTS_PER_JOB_PER_TICK * n_jobs)


class Event(NamedTuple):
    """One decoded lifecycle event (identical tuple on both backends)."""

    tick: int
    etype: int       # EventType code
    jid: int         # true job id (JobTable.jid / Job.id)
    arg: int         # per-type payload, see the schema table

    @property
    def name(self) -> str:
        return EventType(self.etype).name


class JobSnap(NamedTuple):
    """The pre-tick fields the diff rules read (Python backend)."""

    state: int
    submit: int
    n_preempt: int
    n_ckpt: int
    n_spill: int
    ckpt_tier: int


def snap(job: Job) -> JobSnap:
    return JobSnap(int(job.state), job.submit_time, job.n_preemptions,
                   job.n_checkpoints, job.n_spills, job.ckpt_tier)


def events_from_diff(pre: Dict[int, JobSnap], jobs: Dict[int, Job],
                     t: int) -> List[Event]:
    """Apply the schema table to one tick of the Python backend.

    ``pre`` maps job id -> `JobSnap` taken before the tick; ``jobs`` is the
    post-tick state.  Events come out in canonical ``(etype, jid)`` order —
    the same order `obs.jax_capture.decode_events` produces.
    """
    out: List[Event] = []
    ids = sorted(jobs)
    for jid in ids:                                    # EventType.SUBMIT
        p = pre[jid]
        if p.state == JobState.UNSUBMITTED and p.submit <= t:
            out.append(Event(t, EventType.SUBMIT, jid, jobs[jid].cpus))
    started = []
    for jid in ids:                                    # EventType.START
        j = jobs[jid]
        if j.state == JobState.RUNNING and j.run_start == t:
            out.append(Event(t, EventType.START, jid, j.cpus))
            started.append(jid)
    for jid in started:                                # EventType.RESTORE
        if pre[jid].n_ckpt > 0:
            out.append(Event(t, EventType.RESTORE, jid,
                             max(pre[jid].ckpt_tier, 0)))
    for jid in ids:                                    # EventType.EVICT
        if jobs[jid].n_preemptions > pre[jid].n_preempt:
            out.append(Event(t, EventType.EVICT, jid, jobs[jid].cpus))
    for jid in ids:                                    # EventType.SAVE
        if jobs[jid].n_checkpoints > pre[jid].n_ckpt:
            out.append(Event(t, EventType.SAVE, jid, jobs[jid].ckpt_tier))
    for jid in ids:                                    # EventType.SPILL
        if jobs[jid].n_spills > pre[jid].n_spill:
            out.append(Event(t, EventType.SPILL, jid, jobs[jid].ckpt_tier))
    for jid in ids:                                    # EventType.FINISH
        j = jobs[jid]
        if j.state == JobState.DONE and j.finish_time == t:
            out.append(Event(t, EventType.FINISH, jid, j.progress))
    for jid in ids:                                    # EventType.DEFER
        if jobs[jid].state == JobState.PENDING:
            out.append(Event(t, EventType.DEFER, jid, jobs[jid].cpus))
    return out


def canonical_sort(events: Iterable[Event]) -> List[Event]:
    """Cross-backend comparison order: ``(tick, etype, jid)``."""
    return sorted(events, key=lambda e: (e.tick, e.etype, e.jid))
