"""Metrics registry: counters / gauges / histograms derived from the event
log, with Prometheus text exposition and JSON snapshots.

Everything here is a pure function of ``(events, result)`` — no backend
branches: the Python bus and the decoded JAX ring produce the same events,
so `registry_from_result` produces the same scrape for either backend.
`launch.serve --sched-status` serves `MetricsRegistry.to_prometheus` on
``/metrics``; `benchmarks.bench_sched_scale` embeds `to_json` snapshots in
its ``BENCH_*.json`` rows.
"""
from __future__ import annotations

import math
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs.events import EVENT_TYPE_NAMES, Event, EventType

LabelItems = Tuple[Tuple[str, str], ...]

#: default histogram bucket upper bounds (ticks / counts)
DEFAULT_BUCKETS = (0.0, 1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0, 200.0, 500.0)


def _labels(labels: Optional[Dict[str, str]]) -> LabelItems:
    return tuple(sorted((k, str(v)) for k, v in (labels or {}).items()))


def _fmt_labels(items: LabelItems) -> str:
    if not items:
        return ""
    body = ",".join(f'{k}="{v}"' for k, v in items)
    return "{" + body + "}"


def _fmt_value(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    f = float(v)
    return str(int(f)) if f == int(f) else repr(f)


class _Metric:
    """One metric family: a kind, a help string, and labelled samples."""

    def __init__(self, name: str, kind: str, help_: str,
                 buckets: Sequence[float] = ()) -> None:
        self.name = name
        self.kind = kind
        self.help = help_
        self.buckets = tuple(buckets)
        self.samples: Dict[LabelItems, float] = {}
        # histogram state: per-labelset (bucket counts, sum, count)
        self.hist: Dict[LabelItems, Tuple[List[int], float, int]] = {}

    # -- writes ------------------------------------------------------------

    def inc(self, value: float = 1.0,
            labels: Optional[Dict[str, str]] = None) -> None:
        key = _labels(labels)
        self.samples[key] = self.samples.get(key, 0.0) + value

    def set(self, value: float,
            labels: Optional[Dict[str, str]] = None) -> None:
        self.samples[_labels(labels)] = float(value)

    def observe(self, value: float,
                labels: Optional[Dict[str, str]] = None) -> None:
        key = _labels(labels)
        if key not in self.hist:
            self.hist[key] = ([0] * len(self.buckets), 0.0, 0)
        counts, total, n = self.hist[key]
        for i, ub in enumerate(self.buckets):
            if value <= ub:
                counts[i] += 1
        self.hist[key] = (counts, total + float(value), n + 1)

    # -- exposition --------------------------------------------------------

    def expose(self) -> List[str]:
        lines = [f"# HELP {self.name} {self.help}",
                 f"# TYPE {self.name} {self.kind}"]
        if self.kind == "histogram":
            for key, (counts, total, n) in sorted(self.hist.items()):
                for ub, c in zip(self.buckets, counts):
                    items = key + (("le", _fmt_value(ub)),)
                    lines.append(
                        f"{self.name}_bucket{_fmt_labels(items)} {c}")
                items = key + (("le", "+Inf"),)
                lines.append(f"{self.name}_bucket{_fmt_labels(items)} {n}")
                lines.append(
                    f"{self.name}_sum{_fmt_labels(key)} {_fmt_value(total)}")
                lines.append(f"{self.name}_count{_fmt_labels(key)} {n}")
        else:
            for key, v in sorted(self.samples.items()):
                lines.append(
                    f"{self.name}{_fmt_labels(key)} {_fmt_value(v)}")
        return lines

    def to_json(self):
        if self.kind == "histogram":
            return {
                "kind": self.kind, "help": self.help,
                "buckets": list(self.buckets),
                "series": {
                    _fmt_labels(k) or "{}": {
                        "bucket_counts": list(c), "sum": s, "count": n}
                    for k, (c, s, n) in sorted(self.hist.items())
                },
            }
        return {
            "kind": self.kind, "help": self.help,
            "series": {_fmt_labels(k) or "{}": v
                       for k, v in sorted(self.samples.items())},
        }


class MetricsRegistry:
    """A named family of metrics with Prometheus/JSON exposition."""

    def __init__(self) -> None:
        self._metrics: Dict[str, _Metric] = {}

    def _get(self, name: str, kind: str, help_: str,
             buckets: Sequence[float] = ()) -> _Metric:
        m = self._metrics.get(name)
        if m is None:
            m = _Metric(name, kind, help_, buckets)
            self._metrics[name] = m
        elif m.kind != kind:
            raise ValueError(
                f"metric {name!r} already registered as {m.kind}, not {kind}")
        return m

    def counter(self, name: str, help_: str = "") -> _Metric:
        return self._get(name, "counter", help_)

    def gauge(self, name: str, help_: str = "") -> _Metric:
        return self._get(name, "gauge", help_)

    def histogram(self, name: str, help_: str = "",
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> _Metric:
        return self._get(name, "histogram", help_, buckets)

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def __getitem__(self, name: str) -> _Metric:
        return self._metrics[name]

    def to_prometheus(self) -> str:
        lines: List[str] = []
        for name in sorted(self._metrics):
            lines.extend(self._metrics[name].expose())
        return "\n".join(lines) + "\n"

    def to_json(self):
        return {name: m.to_json()
                for name, m in sorted(self._metrics.items())}


# ---------------------------------------------------------------------------
# Event log -> registry
# ---------------------------------------------------------------------------


def _job_info(result, users=None) -> Dict[int, Tuple[str, int]]:
    """jid -> (user label, cpus) from either backend's result.  The JAX
    table stores the user as an index into the users list the sim ran with
    (`omfs_jax.table_from_jobs`); passing ``users`` recovers the name, so
    per-user series carry the same labels on both backends."""
    if getattr(result, "sim", None) is not None:
        return {jid: (j.user, j.cpus)
                for jid, j in result.sim.state.jobs.items()}
    import jax

    names = [u.name for u in users] if users is not None else None
    t = jax.device_get(result.table)
    out = {}
    for jid, uidx, cpus in zip(np.asarray(t.jid), np.asarray(t.user),
                               np.asarray(t.cpus)):
        uidx = int(uidx)
        label = (names[uidx] if names is not None and uidx < len(names)
                 else f"u{uidx}")
        out[int(jid)] = (label, int(cpus))
    return out


def _user_spans(events: Iterable[Event], horizon: int,
                info: Dict[int, Tuple[str, int]]) -> Dict[str, int]:
    """Per-user executed cpu-ticks, integrated from START..EVICT/FINISH
    spans (open spans close at the horizon)."""
    open_at: Dict[int, int] = {}
    ticks: Dict[str, int] = {}
    for ev in events:
        if ev.etype == EventType.START:
            open_at[ev.jid] = ev.tick
        elif ev.etype in (EventType.EVICT, EventType.FINISH):
            t0 = open_at.pop(ev.jid, None)
            if t0 is not None and ev.jid in info:
                user, cpus = info[ev.jid]
                ticks[user] = ticks.get(user, 0) + (ev.tick - t0) * cpus
    for jid, t0 in open_at.items():
        if jid in info:
            user, cpus = info[jid]
            ticks[user] = ticks.get(user, 0) + (horizon - t0) * cpus
    return ticks


def registry_from_result(result, users=None) -> MetricsRegistry:
    """Derive the standard scheduler metrics from an instrumented
    `core.engine.EngineResult` (``record_events=True``).

    ``users`` (the `core.types.User` list the sim ran with) adds per-user
    entitlement gauges next to the realized shares; without it only the
    realized side is emitted.  Works identically for both backends — the
    registry reads nothing but the event log, the busy series, and the
    jid -> (user, cpus) map.
    """
    if result.events is None:
        raise ValueError(
            "result has no event log; run simulate(..., record_events=True)")
    reg = MetricsRegistry()
    events: List[Event] = result.events
    horizon = int(result.busy_series().size)
    info = _job_info(result, users)

    # -- event counters (from the exact counts matrix, drop-proof) ---------
    total = reg.counter("sched_events_total",
                        "Lifecycle events by type (exact, even on ring "
                        "overflow)")
    if result.event_counts is not None and len(result.event_counts):
        per_type = np.asarray(result.event_counts).sum(axis=0)
    else:
        per_type = np.zeros((len(EVENT_TYPE_NAMES),), np.int64)
        for ev in events:
            per_type[ev.etype] += 1
    for name, n in zip(EVENT_TYPE_NAMES, per_type):
        total.inc(int(n), {"type": name})
    reg.counter("sched_events_dropped_total",
                "Events lost to ring overflow (0 for lossless rings)"
                ).inc(result.events_dropped_total())

    # -- per-job churn histograms ------------------------------------------
    defers: Dict[int, int] = {}
    evicts: Dict[int, int] = {}
    submitted = set()
    started = set()
    for ev in events:          # canonical order: DEFER after same-tick START
        if ev.etype == EventType.SUBMIT:
            submitted.add(ev.jid)
        elif ev.etype == EventType.DEFER and ev.jid not in started:
            # only pre-first-start ticks count as wait; post-eviction
            # requeue ticks show up in the churn histogram instead
            defers[ev.jid] = defers.get(ev.jid, 0) + 1
        elif ev.etype == EventType.START:
            started.add(ev.jid)
        elif ev.etype == EventType.EVICT:
            evicts[ev.jid] = evicts.get(ev.jid, 0) + 1
    wait = reg.histogram("sched_wait_ticks",
                         "Ticks a job waited before first start "
                         "(pre-start DEFER count per started job)")
    for jid in sorted(started):
        wait.observe(defers.get(jid, 0))
    churn = reg.histogram("sched_evictions_per_job",
                          "Preemptions suffered per submitted job",
                          buckets=(0.0, 1.0, 2.0, 3.0, 5.0, 8.0, 13.0, 21.0))
    for jid in sorted(submitted):
        churn.observe(evicts.get(jid, 0))

    # -- checkpoint tier traffic + occupancy -------------------------------
    saves = reg.counter("sched_ckpt_saves_total",
                        "Checkpoints written, by placed tier")
    resident: Dict[int, int] = {}
    for ev in events:
        if ev.etype == EventType.SAVE:
            saves.inc(1, {"tier": str(ev.arg)})
            resident[ev.jid] = ev.arg
        elif ev.etype in (EventType.RESTORE, EventType.FINISH):
            resident.pop(ev.jid, None)
    reg.counter("sched_spills_total",
                "Checkpoints placed beyond the fast tier"
                ).inc(int(per_type[EventType.SPILL]))
    occ = reg.gauge("sched_tier_occupancy",
                    "Checkpoints resident per tier at end of run")
    by_tier: Dict[int, int] = {}
    for tier in resident.values():
        by_tier[tier] = by_tier.get(tier, 0) + 1
    for tier in sorted(by_tier):
        occ.set(by_tier[tier], {"tier": str(tier)})

    # -- fairness: realized share vs. entitlement --------------------------
    ticks = _user_spans(events, horizon, info)
    cap = max(result.config.cpu_total * max(horizon, 1), 1)
    share = reg.gauge("sched_user_share",
                      "Realized fraction of cluster cpu-ticks per user")
    used = reg.counter("sched_user_cpu_ticks_total",
                       "Executed cpu-ticks per user (from event spans)")
    for user in sorted(ticks):
        used.inc(ticks[user], {"user": user})
        share.set(ticks[user] / cap, {"user": user})
    if users is not None:
        ent = reg.gauge("sched_user_entitlement",
                        "Entitled fraction of the cluster per user")
        for u in users:
            ent.set(u.entitled_cpus(result.config.cpu_total)
                    / max(result.config.cpu_total, 1), {"user": u.name})

    reg.gauge("sched_utilization",
              "Mean busy fraction over the horizon"
              ).set(result.utilization())
    return reg
