"""In-scan event capture for the JAX backend: fixed shapes, zero retrace.

One jitted tick cannot append to a Python list, so the instrumented scan
captures three fixed-shape outputs per tick:

* ``counts[E]``  — exact per-type event counts (never lossy; the metrics
  registry and the DROPPED accounting are built on these),
* ``ring[R, 3]`` — a bounded per-tick event ring of ``(etype, jid, arg)``
  rows.  Events are laid out in (etype, table-row) order; each event's
  ring slot is its prefix position (cumsum of the flattened flag matrix),
  and events past the capacity R scatter with ``mode="drop"`` — dropped,
  never aliased,
* ``dropped``    — scalar: how many events did not fit this tick.  The
  engine surfaces it per tick; with ``R >= lossless_ring_size(J)`` it is
  provably always 0 (`obs.events.MAX_EVENTS_PER_JOB_PER_TICK`).

Everything is int32 on the device; `decode_events` reconstructs the typed
`Event` list host-side after the scan (one `device_get`, no per-tick host
sync) and applies the canonical ``(etype, jid)`` per-tick sort — the ring's
(etype, row) write order already equals it for monolithic tables (rows are
sorted by id) but not for the streaming engine's recycled slots.

The capture is a pure function of ``(pre, post, t)`` — the SAME diff rules
as the Python emitter (`obs.events`, schema table there).  It allocates no
new table columns and mutates nothing: the uninstrumented tick program is
byte-identical with instrumentation off (`repro.analysis` rule
``event-schema`` checks the confinement; the retrace audit checks the
instrumented runners compile exactly once).
"""
from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.omfs_jax import DONE, PENDING, RUNNING, UNSUB, JobTable
from repro.obs.events import Event, EventType, N_EVENT_TYPES

#: ring row layout
RING_FIELDS = ("etype", "jid", "arg")


def event_flags(pre: JobTable, post: JobTable, t: jax.Array
                ) -> Tuple[jax.Array, jax.Array]:
    """``(flags[E, J], args[E, J])`` for one tick diff — the schema table
    of `obs.events`, vectorized.  Row order = EventType code order, so the
    flattened matrix enumerates events in (etype, table-row) order."""
    start = (post.state == RUNNING) & (post.run_start == t)
    rules = {
        EventType.SUBMIT: ((pre.state == UNSUB) & (pre.submit <= t),
                           post.cpus),
        EventType.START: (start, post.cpus),
        EventType.RESTORE: (start & (pre.n_ckpt > 0),
                            jnp.maximum(pre.ckpt_tier, 0)),
        EventType.EVICT: (post.n_preempt > pre.n_preempt, post.cpus),
        EventType.SAVE: (post.n_ckpt > pre.n_ckpt, post.ckpt_tier),
        EventType.SPILL: (post.n_spill > pre.n_spill, post.ckpt_tier),
        EventType.FINISH: ((post.state == DONE) & (post.finish == t),
                           post.progress),
        EventType.DEFER: (post.state == PENDING, post.cpus),
    }
    assert len(rules) == N_EVENT_TYPES
    flags = jnp.stack([rules[EventType(e)][0] for e in range(N_EVENT_TYPES)])
    args = jnp.stack([jnp.asarray(rules[EventType(e)][1], jnp.int32)
                      for e in range(N_EVENT_TYPES)])
    return flags, args


def capture_tick(pre: JobTable, post: JobTable, t: jax.Array, ring_size: int
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One tick's ``(counts[E], ring[R, 3], dropped)`` — all int32, shapes
    static in ``ring_size``, so the instrumented scan compiles once."""
    flags, args = event_flags(pre, post, t)
    counts = jnp.sum(flags, axis=1, dtype=jnp.int32)
    flat = flags.reshape(-1)
    pos = jnp.cumsum(flat.astype(jnp.int32)) - 1
    # non-events and overflow both land out of bounds -> scattered with
    # mode="drop": dropped, never silently aliased onto a live slot
    slot = jnp.where(flat, pos, ring_size)
    etype = jnp.repeat(jnp.arange(N_EVENT_TYPES, dtype=jnp.int32),
                       pre.jid.shape[0])
    jid = jnp.tile(post.jid, N_EVENT_TYPES)
    rows = jnp.stack([etype, jid, args.reshape(-1)], axis=1)
    ring = jnp.full((ring_size, len(RING_FIELDS)), -1, jnp.int32)
    ring = ring.at[slot].set(rows, mode="drop")
    total = jnp.sum(counts)
    dropped = jnp.maximum(total - ring_size, 0)
    return counts, ring, dropped


def decode_events(counts, ring, dropped, t0: int = 0) -> List[Event]:
    """Host-side reader: scan outputs -> canonical per-tick-sorted Events.

    ``counts``: [T, E], ``ring``: [T, R, 3], ``dropped``: [T] (device or
    host arrays).  Ring slots are contiguous (an event's slot is its
    prefix position), so tick t's valid rows are
    ``ring[t, :min(counts[t].sum(), R)]``; they are re-sorted to the
    canonical (etype, jid) order before being emitted.
    """
    counts = np.asarray(counts)
    ring = np.asarray(ring)
    dropped = np.asarray(dropped)
    cap = ring.shape[1]
    out: List[Event] = []
    totals = counts.sum(axis=1)
    for t in range(counts.shape[0]):
        k = int(min(totals[t], cap))
        if k == 0:
            continue
        rows = ring[t, :k]
        order = np.lexsort((rows[:, 1], rows[:, 0]))   # (etype, jid)
        for e, j, a in rows[order]:
            out.append(Event(t0 + t, int(e), int(j), int(a)))
    return out
