"""EventBus: the Python-backend (and live-executor) event recorder.

The bus is an append-only log of `obs.events.Event` plus a subscriber
fan-out.  It does NOT invent its own capture semantics: `record_tick`
snapshots the job dict before the tick and applies the one shared diff
schema (`obs.events.events_from_diff`) after it — exactly what the JAX
backend's in-scan capture computes — so a bus-recorded log is directly
comparable (bit-identical) to a decoded device ring.
"""
from __future__ import annotations

from typing import Callable, Dict, Iterable, List, Optional

import numpy as np

from repro.core.types import Job
from repro.obs.events import (
    Event,
    JobSnap,
    N_EVENT_TYPES,
    events_from_diff,
    snap,
)

Subscriber = Callable[[Event], None]


class EventBus:
    """Append-only in-process event log with subscriber callbacks.

    The Python backend never drops events (there is no ring), so
    ``dropped`` is always a zero series — kept anyway so consumers can
    treat both backends' logs uniformly.
    """

    def __init__(self) -> None:
        self._events: List[Event] = []
        self._dropped: Dict[int, int] = {}
        self._subs: List[Subscriber] = []
        self._pre: Optional[Dict[int, JobSnap]] = None

    # -- recording ---------------------------------------------------------

    def subscribe(self, fn: Subscriber) -> None:
        self._subs.append(fn)

    def emit(self, events: Iterable[Event]) -> None:
        for ev in events:
            self._events.append(ev)
            for fn in self._subs:
                fn(ev)

    def snapshot(self, jobs: Dict[int, Job]) -> None:
        """Capture the pre-tick state (call just before the tick runs)."""
        self._pre = {jid: snap(j) for jid, j in jobs.items()}

    def record_tick(self, jobs: Dict[int, Job], t: int) -> List[Event]:
        """Diff the post-tick ``jobs`` against the last `snapshot` and emit
        the resulting events (canonical (etype, jid) order)."""
        if self._pre is None:
            raise RuntimeError("record_tick without a prior snapshot()")
        evs = events_from_diff(self._pre, jobs, t)
        self._pre = None
        self.emit(evs)
        return evs

    def record_dropped(self, t: int, n: int) -> None:
        """Account events lost at tick ``t`` (JAX ring overflow feeds this
        when a decoded log is replayed onto a bus)."""
        if n:
            self._dropped[t] = self._dropped.get(t, 0) + int(n)

    # -- reading -----------------------------------------------------------

    @property
    def events(self) -> List[Event]:
        return list(self._events)

    @property
    def dropped_total(self) -> int:
        return sum(self._dropped.values())

    def __len__(self) -> int:
        return len(self._events)

    def counts(self) -> np.ndarray:
        """Total events per type, shape [N_EVENT_TYPES]."""
        out = np.zeros((N_EVENT_TYPES,), np.int64)
        for ev in self._events:
            out[ev.etype] += 1
        return out

    def counts_matrix(self, horizon: int) -> np.ndarray:
        """Per-tick per-type counts, shape [horizon, N_EVENT_TYPES] — the
        Python twin of the JAX scan's counts output."""
        out = np.zeros((horizon, N_EVENT_TYPES), np.int64)
        for ev in self._events:
            if 0 <= ev.tick < horizon:
                out[ev.tick, ev.etype] += 1
        return out

    def dropped_series(self, horizon: int) -> np.ndarray:
        out = np.zeros((horizon,), np.int64)
        for t, n in self._dropped.items():
            if 0 <= t < horizon:
                out[t] += n
        return out

    def clear(self) -> None:
        self._events.clear()
        self._dropped.clear()
        self._pre = None
