"""Perfetto / Chrome ``trace_event`` exporter: any instrumented simulation
renders in chrome://tracing or ui.perfetto.dev as per-CPU-lane job spans
with eviction arrows.

Mapping (all derived from the event log — backend-agnostic):

* pid 0 is the cluster; tid ``k`` is CPU lane ``k`` (named ``cpu-NN`` via
  "M" metadata events).  1 tick = `US_PER_TICK` microseconds.
* a job run is one "X" complete span per lane it occupies, from START to
  the closing EVICT / FINISH (or the horizon, for jobs still running).
  Lanes are assigned first-fit per tick, releases before acquisitions —
  with ``cpu_total`` lanes this can never overflow, because the scheduler
  itself never over-commits CPUs.
* an eviction that later restarts emits a flow arrow ("s" at the EVICT,
  "f" at the restart span) with id = the job id — preemption churn is
  literally visible as arrows between lanes.
* "C" counter tracks: busy CPUs, pending (deferred) jobs, and — when a
  bounded ring overflowed — dropped events per tick, so lossy captures
  are impossible to mistake for quiet ones.

`validate_trace` is the CI gate for the smoke artifact: the JSON must
parse, spans must not overlap per lane, and every START must close with a
matching FINISH / EVICT (when the event log is supplied).
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.obs.events import Event, EventType

#: trace timebase: one scheduler tick = 1000 us, so tick counts read as ms
US_PER_TICK = 1000


def _lane_meta(n_lanes: int) -> List[dict]:
    out = [{"ph": "M", "pid": 0, "name": "process_name",
            "args": {"name": "cluster"}}]
    for k in range(n_lanes):
        out.append({"ph": "M", "pid": 0, "tid": k, "name": "thread_name",
                    "args": {"name": f"cpu-{k:02d}"}})
        out.append({"ph": "M", "pid": 0, "tid": k, "name": "thread_sort_index",
                    "args": {"sort_index": k}})
    return out


def trace_from_result(result, users=None) -> dict:
    """Build a Chrome ``trace_event`` dict from an instrumented
    `core.engine.EngineResult` (``record_events=True``)."""
    if result.events is None:
        raise ValueError(
            "result has no event log; run simulate(..., record_events=True)")
    from repro.obs.metrics import _job_info

    info = _job_info(result, users)
    horizon = int(result.busy_series().size)
    n_lanes = int(result.config.cpu_total)

    by_tick: Dict[int, List[Event]] = {}
    for ev in result.events:
        by_tick.setdefault(ev.tick, []).append(ev)

    free = list(range(n_lanes))          # first-fit lane pool (min-first)
    held: Dict[int, Tuple[int, List[int]]] = {}   # jid -> (start, lanes)
    evicted_at: Dict[int, Tuple[int, int]] = {}   # jid -> (tick, old lane)
    restored: set = set()                # jids whose next START is a restore
    spans: List[dict] = []
    flows: List[dict] = []

    def close(jid: int, t: int, reason: str) -> None:
        start, lanes = held.pop(jid)
        user, cpus = info.get(jid, ("?", len(lanes)))
        for lane in lanes:
            spans.append({
                "ph": "X", "pid": 0, "tid": lane, "cat": "job",
                "name": f"job {jid}", "ts": start * US_PER_TICK,
                "dur": max(t - start, 0) * US_PER_TICK,
                "args": {"jid": jid, "user": user, "cpus": cpus,
                         "end": reason,
                         "restored": jid in restored},
            })
        free.extend(lanes)
        free.sort()

    for t in sorted(by_tick):
        evs = by_tick[t]
        # releases before acquisitions: a tick may evict A to admit B into
        # the very same CPUs
        for ev in evs:
            if ev.etype == EventType.EVICT and ev.jid in held:
                old_lane = held[ev.jid][1][0]
                close(ev.jid, t, "evict")
                evicted_at[ev.jid] = (t, old_lane)
            elif ev.etype == EventType.FINISH and ev.jid in held:
                close(ev.jid, t, "finish")
        for ev in evs:
            if ev.etype == EventType.RESTORE:
                restored.add(ev.jid)
        for ev in evs:
            if ev.etype != EventType.START or ev.jid in held:
                continue
            cpus = info.get(ev.jid, ("?", max(ev.arg, 1)))[1]
            take, rest = free[:cpus], free[cpus:]
            if len(take) < cpus:      # defensive; the scheduler prevents it
                extra = n_lanes + len(held)
                take = take + list(range(extra, extra + cpus - len(take)))
                rest = []
            free[:] = rest
            held[ev.jid] = (t, take)
            src = evicted_at.pop(ev.jid, None)
            if src is not None:       # eviction arrow: old lane -> new lane
                src_t, src_lane = src
                flows.append({"ph": "s", "pid": 0, "tid": src_lane,
                              "cat": "preemption", "name": "evict",
                              "id": ev.jid, "ts": src_t * US_PER_TICK})
                flows.append({"ph": "f", "pid": 0, "tid": take[0],
                              "cat": "preemption", "name": "evict",
                              "id": ev.jid, "ts": t * US_PER_TICK,
                              "bp": "e"})
        restored = {j for j in restored if j in held}

    for jid in list(held):            # still running at the horizon
        close(jid, horizon, "horizon")

    counters: List[dict] = []
    busy = result.busy_series()
    for t in range(horizon):
        counters.append({"ph": "C", "pid": 0, "name": "busy_cpus",
                         "ts": t * US_PER_TICK,
                         "args": {"busy": int(busy[t])}})
    if result.event_counts is not None and len(result.event_counts):
        pend = np.asarray(result.event_counts)[:, int(EventType.DEFER)]
        for t in range(min(horizon, pend.shape[0])):
            counters.append({"ph": "C", "pid": 0, "name": "pending_jobs",
                             "ts": t * US_PER_TICK,
                             "args": {"pending": int(pend[t])}})
    if result.events_dropped is not None:
        drp = np.asarray(result.events_dropped)
        for t in np.flatnonzero(drp):
            counters.append({"ph": "C", "pid": 0, "name": "events_dropped",
                             "ts": int(t) * US_PER_TICK,
                             "args": {"dropped": int(drp[t])}})

    return {
        "displayTimeUnit": "ms",
        "otherData": {"policy": result.policy, "backend": result.backend,
                      "horizon_ticks": horizon,
                      "events_dropped": result.events_dropped_total()},
        "traceEvents": _lane_meta(n_lanes) + spans + flows + counters,
    }


def validate_trace(trace, events: Optional[List[Event]] = None) -> List[str]:
    """Return a list of validity errors (empty = valid).

    Checks: the trace JSON-serializes and parses back; "X" spans do not
    overlap within a (pid, tid) lane; flow arrows pair up ("s" and "f" per
    id); and — when the source event log is supplied — every START is
    closed by a matching FINISH or EVICT or survives to the horizon with a
    span of the same job.
    """
    errors: List[str] = []
    try:
        trace = json.loads(json.dumps(trace))
    except (TypeError, ValueError) as exc:
        return [f"trace does not round-trip as JSON: {exc}"]
    evs = trace.get("traceEvents")
    if not isinstance(evs, list):
        return ["traceEvents missing or not a list"]

    lanes: Dict[Tuple[int, int], List[Tuple[int, int, str]]] = {}
    for ev in evs:
        if ev.get("ph") == "X":
            key = (ev.get("pid", 0), ev.get("tid", 0))
            if ev.get("dur", 0) < 0:
                errors.append(f"negative duration span: {ev.get('name')}")
            lanes.setdefault(key, []).append(
                (ev["ts"], ev["ts"] + ev.get("dur", 0), ev.get("name", "?")))
    for key, spans in lanes.items():
        spans.sort()
        for (s0, e0, n0), (s1, e1, n1) in zip(spans, spans[1:]):
            if s1 < e0:
                errors.append(
                    f"overlap on lane {key}: {n0!r} [{s0},{e0}) vs "
                    f"{n1!r} [{s1},{e1})")

    starts = {(e.get("cat"), e.get("id")) for e in evs if e.get("ph") == "s"}
    ends = {(e.get("cat"), e.get("id")) for e in evs if e.get("ph") == "f"}
    for key in starts - ends:
        errors.append(f"flow {key} started but never finished")
    for key in ends - starts:
        errors.append(f"flow {key} finished but never started")

    if events is not None:
        open_jobs: Dict[int, int] = {}
        for ev in events:
            if ev.etype == EventType.START:
                if ev.jid in open_jobs:
                    errors.append(f"job {ev.jid} started twice without "
                                  f"close (ticks {open_jobs[ev.jid]}, "
                                  f"{ev.tick})")
                open_jobs[ev.jid] = ev.tick
            elif ev.etype in (EventType.EVICT, EventType.FINISH):
                open_jobs.pop(ev.jid, None)
        spanned = {e["args"].get("jid") for e in evs
                   if e.get("ph") == "X" and isinstance(e.get("args"), dict)}
        for jid in open_jobs:
            if jid not in spanned:
                errors.append(
                    f"job {jid} STARTed but has no span and no close event")
    return errors


# ---------------------------------------------------------------------------
# CLI: python -m repro.obs.trace --out trace.json
# ---------------------------------------------------------------------------


def main(argv: Optional[List[str]] = None) -> int:
    p = argparse.ArgumentParser(
        description="Export a Perfetto/Chrome trace of a simulated schedule")
    p.add_argument("--policy", default="omfs")
    p.add_argument("--backend", default="jax", choices=("python", "jax"))
    p.add_argument("--users", type=int, default=3)
    p.add_argument("--horizon", type=int, default=200)
    p.add_argument("--cpus", type=int, default=32)
    p.add_argument("--jobs", type=int, default=40)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--out", default="trace.json")
    p.add_argument("--validate", action="store_true",
                   help="exit nonzero unless the exported trace validates")
    args = p.parse_args(argv)

    from repro.core import engine
    from repro.core.types import SchedulerConfig
    from repro.core.workload import WorkloadSpec, make_jobs, make_users

    spec = WorkloadSpec(n_users=args.users, horizon=args.horizon,
                        cpu_total=args.cpus, seed=args.seed,
                        arrival_rate=0.12, mean_work=30,
                        class_mix=(0.15, 0.35, 0.5))
    users = make_users(spec)
    jobs = make_jobs(spec, users)[:args.jobs]
    cfg = SchedulerConfig(cpu_total=args.cpus, quantum=4, cr_overhead=2)
    result = engine.simulate(users, jobs, cfg, args.horizon,
                             policy=args.policy, backend=args.backend,
                             record_events=True)
    trace = trace_from_result(result, users=users)
    with open(args.out, "w") as fh:
        json.dump(trace, fh)
    n_spans = sum(1 for e in trace["traceEvents"] if e.get("ph") == "X")
    print(f"wrote {args.out}: {len(trace['traceEvents'])} trace events "
          f"({n_spans} spans, {len(result.events)} lifecycle events, "
          f"{result.events_dropped_total()} dropped)")
    if args.validate:
        errors = validate_trace(trace, events=result.events)
        for err in errors:
            print(f"INVALID: {err}")
        if errors:
            return 1
        print("trace valid: spans non-overlapping per lane, flows paired, "
              "all starts closed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
