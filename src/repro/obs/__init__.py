"""Scheduler observability: event bus, metrics registry, trace export.

The layer is event-sourced: both engine backends record the SAME typed
per-job lifecycle events (`obs.events.EventType`), defined once as rules
over the tick-boundary state diff — the Python backend walks the job dict
(`obs.events.events_from_diff`), the JAX backend captures them *inside*
the jitted scan with fixed shapes and zero retrace
(`obs.jax_capture.capture_tick`) and decodes host-side after the scan.
Everything downstream — the metrics registry (`obs.metrics`), the
Perfetto/Chrome trace exporter (`obs.trace`), the fairness audit — is a
pure function of the event log, so it is backend-agnostic by construction
(DESIGN.md §Observability).
"""
from repro.obs.bus import EventBus
from repro.obs.events import (
    EVENT_TYPE_NAMES,
    MAX_EVENTS_PER_JOB_PER_TICK,
    N_EVENT_TYPES,
    Event,
    EventType,
    canonical_sort,
    events_from_diff,
    lossless_ring_size,
)
from repro.obs.metrics import MetricsRegistry, registry_from_result
from repro.obs.profile import ProfileTimers
from repro.obs.trace import trace_from_result, validate_trace

__all__ = [
    "EVENT_TYPE_NAMES",
    "MAX_EVENTS_PER_JOB_PER_TICK",
    "N_EVENT_TYPES",
    "Event",
    "EventBus",
    "EventType",
    "MetricsRegistry",
    "ProfileTimers",
    "canonical_sort",
    "events_from_diff",
    "lossless_ring_size",
    "registry_from_result",
    "trace_from_result",
    "validate_trace",
]
