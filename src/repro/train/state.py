"""TrainState: everything a transparent checkpoint must capture.

The paper's "transparent C/R" maps to: (params, optimizer state, step, RNG,
data-iterator cursor) — restoring this tuple and re-entering the train loop
is bitwise-equivalent to never having been preempted (tested in
tests/test_e2e_train.py).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.optim import adamw


class TrainState(NamedTuple):
    params: Any
    opt: adamw.AdamWState
    rng: jax.Array          # PRNG key consumed by dropout-like features
    data_cursor: jax.Array  # [] int64-ish int32 cursor into the data stream

    @property
    def step(self) -> jax.Array:
        return self.opt.step


def init_train_state(params, seed: int = 0) -> TrainState:
    return TrainState(
        params=params,
        opt=adamw.init(params),
        rng=jax.random.PRNGKey(seed),
        data_cursor=jnp.zeros((), jnp.int32),
    )


def train_state_shapes(model, seed: int = 0):
    """ShapeDtypeStruct pytree of the full state (dry-run, no allocation)."""
    return jax.eval_shape(
        lambda: init_train_state(model.init(jax.random.PRNGKey(0)), seed)
    )
