"""Training and serving step functions (the units the scheduler preempts at).

``make_train_step`` builds a jit-able ``(state, batch) -> (state, metrics)``
with optional gradient accumulation (scan over microbatches — bounds
activation memory at large global batch) and gradient clipping.  All model
compute runs in the config's compute dtype; master params/optimizer in fp32.

``make_prefill_step`` / ``make_decode_step`` are the serving entry points the
decode/prefill dry-run cells lower.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.optim import adamw
from repro.train.state import TrainState


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    grad_accum: int = 1            # microbatches per step (scan)


def make_train_step(model: Model, tcfg: TrainConfig) -> Callable:
    lr_fn = adamw.cosine_schedule(tcfg.lr, tcfg.warmup_steps, tcfg.total_steps)

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        accum = tcfg.grad_accum

        if accum == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                state.params, batch)
        else:
            # split the global batch into `accum` microbatches and scan;
            # gradients accumulate in fp32.
            def micro(batch_i, carry):
                g_acc, loss_acc, aux_acc = carry
                (loss, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    state.params, batch_i)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return g_acc, loss_acc + loss, aux_acc + metrics["aux_loss"]

            def split(x):
                b = x.shape[0]
                return x.reshape(accum, b // accum, *x.shape[1:])

            micro_batches = jax.tree.map(split, batch)
            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)

            def body(carry, mb):
                return micro(mb, carry), None

            (g_sum, loss_sum, aux_sum), _ = jax.lax.scan(
                body, (g0, jnp.zeros(()), jnp.zeros(())), micro_batches)
            grads = jax.tree.map(lambda g: g / accum, g_sum)
            loss = loss_sum / accum
            metrics = {"ce_loss": loss, "aux_loss": aux_sum / accum,
                       "tokens": jnp.zeros(())}

        grads, gnorm = adamw.clip_by_global_norm(grads, tcfg.clip_norm)
        lr = lr_fn(state.opt.step)
        new_params, new_opt = adamw.update(
            state.params, grads, state.opt, lr=lr,
            b1=tcfg.b1, b2=tcfg.b2, weight_decay=tcfg.weight_decay)
        new_state = TrainState(
            params=new_params, opt=new_opt,
            rng=jax.random.fold_in(state.rng, 1),
            data_cursor=state.data_cursor + 1,
        )
        out_metrics = {
            "loss": loss, "grad_norm": gnorm, "lr": lr,
            "step": new_opt.step.astype(jnp.float32),
            **{k: v for k, v in metrics.items() if k != "tokens"},
        }
        return new_state, out_metrics

    return train_step


def make_prefill_step(model: Model) -> Callable:
    def prefill_step(params, batch, cache):
        return model.prefill(params, batch, cache)

    return prefill_step


def make_decode_step(model: Model) -> Callable:
    def decode_step(params, cache, tokens):
        return model.decode_step(params, cache, tokens)

    return decode_step
