"""Event-schema contract: declared ⟺ emitted ⟺ consumed, and the capture
stays out of the uninstrumented hot path.

The observability layer's one schema (`repro.obs.events.EventType`) has
THREE implementations that must stay in lockstep: the Python diff emitter
(`events_from_diff`), the JAX in-scan capture (`obs.jax_capture`'s flag
matrix), and the downstream consumers (metrics registry + trace exporter).
A type added to the enum but missing from any of them is a silent hole in
the telemetry — counts matrices and rings are indexed by enum code, so
nothing crashes, the events just never exist.

Two checks, both static (AST over the source tree, no imports — so the
fixture tests can run them against broken trees):

* **event-schema** — every ``EventType`` member is referenced by the
  Python emitter body, by the JAX flag builder, and by at least one
  consumer (obs/metrics.py or obs/trace.py); conversely every
  ``EventType.X`` attribute reference anywhere in src/repro names a
  declared member.
* **confinement** (same rule id) — the uninstrumented tick path in
  core/engine.py (`_tick_step`, `tick_jax`, and the four plain jitted
  runners) must not reference the obs layer, and the scheduler kernels
  (omfs.py / omfs_jax.py / policies_jax.py / baselines.py) must not import
  ``repro.obs`` at all: events are defined over the tick-boundary diff,
  never emitted from inside a pass — that is what keeps the uninstrumented
  program byte-identical and the backends' logs bit-equal.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.base import Violation, register

EVENTS = Path("src/repro/obs/events.py")
JAX_CAPTURE = Path("src/repro/obs/jax_capture.py")
CONSUMERS = (Path("src/repro/obs/metrics.py"), Path("src/repro/obs/trace.py"))
ENGINE = Path("src/repro/core/engine.py")
SRC = Path("src/repro")

#: engine functions that make up the UNINSTRUMENTED hot path; their
#: instrumented twins (`*_events`) are exactly the ones allowed to capture
HOT_PATH_FNS = ("tick_jax", "_tick_step", "_jitted_runner",
                "_jitted_matrix_runner", "_jitted_batch_runner",
                "_jitted_segment_runner")

#: scheduler kernels that must never import the obs layer
KERNEL_FILES = (Path("src/repro/core/omfs.py"),
                Path("src/repro/core/omfs_jax.py"),
                Path("src/repro/core/policies_jax.py"),
                Path("src/repro/core/baselines.py"))

#: names that unmistakably belong to the obs capture layer
OBS_TOKENS = {"obs", "jax_capture", "capture_tick", "EventBus",
              "events_from_diff"}


def _parse(path: Path) -> Optional[ast.AST]:
    try:
        return ast.parse(path.read_text())
    except (OSError, SyntaxError):
        return None


def _declared_events(tree: ast.AST) -> Dict[str, int]:
    """EventType member -> lineno, from the enum class body."""
    out: Dict[str, int] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == "EventType":
            for stmt in node.body:
                if isinstance(stmt, ast.Assign):
                    for tgt in stmt.targets:
                        if isinstance(tgt, ast.Name):
                            out[tgt.id] = stmt.lineno
    return out


def _etype_refs(tree: ast.AST, within: Optional[str] = None
                ) -> Set[Tuple[str, int]]:
    """``EventType.X`` attribute references — optionally only inside the
    function named ``within``."""
    scopes: List[ast.AST] = [tree]
    if within is not None:
        scopes = [n for n in ast.walk(tree)
                  if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
                  and n.name == within]
    refs: Set[Tuple[str, int]] = set()
    for scope in scopes:
        for node in ast.walk(scope):
            if (isinstance(node, ast.Attribute)
                    and isinstance(node.value, ast.Name)
                    and node.value.id == "EventType"):
                refs.add((node.attr, node.lineno))
    return refs


def _names_in(refs: Set[Tuple[str, int]]) -> Set[str]:
    return {name for name, _ in refs}


@register(
    "event-schema", "project",
    "every EventType is emitted by both backends and consumed downstream; "
    "capture stays out of the uninstrumented tick path and the kernels")
def check_event_schema(root: Path) -> List[Violation]:
    out: List[Violation] = []
    events_path = root / EVENTS
    events_tree = _parse(events_path)
    if events_tree is None:
        return [Violation("event-schema", str(events_path), 1,
                          "obs/events.py missing or unparseable — the event "
                          "schema must live there")]
    declared = _declared_events(events_tree)
    if not declared:
        return [Violation("event-schema", str(events_path), 1,
                          "no EventType members declared")]

    # -- declared => emitted (python): referenced in events_from_diff -------
    py_emitted = _names_in(_etype_refs(events_tree, within="events_from_diff"))
    # -- declared => emitted (jax): referenced in the flag-matrix builder ---
    cap_tree = _parse(root / JAX_CAPTURE)
    jx_emitted = (_names_in(_etype_refs(cap_tree, within="event_flags"))
                  if cap_tree is not None else set())
    if cap_tree is None:
        out.append(Violation(
            "event-schema", str(root / JAX_CAPTURE), 1,
            "obs/jax_capture.py missing or unparseable — the JAX backend "
            "has no in-scan emitter"))
    # -- declared => consumed: referenced by metrics or trace ---------------
    consumed: Set[str] = set()
    for rel in CONSUMERS:
        tree = _parse(root / rel)
        if tree is not None:
            consumed |= _names_in(_etype_refs(tree))

    for name, line in sorted(declared.items()):
        if name not in py_emitted:
            out.append(Violation(
                "event-schema", str(events_path), line,
                f"EventType.{name} is declared but events_from_diff never "
                "references it — the Python backend cannot emit it"))
        if cap_tree is not None and name not in jx_emitted:
            out.append(Violation(
                "event-schema", str(root / JAX_CAPTURE), 1,
                f"EventType.{name} is declared but the jax flag matrix "
                "(event_flags) never references it — the JAX backend "
                "cannot emit it"))
        if name not in consumed:
            out.append(Violation(
                "event-schema", str(events_path), line,
                f"EventType.{name} is declared and emitted but neither the "
                "metrics registry nor the trace exporter consumes it"))

    # -- referenced => declared: no phantom event types anywhere ------------
    for py in sorted((root / SRC).rglob("*.py")):
        tree = _parse(py)
        if tree is None:
            continue
        for name, line in sorted(_etype_refs(tree)):
            if name not in declared and name.isupper():
                out.append(Violation(
                    "event-schema", str(py), line,
                    f"EventType.{name} referenced but not declared in "
                    "obs/events.py"))

    # -- confinement: the uninstrumented engine hot path stays capture-free -
    engine_tree = _parse(root / ENGINE)
    if engine_tree is not None:
        for node in ast.walk(engine_tree):
            if not isinstance(node, ast.FunctionDef):
                continue
            if node.name not in HOT_PATH_FNS:
                continue
            for sub in ast.walk(node):
                hit = None
                if isinstance(sub, ast.Name) and sub.id in OBS_TOKENS:
                    hit = sub
                elif (isinstance(sub, ast.Attribute)
                      and sub.attr in OBS_TOKENS):
                    hit = sub
                elif (isinstance(sub, ast.ImportFrom) and sub.module
                      and "obs" in sub.module.split(".")):
                    hit = sub
                if hit is not None:
                    out.append(Violation(
                        "event-schema", str(root / ENGINE), hit.lineno,
                        f"uninstrumented hot-path function {node.name!r} "
                        "references the obs capture layer — instrumentation "
                        "must stay in the *_events twins so the plain "
                        "program is byte-identical"))
                    break

    # -- confinement: scheduler kernels never import repro.obs --------------
    for rel in KERNEL_FILES:
        tree = _parse(root / rel)
        if tree is None:
            continue
        for node in ast.walk(tree):
            mod = None
            if isinstance(node, ast.ImportFrom):
                mod = node.module or ""
            elif isinstance(node, ast.Import):
                mod = " ".join(a.name for a in node.names)
            if mod and "obs" in mod.replace(".", " ").split():
                out.append(Violation(
                    "event-schema", str(root / rel), node.lineno,
                    "scheduler kernel imports repro.obs — events are "
                    "tick-boundary diffs recorded OUTSIDE the passes; "
                    "in-pass emission breaks cross-backend bit-equality"))
    return out
