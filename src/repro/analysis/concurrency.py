"""Concurrency lint for the checkpoint/executor thread boundary.

The async-checkpoint contract (`checkpoint.async_writer`) runs the durable
write on a background thread while the trainer keeps mutating state on the
main thread.  Two rules police that boundary:

* **thread-shared-state** — attributes of a class reachable off-thread
  (a method submitted to a ``ThreadPoolExecutor``, passed as a ``Thread``
  target, or handed to ``AsyncCheckpointer`` as its ``write_fn``) that are
  mutated without holding a lock, while other methods of the same class
  access the same attribute from the caller thread.  Also: in a class that
  owns a lock, an attribute mutated under ``with self._lock`` somewhere
  must not be mutated bare elsewhere (outside ``__init__``).
* **lock-order** — two locks acquired nested in one order at one site and
  the opposite order at another (the classic ABBA deadlock).

The analysis is cross-file within the handed file set: `manager.py` wires
``AsyncCheckpointer(self.disk.save_leaves)`` where ``self.disk`` is a
`DiskTier` from `tiers.py`, so the off-thread entry point resolution
follows ``self.<attr> = ClassName(...)`` assignments across modules.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.base import SourceFile, Violation, register, tail

#: directories the project-level concurrency audit covers
CONCURRENCY_DIRS = ("src/repro/checkpoint", "src/repro/cluster")
LOCK_CTORS = {"Lock", "RLock", "Condition", "Semaphore", "BoundedSemaphore"}
#: callables whose first argument (or ``target=``) runs on another thread
ASYNC_SINK_CALLS = {"submit", "Thread", "AsyncCheckpointer", "apply_async"}


class _ClassInfo:
    def __init__(self, name: str, sf: SourceFile, node: ast.ClassDef):
        self.name = name
        self.sf = sf
        self.node = node
        self.locks: Set[str] = set()            # self.<attr> lock attributes
        self.methods: Dict[str, ast.FunctionDef] = {}
        self.attr_class: Dict[str, str] = {}    # self.<attr> = ClassName(...)
        self.off_thread: Set[str] = set()       # methods reachable off-thread


def _self_chain(node: ast.expr) -> Optional[List[str]]:
    """['stats', 'saves'] for ``self.stats.saves``; None if not self-rooted."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name) and node.id == "self":
        return list(reversed(parts))
    return None


def _collect_classes(files: List[SourceFile]) -> Dict[str, _ClassInfo]:
    classes: Dict[str, _ClassInfo] = {}
    for sf in files:
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            info = _ClassInfo(node.name, sf, node)
            for item in node.body:
                if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    info.methods[item.name] = item
            for sub in ast.walk(node):
                if not isinstance(sub, ast.Assign):
                    continue
                for tgt in sub.targets:
                    chain = _self_chain(tgt)
                    if chain is None or len(chain) != 1:
                        continue
                    if isinstance(sub.value, ast.Call):
                        ctor = tail(sub.value.func)
                        if ctor in LOCK_CTORS:
                            info.locks.add(chain[0])
                        elif ctor:
                            info.attr_class[chain[0]] = ctor
            classes[node.name] = info
    return classes


def _resolve_callable(expr: ast.expr, cls: Optional[_ClassInfo],
                      classes: Dict[str, _ClassInfo]
                      ) -> Optional[Tuple[str, str]]:
    """(class_name, method_name) a callable expression points at."""
    chain = _self_chain(expr)
    if chain and cls is not None:
        if len(chain) == 1 and chain[0] in cls.methods:
            return (cls.name, chain[0])
        if len(chain) == 2 and chain[0] in cls.attr_class:
            target = cls.attr_class[chain[0]]
            if target in classes and chain[1] in classes[target].methods:
                return (target, chain[1])
    return None


def _mark_off_thread(files: List[SourceFile],
                     classes: Dict[str, _ClassInfo]) -> None:
    for sf in files:
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.ClassDef) or node.name not in classes:
                continue
            cls = classes[node.name]
            for sub in ast.walk(node):
                if not isinstance(sub, ast.Call):
                    continue
                if tail(sub.func) not in ASYNC_SINK_CALLS:
                    continue
                cands = list(sub.args[:1]) + [
                    kw.value for kw in sub.keywords
                    if kw.arg in ("target", "fn", "write_fn")]
                for cand in cands:
                    hit = _resolve_callable(cand, cls, classes)
                    if hit is not None:
                        classes[hit[0]].off_thread.add(hit[1])
    # close over same-class self.method() calls from off-thread methods
    for cls in classes.values():
        work = list(cls.off_thread)
        while work:
            m = work.pop()
            fn = cls.methods.get(m)
            if fn is None:
                continue
            for sub in ast.walk(fn):
                if isinstance(sub, ast.Call):
                    chain = _self_chain(sub.func)
                    if (chain and len(chain) == 1
                            and chain[0] in cls.methods
                            and chain[0] not in cls.off_thread):
                        cls.off_thread.add(chain[0])
                        work.append(chain[0])


def _with_lock_names(stmt: ast.With, cls: _ClassInfo) -> Set[str]:
    out = set()
    for item in stmt.items:
        chain = _self_chain(item.context_expr)
        if chain and len(chain) == 1 and (
                chain[0] in cls.locks or "lock" in chain[0].lower()):
            out.add(chain[0])
    return out


def _walk_mutations(fn: ast.AST, cls: _ClassInfo):
    """Yield (attr, node, held_locks) for every ``self.<attr>...`` mutation."""

    def walk(body, held: frozenset):
        for stmt in body:
            if isinstance(stmt, ast.With):
                walk(stmt.body, held | _with_lock_names(stmt, cls))
                continue
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            targets = []
            if isinstance(stmt, ast.Assign):
                targets = stmt.targets
            elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
                targets = [stmt.target]
            for tgt in targets:
                for t in ([tgt] if not isinstance(tgt, (ast.Tuple, ast.List))
                          else tgt.elts):
                    chain = _self_chain(t)
                    if chain:
                        yield chain[0], stmt, held
            for attr in ("body", "orelse", "finalbody"):
                sub = getattr(stmt, attr, None)
                if sub and not isinstance(stmt, ast.With):
                    yield from walk(sub, held)
            for h in getattr(stmt, "handlers", []):
                yield from walk(h.body, held)

    yield from walk(fn.body, frozenset())


def _collect_lock_edges(body, held: Tuple[str, ...], cls: _ClassInfo,
                        edges: Dict[Tuple[str, str], Tuple[str, int]]) -> None:
    """Record (outer_lock, inner_lock) acquisition pairs per with-nesting."""
    for stmt in body:
        if isinstance(stmt, ast.With):
            cur = held
            for n in sorted(_with_lock_names(stmt, cls)):
                q = f"{cls.name}.{n}"
                for h in cur:
                    edges.setdefault((h, q), (str(cls.sf.path), stmt.lineno))
                cur = cur + (q,)
            _collect_lock_edges(stmt.body, cur, cls, edges)
            continue
        for attr in ("body", "orelse", "finalbody"):
            sub = getattr(stmt, attr, None)
            if sub:
                _collect_lock_edges(sub, held, cls, edges)
        for h in getattr(stmt, "handlers", []):
            _collect_lock_edges(h.body, held, cls, edges)


def _attr_accesses(fn: ast.AST) -> Set[str]:
    out = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Attribute):
            chain = _self_chain(node)
            if chain:
                out.add(chain[0])
    return out


def analyze_concurrency(files: List[SourceFile]) -> List[Violation]:
    out: List[Violation] = []
    classes = _collect_classes(files)
    _mark_off_thread(files, classes)

    for cls in classes.values():
        path = str(cls.sf.path)
        # attributes mutated off-thread without a lock, shared with other
        # methods of the class
        if cls.off_thread:
            shared_attrs: Set[str] = set()
            for m in cls.off_thread:
                fn = cls.methods.get(m)
                if fn is None:
                    continue
                for attr, _node, _held in _walk_mutations(fn, cls):
                    others = [n for n, f in cls.methods.items()
                              if n not in cls.off_thread and n != "__init__"
                              and attr in _attr_accesses(f)]
                    if others:
                        shared_attrs.add(attr)
            for name, fn in cls.methods.items():
                if name == "__init__":
                    continue
                for attr, node, held in _walk_mutations(fn, cls):
                    if attr in shared_attrs and not held:
                        where = ("runs on the checkpoint writer thread"
                                 if name in cls.off_thread
                                 else "races the writer thread")
                        out.append(Violation(
                            "thread-shared-state", path, node.lineno,
                            f"{cls.name}.{name} mutates shared "
                            f"`self.{attr}` without holding a lock "
                            f"({where}; `self.{attr}` is reached from "
                            "both sides of the async-write boundary)"))
        # lock-guarded attributes mutated bare elsewhere
        guarded: Set[str] = set()
        for fn in cls.methods.values():
            for attr, _node, held in _walk_mutations(fn, cls):
                if held:
                    guarded.add(attr)
        if guarded:
            for name, fn in cls.methods.items():
                if name == "__init__":
                    continue
                for attr, node, held in _walk_mutations(fn, cls):
                    if attr in guarded and not held and attr not in cls.locks:
                        out.append(Violation(
                            "thread-shared-state", path, node.lineno,
                            f"{cls.name}.{name} mutates `self.{attr}` "
                            "without the lock that guards it elsewhere in "
                            "the class"))

    # -- lock acquisition order --------------------------------------------
    edges: Dict[Tuple[str, str], Tuple[str, int]] = {}
    for cls in classes.values():
        for fn in cls.methods.values():
            _collect_lock_edges(fn.body, (), cls, edges)
    for (a, b), (path, line) in sorted(edges.items()):
        if (b, a) in edges and a < b:
            other = edges[(b, a)]
            out.append(Violation(
                "lock-order", path, line,
                f"inconsistent lock order: {a} -> {b} here but "
                f"{b} -> {a} at {other[0]}:{other[1]} — ABBA deadlock"))
    return out


@register(
    "thread-shared-state", "project",
    "shared mutable state crosses the async-checkpoint thread boundary "
    "without its lock")
def check_thread_shared_state(root: Path) -> List[Violation]:
    files = _concurrency_files(root)
    return [v for v in analyze_concurrency(files)
            if v.rule == "thread-shared-state"]


@register(
    "lock-order", "project",
    "locks acquired in contradictory nesting orders (ABBA deadlock)")
def check_lock_order(root: Path) -> List[Violation]:
    files = _concurrency_files(root)
    return [v for v in analyze_concurrency(files) if v.rule == "lock-order"]


def _concurrency_files(root: Path) -> List[SourceFile]:
    files = []
    for d in CONCURRENCY_DIRS:
        for py in sorted((root / d).glob("*.py")):
            files.append(SourceFile(py))
    return files
