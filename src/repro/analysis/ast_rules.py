"""AST lint rules specific to this repo's JAX scheduler contracts.

Three invariants the test suite cannot see but the AST can:

* **tracer-leak** — Python control flow (``if``/``while``/``and``/``not``)
  or host conversions (``int()``/``bool()``/``float()``/``.item()``) applied
  to values derived from `JobTable` columns or ``jnp``/``lax`` ops inside a
  traced context.  Under ``jit`` these either raise ``TracerBoolConversion``
  at runtime on a rarely-taken path or silently bake a traced value into a
  Python constant at trace time.
* **host-sync** — ``np.asarray``/``np.array``/``jax.device_get``/
  ``.block_until_ready()`` inside a jitted pass or a ``lax`` loop body:
  a hidden device->host transfer that serializes the hot loop.
* **cost-grid** — a float literal, true division ``/``, or float cast
  flowing into the integer /256 cost grid (the ``cost_*``/``state_mib``/
  ``overhead`` columns and the `CRCostModel` evaluation functions).  The
  grid is what keeps the Python and JAX backends bit-identical; one stray
  float breaks cross-backend equality without failing any unit test.

Plus **mutable-default** (the classic shared-default-argument bug), so the
analyzer holds the line even where ruff is not installed.

Traced contexts are discovered syntactically:

* functions decorated ``@jax.jit`` / ``@partial(jax.jit, ...)`` (params
  tainted except literal ``static_argnames``) — *strict* contexts;
* callbacks passed to ``jax.lax.{fori_loop,while_loop,scan,cond,switch,
  map,associative_scan}`` (all params tainted) — *strict* contexts;
* functions taking a `JobTable` parameter (``tbl``/``table`` or an
  annotation naming ``JobTable``) — *soft* contexts: the table is tainted
  but host syncs are allowed, and ``jax.device_get``/``np.asarray`` launder
  taint (these helpers legitimately run host-side, e.g. signatures).

``.shape``/``.dtype``/``.ndim``/``.size`` of a traced value are static at
trace time and do not propagate taint.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from repro.analysis.base import SourceFile, Violation, dotted, register, tail

LAX_LOOPS = {"fori_loop", "while_loop", "scan", "cond", "switch", "map",
             "associative_scan"}
SHAPE_ATTRS = {"shape", "dtype", "ndim", "size"}
TABLE_PARAMS = {"tbl", "table"}
TABLE_ANNOS = {"JobTable"}
TAINT_ROOTS = ("jnp.", "lax.", "jax.lax.", "jax.ops.", "jax.nn.")
LAUNDER_CALLS = {"jax.device_get", "np.asarray", "np.array", "device_get"}
SYNC_CALLS = {"np.asarray", "np.array", "jax.device_get", "device_get"}
HOST_CONVERSIONS = {"int", "bool", "float"}
# the /256 integer cost grid: JobTable columns priced by core.crcost —
# the [J, T] lattice columns plus the legacy view accessors over them
GRID_NAMES = {"cost_save_lat", "cost_rsave_lat", "cost_restore_lat",
              "cost_save", "cost_restore", "cost_save2", "cost_restore2",
              "state_mib", "overhead"}
# CRCostModel evaluation path: must stay integer end-to-end (calibration
# boundaries like from_measured/measured_delta_num/ticks_from_seconds take
# floats on purpose)
GRID_FUNCTIONS = {"_cost", "save_cost", "recurrent_save_cost",
                  "restore_cost", "compressed_mib", "delta_mib",
                  "_ceil_div", "_saturate", "state_mib_of", "choose_tier",
                  "feasible", "eviction_save_cost", "restart_restore_cost",
                  "effective_save_lat", "tier_occupancy",
                  # the fused victim-select/placement kernel family charges
                  # the same grid (save costs, state_mib occupancy) — one
                  # float in the kernel would break lax/pallas bit-equality
                  "sched_select_kernel", "plan_evictions_fused",
                  "plan_evictions_ref", "plan_evictions"}


# ---------------------------------------------------------------------------
# Traced-context discovery
# ---------------------------------------------------------------------------


def _is_jit_decorator(dec: ast.expr) -> Optional[ast.Call]:
    """Return a Call carrying jit kwargs when ``dec`` is a jit decorator."""
    if tail(dec) == "jit":
        return ast.Call(func=dec, args=[], keywords=[])
    if isinstance(dec, ast.Call) and tail(dec.func) == "jit":
        return dec
    if isinstance(dec, ast.Call) and tail(dec.func) == "partial":
        if any(tail(a) == "jit" for a in dec.args):
            return dec
    return None


def _static_argnames(call: ast.Call) -> Set[str]:
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            if isinstance(kw.value, (ast.Tuple, ast.List)):
                return {e.value for e in kw.value.elts
                        if isinstance(e, ast.Constant)
                        and isinstance(e.value, str)}
            if isinstance(kw.value, ast.Constant) and isinstance(
                    kw.value.value, str):
                return {kw.value.value}
    return set()


def _table_params(fn) -> Set[str]:
    names = set()
    for a in fn.args.args + fn.args.kwonlyargs + fn.args.posonlyargs:
        anno = getattr(a, "annotation", None)
        anno_s = ""
        if anno is not None:
            anno_s = dotted(anno) or (
                anno.value if isinstance(anno, ast.Constant) else "")
        if a.arg in TABLE_PARAMS or any(t in str(anno_s) for t in TABLE_ANNOS):
            names.add(a.arg)
    return names


def _all_params(fn) -> Set[str]:
    args = fn.args
    out = {a.arg for a in args.args + args.kwonlyargs + args.posonlyargs}
    if args.vararg:
        out.add(args.vararg.arg)
    if args.kwarg:
        out.add(args.kwarg.arg)
    return out


def _lax_callback_ids(tree: ast.AST) -> Set[int]:
    """ids of FunctionDef/Lambda nodes passed to lax control-flow calls."""
    by_name: Dict[str, List[ast.AST]] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            by_name.setdefault(node.name, []).append(node)
    out: Set[int] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and tail(node.func) in LAX_LOOPS:
            d = dotted(node.func) or ""
            if not (d.startswith(("jax.", "lax.")) or d in LAX_LOOPS):
                continue
            for arg in list(node.args) + [k.value for k in node.keywords]:
                if isinstance(arg, ast.Lambda):
                    out.add(id(arg))
                elif isinstance(arg, ast.Name) and arg.id in by_name:
                    for fn in by_name[arg.id]:
                        out.add(id(fn))
                elif isinstance(arg, (ast.List, ast.Tuple)):   # switch branches
                    for e in arg.elts:
                        if isinstance(e, ast.Lambda):
                            out.add(id(e))
                        elif isinstance(e, ast.Name) and e.id in by_name:
                            for fn in by_name[e.id]:
                                out.add(id(fn))
    return out


def _find_contexts(tree: ast.AST) -> List[tuple]:
    """Top-level traced contexts as (fn_node, strict, tainted_params).

    Nested FunctionDefs inside another context are walked by their parent
    (inheriting closure taint) and are not returned separately.
    """
    callbacks = _lax_callback_ids(tree)
    contexts: List[tuple] = []
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            jit = None
            for dec in node.decorator_list:
                jit = jit or _is_jit_decorator(dec)
            if jit is not None:
                static = _static_argnames(jit) | {"cfg", "config"}
                contexts.append((node, True, _all_params(node) - static))
            elif id(node) in callbacks:
                contexts.append((node, True, _all_params(node)))
            else:
                tp = _table_params(node)
                if tp:
                    contexts.append((node, False, tp))
        elif isinstance(node, ast.Lambda) and id(node) in callbacks:
            contexts.append((node, True, _all_params(node)))
    # drop contexts nested inside another context (parent walk covers them,
    # with closure taint the standalone analysis would miss)
    ctx_nodes = [c[0] for c in contexts]
    nested: Set[int] = set()
    for fn in ctx_nodes:
        for sub in ast.walk(fn):
            if sub is not fn and isinstance(
                    sub, (ast.FunctionDef, ast.AsyncFunctionDef)) and any(
                    sub is c for c in ctx_nodes):
                nested.add(id(sub))
    return [(fn, s, t) for fn, s, t in contexts if id(fn) not in nested]


# ---------------------------------------------------------------------------
# Taint propagation + sink detection within one context
# ---------------------------------------------------------------------------


class _Taint:
    def __init__(self, sf: SourceFile, strict: bool, tainted: Set[str],
                 out: List[Violation], callbacks: Set[int]):
        self.sf = sf
        self.strict = strict
        self.tainted = set(tainted)
        self.out = out
        self.callbacks = callbacks

    # -- expression taint ---------------------------------------------------
    def is_tainted(self, e: ast.expr) -> bool:
        if isinstance(e, ast.Name):
            return e.id in self.tainted
        if isinstance(e, ast.Attribute):
            if e.attr in SHAPE_ATTRS:
                return False
            return self.is_tainted(e.value)
        if isinstance(e, ast.Subscript):
            return self.is_tainted(e.value) or self.is_tainted(e.slice)
        if isinstance(e, ast.Call):
            d = dotted(e.func)
            if d in LAUNDER_CALLS:
                return False                     # explicit host transfer
            if d and (d.startswith(TAINT_ROOTS) or d.split(".")[0] in
                      ("jnp", "lax")):
                return True
            if self.is_tainted(e.func):
                return True
            return any(self.is_tainted(a) for a in e.args) or any(
                self.is_tainted(k.value) for k in e.keywords)
        if isinstance(e, ast.BinOp):
            return self.is_tainted(e.left) or self.is_tainted(e.right)
        if isinstance(e, ast.UnaryOp):
            return self.is_tainted(e.operand)
        if isinstance(e, ast.Compare):
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in e.ops):
                return False      # `x is None` is static at trace time
            return self.is_tainted(e.left) or any(
                self.is_tainted(c) for c in e.comparators)
        if isinstance(e, ast.BoolOp):
            return any(self.is_tainted(v) for v in e.values)
        if isinstance(e, ast.IfExp):
            return self.is_tainted(e.body) or self.is_tainted(e.orelse)
        if isinstance(e, (ast.Tuple, ast.List)):
            return any(self.is_tainted(x) for x in e.elts)
        if isinstance(e, ast.Starred):
            return self.is_tainted(e.value)
        return False

    # -- sinks --------------------------------------------------------------
    def _flag(self, rule: str, node: ast.AST, msg: str):
        self.out.append(Violation(rule, str(self.sf.path), node.lineno, msg))

    def check_expr_sinks(self, e: ast.expr):
        for node in ast.walk(e):
            if isinstance(node, ast.Lambda):
                continue
            if isinstance(node, ast.Call):
                d = dotted(node.func)
                fn_tail = tail(node.func)
                args_tainted = any(self.is_tainted(a) for a in node.args)
                if (isinstance(node.func, ast.Name)
                        and node.func.id in HOST_CONVERSIONS and args_tainted):
                    self._flag(
                        "tracer-leak", node,
                        f"{node.func.id}() applied to a traced value inside "
                        "a jitted context — bakes the tracer into a Python "
                        "scalar (or raises ConcretizationTypeError)")
                if (fn_tail == "item" and isinstance(node.func, ast.Attribute)
                        and self.is_tainted(node.func.value)):
                    self._flag(
                        "tracer-leak", node,
                        ".item() on a traced value inside a jitted context — "
                        "forces a device sync and breaks tracing")
                if self.strict and (
                        d in SYNC_CALLS
                        or (fn_tail == "block_until_ready"
                            and isinstance(node.func, ast.Attribute))):
                    self._flag(
                        "host-sync", node,
                        f"hidden host sync ({d or fn_tail}) inside a jitted "
                        "context / lax loop body — serializes the hot loop")
            elif isinstance(node, ast.BoolOp):
                if any(self.is_tainted(v) for v in node.values):
                    op = "and" if isinstance(node.op, ast.And) else "or"
                    self._flag(
                        "tracer-leak", node,
                        f"Python `{op}` over a traced value — use `&`/`|` "
                        "(jnp.logical_*) inside jitted code")
            elif (isinstance(node, ast.UnaryOp)
                  and isinstance(node.op, ast.Not)
                  and self.is_tainted(node.operand)):
                self._flag(
                    "tracer-leak", node,
                    "Python `not` on a traced value — use `~` inside "
                    "jitted code")

    # -- statement walk -----------------------------------------------------
    def _assign_names(self, target: ast.expr) -> List[str]:
        if isinstance(target, ast.Name):
            return [target.id]
        if isinstance(target, (ast.Tuple, ast.List)):
            out = []
            for e in target.elts:
                out.extend(self._assign_names(e))
            return out
        return []

    def run(self, body: List[ast.stmt]):
        # propagation passes to fixpoint (names assigned late in a loop body
        # taint earlier uses on the next iteration), then one checking pass
        for _ in range(4):
            before = set(self.tainted)
            self._walk(body, check=False)
            if self.tainted == before:
                break
        self._walk(body, check=True)

    def _walk(self, body: List[ast.stmt], check: bool):
        for stmt in body:
            self._stmt(stmt, check)

    def _stmt(self, stmt: ast.stmt, check: bool):
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # nested function: traced callback params are tainted; the body
            # inherits the enclosing closure's taint.  strictness upgrades
            # when the nested fn is a lax callback.
            strict = self.strict or id(stmt) in self.callbacks
            sub = _Taint(self.sf, strict,
                         self.tainted | _all_params(stmt),
                         self.out if check else [], self.callbacks)
            sub._walk(stmt.body, check)
            return
        if isinstance(stmt, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            value = stmt.value
            if value is None:
                return
            if check:
                self.check_expr_sinks(value)
            t = self.is_tainted(value)
            targets = (stmt.targets if isinstance(stmt, ast.Assign)
                       else [stmt.target])
            for tgt in targets:
                for name in self._assign_names(tgt):
                    if t:
                        self.tainted.add(name)
                    elif not isinstance(stmt, ast.AugAssign):
                        self.tainted.discard(name)
            return
        if isinstance(stmt, (ast.If, ast.While)):
            if check:
                if self.is_tainted(stmt.test):
                    kind = "if" if isinstance(stmt, ast.If) else "while"
                    self._flag(
                        "tracer-leak", stmt,
                        f"Python `{kind}` on a traced value (derived from a "
                        "JobTable column or a jnp/lax op) inside a jitted "
                        "context — use jnp.where / lax.cond")
                self.check_expr_sinks(stmt.test)
            self._walk(stmt.body, check)
            self._walk(stmt.orelse, check)
            return
        if isinstance(stmt, ast.Assert):
            if check:
                if self.is_tainted(stmt.test):
                    self._flag(
                        "tracer-leak", stmt,
                        "Python `assert` on a traced value inside a jitted "
                        "context — use checkify or move the check host-side")
                self.check_expr_sinks(stmt.test)
            return
        if isinstance(stmt, ast.For):
            if check:
                self.check_expr_sinks(stmt.iter)
            if self.is_tainted(stmt.iter):
                for name in self._assign_names(stmt.target):
                    self.tainted.add(name)
            self._walk(stmt.body, check)
            self._walk(stmt.orelse, check)
            return
        if isinstance(stmt, (ast.Return, ast.Expr)):
            if check and stmt.value is not None:
                self.check_expr_sinks(stmt.value)
            return
        if isinstance(stmt, ast.With):
            if check:
                for item in stmt.items:
                    self.check_expr_sinks(item.context_expr)
            self._walk(stmt.body, check)
            return
        if isinstance(stmt, ast.Try):
            self._walk(stmt.body, check)
            for h in stmt.handlers:
                self._walk(h.body, check)
            self._walk(stmt.orelse, check)
            self._walk(stmt.finalbody, check)
            return


def _run_taint(sf: SourceFile) -> List[Violation]:
    out: List[Violation] = []
    callbacks = _lax_callback_ids(sf.tree)
    for fn, strict, tainted in _find_contexts(sf.tree):
        if isinstance(fn, ast.Lambda):
            body = [ast.Expr(value=fn.body, lineno=fn.lineno, col_offset=0)]
        else:
            body = fn.body
        _Taint(sf, strict, tainted, out, callbacks).run(body)
    return out


@register(
    "tracer-leak", "file",
    "Python control flow / host conversions on traced JobTable values "
    "inside jitted contexts")
def check_tracer_leak(sf: SourceFile) -> List[Violation]:
    return [v for v in _run_taint(sf) if v.rule == "tracer-leak"]


@register(
    "host-sync", "file",
    "np.asarray / device_get / block_until_ready inside jitted contexts")
def check_host_sync(sf: SourceFile) -> List[Violation]:
    return [v for v in _run_taint(sf) if v.rule == "host-sync"]


def _contains_float_or_div(expr: ast.expr) -> Optional[ast.AST]:
    for node in ast.walk(expr):
        if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Div):
            return node
        if isinstance(node, ast.Constant) and isinstance(node.value, float):
            return node
        if isinstance(node, ast.Call):
            if isinstance(node.func, ast.Name) and node.func.id == "float":
                return node
            if (tail(node.func) == "astype" and node.args
                    and "float" in str(dotted(node.args[0]) or "")):
                return node
        if isinstance(node, ast.Attribute) and node.attr in (
                "float32", "float64", "float16", "bfloat16"):
            return node
    return None


@register(
    "cost-grid", "file",
    "float literals / true division / float casts reaching the /256 "
    "integer cost grid (cost_* columns, CRCostModel evaluation)")
def check_cost_grid(sf: SourceFile) -> List[Violation]:
    out: List[Violation] = []

    def flag(node: ast.AST, where: str):
        out.append(Violation(
            "cost-grid", str(sf.path), node.lineno,
            f"float/true-division reaches the integer /256 cost grid "
            f"({where}) — use integer arithmetic "
            "(`(a + b - 1) // b` for ceil) so both backends stay "
            "bit-identical"))

    for node in ast.walk(sf.tree):
        # writes into grid-named columns/keywords (JobTable(...), _replace,
        # update_state_mib scatters, plain assignments)
        if isinstance(node, ast.Call):
            for kw in node.keywords:
                if kw.arg in GRID_NAMES:
                    bad = _contains_float_or_div(kw.value)
                    if bad is not None:
                        flag(bad, f"keyword `{kw.arg}`")
        elif isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            targets = (node.targets if isinstance(node, ast.Assign)
                       else [node.target])
            names = {tail(t) for t in targets}
            hit = names & GRID_NAMES
            if hit and node.value is not None:
                bad = _contains_float_or_div(node.value)
                if bad is not None:
                    flag(bad, f"assignment to `{sorted(hit)[0]}`")
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if node.name in GRID_FUNCTIONS:
                for sub in ast.walk(node):
                    if isinstance(sub, ast.BinOp) and isinstance(
                            sub.op, ast.Div):
                        flag(sub, f"cost function `{node.name}`")
                    elif isinstance(sub, ast.Constant) and isinstance(
                            sub.value, float):
                        flag(sub, f"cost function `{node.name}`")
    return out


@register("mutable-default", "file",
          "mutable default argument shared across calls")
def check_mutable_default(sf: SourceFile) -> List[Violation]:
    out: List[Violation] = []
    mutable_calls = {"list", "dict", "set", "OrderedDict", "defaultdict"}
    for node in ast.walk(sf.tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
            continue
        for default in list(node.args.defaults) + [
                d for d in node.args.kw_defaults if d is not None]:
            bad = isinstance(default, (ast.List, ast.Dict, ast.Set)) or (
                isinstance(default, ast.Call)
                and tail(default.func) in mutable_calls)
            if bad:
                name = getattr(node, "name", "<lambda>")
                out.append(Violation(
                    "mutable-default", str(sf.path), default.lineno,
                    f"mutable default argument in `{name}` is shared across "
                    "calls — default to None and construct inside"))
    return out
