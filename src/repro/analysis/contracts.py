"""Backend-contract drift checks over the live policy registry + JobTable.

Two contracts hold the two-backend design together (DESIGN.md §Engine):

* **backend-contract** — every policy registered in `core.engine.POLICIES`
  must carry BOTH a Python pass and a JAX-pass factory that actually
  produce callables, and must be exercised by the cross-backend property
  suite (`tests/test_policies_equivalence.py`).  A policy added to the
  registry without an equivalence test is exactly how the backends drift
  apart silently.
* **column-dataflow** — every `JobTable` column written by
  `table_from_jobs` must be consumed (attribute-read) somewhere in
  ``src/repro``, and every column name passed to ``JobTable(...)`` /
  ``tbl._replace(...)`` must be a declared field.  A written-never-read
  column is dead state bloating the fixed-size table; a read-never-written
  column is a latent AttributeError.

These import the live modules (registry contents are runtime data), so they
run as *project* rules against the repo root.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import List

from repro.analysis.base import SourceFile, Violation, register

EQUIV_TEST = Path("tests/test_policies_equivalence.py")
OMFS_JAX = Path("src/repro/core/omfs_jax.py")
ENGINE = Path("src/repro/core/engine.py")
SRC = Path("src/repro")


def _test_covers_registry(test_src: str) -> bool:
    """True when the equivalence suite derives its policy list from the
    registry itself (``engine.POLICIES``) — then every future policy is
    covered by construction."""
    tree = ast.parse(test_src)
    for node in ast.walk(tree):
        if isinstance(node, ast.Attribute) and node.attr == "POLICIES":
            return True
        if isinstance(node, ast.Name) and node.id == "POLICIES":
            return True
    return False


@register(
    "backend-contract", "project",
    "every registered policy has a Python pass, a JAX factory, and "
    "equivalence-test coverage")
def check_backend_contract(root: Path) -> List[Violation]:
    out: List[Violation] = []
    from repro.core import engine

    engine_path = str(root / ENGINE)
    for name, spec in sorted(engine.POLICIES.items()):
        if not callable(spec.python_pass):
            out.append(Violation(
                "backend-contract", engine_path, 1,
                f"policy {name!r}: python_pass is not callable"))
        try:
            jax_pass = spec.jax_factory(None)
        except Exception as e:  # registry entry must build without args
            out.append(Violation(
                "backend-contract", engine_path, 1,
                f"policy {name!r}: jax_factory(None) raised {e!r}"))
            continue
        if not callable(jax_pass):
            out.append(Violation(
                "backend-contract", engine_path, 1,
                f"policy {name!r}: jax_factory(None) returned a "
                "non-callable"))

    test_path = root / EQUIV_TEST
    if not test_path.exists():
        out.append(Violation(
            "backend-contract", str(test_path), 1,
            "cross-backend equivalence suite is missing"))
        return out
    test_src = test_path.read_text()
    if not _test_covers_registry(test_src):
        for name in sorted(engine.POLICIES):
            if f'"{name}"' not in test_src and f"'{name}'" not in test_src:
                out.append(Violation(
                    "backend-contract", str(test_path), 1,
                    f"policy {name!r} is registered in core/engine.py but "
                    "never exercised by the Python-vs-JAX equivalence suite "
                    "(parametrize over engine.POLICIES or name it "
                    "explicitly)"))
    return out


def _jobtable_fields(root: Path) -> List[str]:
    from repro.core.omfs_jax import JobTable
    return list(JobTable._fields)


@register(
    "column-dataflow", "project",
    "every JobTable column built by table_from_jobs is consumed somewhere, "
    "and every written column is a declared field")
def check_column_dataflow(root: Path) -> List[Violation]:
    out: List[Violation] = []
    fields = set(_jobtable_fields(root))
    omfs_jax_path = root / OMFS_JAX

    # -- writes: keywords of JobTable(...) and *._replace(...) --------------
    built_in_table_from_jobs: set = set()
    for py in sorted((root / SRC).rglob("*.py")):
        try:
            sf = SourceFile(py)
        except SyntaxError:
            continue
        enclosing_fn = {}
        for fn in ast.walk(sf.tree):
            if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for sub in ast.walk(fn):
                    enclosing_fn.setdefault(id(sub), fn.name)
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            is_ctor = isinstance(node.func, ast.Name) and \
                node.func.id == "JobTable"
            is_replace = isinstance(node.func, ast.Attribute) and \
                node.func.attr == "_replace"
            if not (is_ctor or is_replace):
                continue
            for kw in node.keywords:
                if kw.arg is None:
                    continue
                if kw.arg not in fields and is_ctor:
                    out.append(Violation(
                        "column-dataflow", str(py), kw.value.lineno,
                        f"JobTable(...) writes unknown column {kw.arg!r} — "
                        "not a declared field"))
                if (is_ctor and enclosing_fn.get(id(node)) ==
                        "table_from_jobs"):
                    built_in_table_from_jobs.add(kw.arg)

    missing_init = fields - built_in_table_from_jobs
    if built_in_table_from_jobs and missing_init:
        out.append(Violation(
            "column-dataflow", str(omfs_jax_path), 1,
            f"JobTable column(s) {sorted(missing_init)} are declared but "
            "never initialized by table_from_jobs"))

    # -- reads: tbl.<col> attribute loads anywhere in src/repro -------------
    consumed: set = set()
    for py in sorted((root / SRC).rglob("*.py")):
        try:
            tree = ast.parse(py.read_text())
        except SyntaxError:
            continue
        skip_ranges = []
        if py == omfs_jax_path:
            # the class declaration and the constructor call in
            # table_from_jobs are writes, not consumption
            for node in ast.walk(tree):
                if isinstance(node, ast.ClassDef) and node.name == "JobTable":
                    skip_ranges.append((node.lineno, node.end_lineno))
        for node in ast.walk(tree):
            if isinstance(node, ast.Attribute) and isinstance(
                    node.ctx, ast.Load) and node.attr in fields:
                if any(a <= node.lineno <= b for a, b in skip_ranges):
                    continue
                consumed.add(node.attr)

    for col in sorted(fields - consumed):
        out.append(Violation(
            "column-dataflow", str(omfs_jax_path), 1,
            f"JobTable column {col!r} is written by table_from_jobs but "
            "never read anywhere in src/repro — dead state in the "
            "fixed-size table"))

    # -- migration guard: the legacy two-column accessors must stay views
    # over the [J, T] lattice (DESIGN.md §Cost lattice), never fields —
    # re-declaring one would silently fork the cost state
    legacy = {"cost_save", "cost_save2", "cost_restore", "cost_restore2"}
    for name in sorted(legacy & fields):
        out.append(Violation(
            "column-dataflow", str(omfs_jax_path), 1,
            f"legacy cost accessor {name!r} re-declared as a JobTable "
            "field — it must remain a read-only view over cost_save_lat/"
            "cost_restore_lat"))
    return out
