"""Jaxpr auditor: trace the jitted passes and assert what the AST can't see.

Three trace-level invariants:

* **jaxpr-float-cast** — tracing every registered policy pass (tiered
  config, so placement machinery is live) must produce NO
  ``convert_element_type`` from an integer to a floating dtype, and every
  output `JobTable` column must still be integer-typed.  A float sneaking
  into the /256 cost grid mid-pass rounds differently than the Python
  backend's integer arithmetic — schedules drift without a test failing.
* **branch-confinement** — in the incremental OMFS passes the expensive
  eviction machinery (the victim ``sort``/lexsort and the placement
  ``scan``) must stay confined under a ``lax.cond``/``switch`` branch
  inside the per-queue-position loop.  Hoisted onto the always-taken path
  it still produces identical schedules — only ~10x slower (the whole
  point of the incremental pass, ROADMAP "11k ticks/s").
* **retrace** — the compile-counter harness: a second
  ``engine.simulate`` / ``engine.simulate_matrix`` call with same-shaped
  inputs, and a tick after ``update_state_mib``, must all hit the
  compilation cache (``_cache_size() == 1``).  A retrace per tick/call
  silently turns throughput into compile time.

The audit builds one small deterministic workload (J=12, a T=3 cost
lattice with tight fast tiers so spilling actually happens, and
delta-aware recurrent-save coefficients so both lattice columns are live)
and traces the real registered passes — no fixtures, no mocks.

Every trace rule runs the passes under BOTH kernel-dispatch paths
(``SchedulerConfig.kernel_backend`` "lax" and "pallas_interpret"): the
float-cast walk descends into the ``pallas_call`` sub-jaxpr, so the fused
`kernels.sched_select` kernel is held to the same integer-grid bar, the
confinement rule additionally requires the kernel call itself to sit
behind the eviction ``cond``, and the retrace harness asserts that
toggling the flag lands on separately cached runners (each compiled
exactly once) instead of retracing one.
"""
from __future__ import annotations

from pathlib import Path
from typing import Dict, List, Optional

from repro.analysis.base import Violation, register

ENGINE = "src/repro/core/engine.py"
OMFS_JAX = "src/repro/core/omfs_jax.py"

#: policies whose per-queue-position loop must keep eviction machinery
#: behind a cond (backfill's once-per-tick reservation sort is by design)
CONFINED_POLICIES = ("omfs", "omfs_cheap_victim")

_FIXTURE_CACHE: Dict[str, object] = {}


def _fixture():
    """(users, jobs, cfg, tbl, ent) — small, deterministic, tiered."""
    if "fx" in _FIXTURE_CACHE:
        return _FIXTURE_CACHE["fx"]
    from repro.core import omfs_jax
    from repro.core.crcost import CRCostModel, TieredCRCostModel, UNBOUNDED
    from repro.core.types import SchedulerConfig
    from repro.core.workload import WorkloadSpec, make_jobs, make_users

    spec = WorkloadSpec(n_users=3, horizon=40, cpu_total=16, seed=7,
                        arrival_rate=0.3, mean_work=12,
                        class_mix=(0.1, 0.2, 0.7))
    users = make_users(spec)
    jobs = make_jobs(spec, users)[:12]
    tiers = TieredCRCostModel(
        tiers=(CRCostModel(save_mib_per_tick=256, restore_mib_per_tick=256,
                           delta_num=141, delta_den=256),
               CRCostModel(save_mib_per_tick=64, restore_mib_per_tick=64,
                           delta_num=182, delta_den=256),
               CRCostModel(save_mib_per_tick=32, restore_mib_per_tick=32,
                           save_base=1, restore_base=1,
                           delta_num=182, delta_den=256)),
        capacity_mib=(48, 96, UNBOUNDED))
    cfg = SchedulerConfig(cpu_total=16, quantum=2, cr_overhead=1,
                          cr_tiers=tiers)
    tbl, ent = omfs_jax.table_from_jobs(jobs, users, cfg.cpu_total, cfg)
    _FIXTURE_CACHE["fx"] = (users, jobs, cfg, tbl, ent)
    return _FIXTURE_CACHE["fx"]


#: the two kernel-dispatch paths every trace rule audits
BACKENDS = ("lax", "pallas_interpret")


def _with_backend(cfg, backend: str):
    import dataclasses
    return cfg if backend == "lax" else dataclasses.replace(
        cfg, kernel_backend=backend)


# ---------------------------------------------------------------------------
# jaxpr walking
# ---------------------------------------------------------------------------


def _sub_jaxprs(eqn):
    """(param_name, jaxpr) pairs for every sub-jaxpr of an equation."""
    import jax.core as jcore

    out = []
    for k, v in eqn.params.items():
        vals = v if isinstance(v, (list, tuple)) else [v]
        for x in vals:
            if isinstance(x, jcore.ClosedJaxpr):
                out.append((k, x.jaxpr))
            elif isinstance(x, jcore.Jaxpr):
                out.append((k, x))
    return out


def _walk_eqns(jaxpr, path=()):
    """Yield (eqn, path) for every equation, path = primitive-name ancestry."""
    for eqn in jaxpr.eqns:
        yield eqn, path
        for _, sub in _sub_jaxprs(eqn):
            yield from _walk_eqns(sub, path + (eqn.primitive.name,))


def _trace_pass(name: str, backend: str = "lax"):
    """ClosedJaxpr of one registered policy pass over the fixture table,
    under the requested ``kernel_backend`` dispatch path."""
    import jax

    from repro.core import engine
    _, _, cfg, tbl, ent = _fixture()
    cfg = _with_backend(cfg, backend)
    pass_fn = engine.POLICIES[name].jax_factory(None)

    def run(tbl, t):
        return pass_fn(cfg, ent, t, tbl)

    import jax.numpy as jnp
    t0 = jnp.int32(3)
    return jax.make_jaxpr(run)(tbl, t0)


def _is_float(dtype) -> bool:
    import numpy as np
    return np.issubdtype(dtype, np.floating)


def _is_int(dtype) -> bool:
    import numpy as np
    return np.issubdtype(dtype, np.integer) or np.issubdtype(dtype, np.bool_)


@register(
    "jaxpr-float-cast", "trace",
    "no int->float convert_element_type inside any policy pass; JobTable "
    "cost/occupancy columns stay integer end-to-end")
def check_float_casts(root: Path) -> List[Violation]:
    out: List[Violation] = []
    from repro.core import engine

    for name in sorted(engine.POLICIES):
        for backend in BACKENDS:
            closed = _trace_pass(name, backend)
            for eqn, _path in _walk_eqns(closed.jaxpr):
                if eqn.primitive.name != "convert_element_type":
                    continue
                new = eqn.params.get("new_dtype")
                src = eqn.invars[0].aval.dtype if eqn.invars else None
                if new is not None and _is_float(new) and (
                        src is None or _is_int(src)):
                    out.append(Violation(
                        "jaxpr-float-cast", str(root / ENGINE), 1,
                        f"policy {name!r} ({backend}): traced pass converts "
                        f"{src} -> {new} — a float entering the integer "
                        "cost grid breaks cross-backend bit-equality"))
            for aval in closed.out_avals:
                if hasattr(aval, "dtype") and _is_float(aval.dtype):
                    out.append(Violation(
                        "jaxpr-float-cast", str(root / ENGINE), 1,
                        f"policy {name!r} ({backend}): pass output column "
                        f"has floating dtype {aval.dtype}; JobTable columns "
                        "must stay integer"))
    return out


@register(
    "branch-confinement", "trace",
    "victim sort + placement scan stay under lax.cond in the incremental "
    "OMFS passes (not hoisted onto the always-taken path)")
def check_branch_confinement(root: Path) -> List[Violation]:
    out: List[Violation] = []
    loops = {"while", "scan", "fori"}
    # the fused kernel call is the pallas path's whole eviction machinery —
    # held to the same confinement bar as the lax sort/scan
    confined = ("sort", "scan", "pallas_call")
    for name in CONFINED_POLICIES:
        for backend in BACKENDS:
            closed = _trace_pass(name, backend)
            for eqn, path in _walk_eqns(closed.jaxpr):
                if eqn.primitive.name not in confined:
                    continue
                in_loop = any(p in loops for p in path)
                if not in_loop:
                    continue    # once-per-tick (queue_order / hoisted
                    #             victim_order) sorts are the design
                if eqn.primitive.name == "scan" and "pallas_call" in path:
                    continue    # kernel-internal loops are already confined
                after_loop = path[max(i for i, p in enumerate(path)
                                      if p in loops):]
                if not any(p in ("cond", "switch") for p in after_loop):
                    out.append(Violation(
                        "branch-confinement", str(root / OMFS_JAX), 1,
                        f"policy {name!r} ({backend}): "
                        f"`{eqn.primitive.name}` runs on the always-taken "
                        "path of the per-queue-position loop (ancestry "
                        f"{'->'.join(path)}) — eviction machinery must "
                        "stay behind the lax.cond eviction branch"))
    return out


@register(
    "retrace", "trace",
    "repeat simulate / simulate_matrix and update_state_mib hit the "
    "compilation cache (compile exactly once)")
def check_retrace(root: Path) -> List[Violation]:
    out: List[Violation] = []
    from repro.core import engine, omfs_jax

    users, jobs, cfg, tbl, ent = _fixture()
    horizon = 25
    engine_path = str(root / ENGINE)

    def cache_size(jitted) -> Optional[int]:
        get = getattr(jitted, "_cache_size", None)
        return get() if get is not None else None

    # -- repeat simulate: one compile for two same-shaped calls -------------
    engine.simulate(users, jobs, cfg, horizon, policy="omfs", backend="jax")
    engine.simulate(users, jobs, cfg, horizon, policy="omfs", backend="jax")
    pass_fn = engine.POLICIES["omfs"].jax_factory(None)
    runner = engine._jitted_runner(cfg, pass_fn, horizon)
    n = cache_size(runner)
    if n is not None and n != 1:
        out.append(Violation(
            "retrace", engine_path, 1,
            f"repeat simulate(policy='omfs') compiled {n} times for "
            "same-shaped inputs — expected exactly 1 (a retrace per call "
            "destroys tick throughput)"))

    # -- update_state_mib must not invalidate the compiled scan -------------
    # (the runner donates its input; copy so the cached fixture table's
    # buffers — aliased by the untouched columns — survive)
    tbl2 = omfs_jax.update_state_mib(tbl, 0, 777, cfg)
    runner(engine._copy_table(tbl2), ent)
    n = cache_size(runner)
    if n is not None and n != 1:
        out.append(Violation(
            "retrace", str(root / OMFS_JAX), 1,
            f"update_state_mib triggered a retrace (cache size {n}) — it "
            "must be O(1) scatters with unchanged shapes/dtypes"))

    # -- kernel-backend dispatch: toggling the flag must land on separately
    # cached runners (the config IS the builder key), each compiled exactly
    # once — never a retrace of one runner
    pcfg = _with_backend(cfg, "pallas_interpret")
    engine.simulate(users, jobs, pcfg, horizon, policy="omfs", backend="jax")
    engine.simulate(users, jobs, cfg, horizon, policy="omfs", backend="jax")
    engine.simulate(users, jobs, pcfg, horizon, policy="omfs", backend="jax")
    prunner = engine._jitted_runner(pcfg, pass_fn, horizon)
    if prunner is runner:
        out.append(Violation(
            "retrace", engine_path, 1,
            "kernel_backend='pallas_interpret' resolved to the SAME cached "
            "runner as 'lax' — the flag must key separate builders"))
    for fn, label in ((runner, "lax"), (prunner, "pallas_interpret")):
        n = cache_size(fn)
        if n is not None and n != 1:
            out.append(Violation(
                "retrace", engine_path, 1,
                f"toggling kernel_backend retraced the {label} runner "
                f"(cache size {n}) — each dispatch path must keep its own "
                "compiled program"))

    # -- repeat simulate_matrix: one compile for the whole policy union -----
    names = sorted(engine.POLICIES)
    engine.simulate_matrix(users, jobs, cfg, horizon, names)
    engine.simulate_matrix(users, jobs, cfg, horizon, names)
    pass_fns = tuple(engine.POLICIES[p].jax_factory(None) for p in names)
    mrunner = engine._jitted_matrix_runner(cfg, pass_fns, horizon)
    n = cache_size(mrunner)
    if n is not None and n != 1:
        out.append(Violation(
            "retrace", engine_path, 1,
            f"repeat simulate_matrix compiled {n} times — the policy "
            "matrix must share ONE compiled lax.switch scan"))

    # -- repeat simulate_batch: one compile for the whole sweep grid --------
    cells = [engine.BatchCell(users=users, jobs=jobs, policy="omfs",
                              quantum=q, pass_depth=d)
             for q in (1, 3) for d in (4, None)]
    engine.simulate_batch(cells, cfg, horizon)
    engine.simulate_batch(list(reversed(cells)), cfg, horizon)
    brunner = engine._jitted_batch_runner(
        cfg, (engine.POLICIES["omfs"].jax_factory(None),), horizon, 1)
    n = cache_size(brunner)
    if n is not None and n != 1:
        out.append(Violation(
            "retrace", engine_path, 1,
            f"repeat simulate_batch compiled {n} times — the knobs "
            "(quantum/pass_depth) must ride the batch axis as traced "
            "scalars, ONE program for the whole grid"))

    # -- streaming: N segments, one compile (t0 is traced) ------------------
    from repro.core.workload import arrival_stream
    engine.simulate_stream(users, arrival_stream(jobs), cfg, horizon,
                           capacity=16, segment_len=5)
    srunner = engine._jitted_segment_runner(cfg, pass_fn, 5)
    n = cache_size(srunner)
    if n is not None and n != 1:
        out.append(Violation(
            "retrace", engine_path, 1,
            f"streaming segment runner compiled {n} times across segments "
            "— the segment start tick must stay traced (one program for "
            "the whole stream)"))
    ins = cache_size(omfs_jax.insert_rows)
    if ins is not None and ins > 1:
        out.append(Violation(
            "retrace", str(root / OMFS_JAX), 1,
            f"segment-boundary insert_rows compiled {ins} times — the "
            "compaction scatter must be one fixed-shape program per "
            "capacity"))

    # -- instrumented runners: event capture must not retrace either --------
    from repro.obs.events import lossless_ring_size
    engine.simulate(users, jobs, cfg, horizon, policy="omfs", backend="jax",
                    record_events=True)
    engine.simulate(users, jobs, cfg, horizon, policy="omfs", backend="jax",
                    record_events=True)
    ring = lossless_ring_size(tbl.cpus.shape[0])
    irunner = engine._jitted_runner_events(cfg, pass_fn, horizon, ring)
    n = cache_size(irunner)
    if n is not None and n != 1:
        out.append(Violation(
            "retrace", engine_path, 1,
            f"repeat instrumented simulate compiled {n} times — the event "
            "ring is fixed-shape; capture must add zero retraces"))

    engine.simulate_stream(users, arrival_stream(jobs), cfg, horizon,
                           capacity=16, segment_len=5, record_events=True)
    isrunner = engine._jitted_segment_runner_events(
        cfg, pass_fn, 5, lossless_ring_size(16))
    n = cache_size(isrunner)
    if n is not None and n != 1:
        out.append(Violation(
            "retrace", engine_path, 1,
            f"instrumented streaming segment runner compiled {n} times "
            "across segments — the ring and the traced start tick must "
            "keep it at one compile per (cfg, pass, seg_len, ring)"))

    # -- confinement: instrumentation off means the SAME plain runner -------
    # (the uninstrumented builders must not have been invalidated or
    # duplicated by the capture wiring: their caches still hold exactly one
    # entry each after the instrumented calls above)
    for fn, label in ((runner, "_jitted_runner"),
                      (srunner, "_jitted_segment_runner")):
        n = cache_size(fn)
        if n is not None and n != 1:
            out.append(Violation(
                "retrace", engine_path, 1,
                f"{label} compiled {n} times after instrumented runs — "
                "record_events=True must leave the uninstrumented program "
                "untouched"))
    return out
