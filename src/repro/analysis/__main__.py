"""``python -m repro.analysis`` entry point."""
import sys

from repro.analysis import main

if __name__ == "__main__":
    sys.exit(main())
