"""Rule engine for `repro.analysis`: violations, registry, suppressions.

The analyzer is organized around a flat registry of *rules*.  Each rule is a
function registered under a stable id (the id appears in output, in inline
suppressions, and in the fixture tests) with one of three kinds:

* ``file``    — AST/text checks run per source file (`ast_rules`,
  `concurrency`);
* ``project`` — whole-repo checks that need several files or an import of
  the live registry (`contracts`, `known_failures`);
* ``trace``   — checks that actually trace the jitted passes and inspect
  jaxprs / compilation caches (`jaxpr_audit`).

Suppressions are inline comments on the violating line::

    x = int(flag)   # analysis: ignore[tracer-leak] -- host-side epilogue

and are themselves validated: an unknown rule id, a missing ``-- reason``,
or a suppression that matches no violation is reported under the
``suppression`` rule — a stale suppression cannot silently linger.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Callable, Dict, List, Optional, Sequence


@dataclasses.dataclass(frozen=True)
class Violation:
    """One finding: stable rule id + location + human message."""

    rule: str
    path: str
    line: int
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


@dataclasses.dataclass(frozen=True)
class Rule:
    id: str
    kind: str                  # "file" | "project" | "trace"
    doc: str
    check: Callable


#: rule id -> Rule; populated by the @register decorators at import time.
RULES: Dict[str, Rule] = {}

#: rule ids that only ever surface through other rules (never run directly)
#: but are still valid suppression / reporting targets.
VIRTUAL_RULES = ("suppression",)


def register(rule_id: str, kind: str, doc: str):
    """Register ``fn`` as the checker for ``rule_id``."""
    assert kind in ("file", "project", "trace"), kind

    def deco(fn):
        assert rule_id not in RULES, f"duplicate rule {rule_id}"
        RULES[rule_id] = Rule(rule_id, kind, doc, fn)
        return fn

    return deco


def known_rule_ids() -> List[str]:
    return sorted(set(RULES) | set(VIRTUAL_RULES))


class SourceFile:
    """A parsed source file handed to every file-kind rule."""

    def __init__(self, path: Path, text: Optional[str] = None):
        self.path = Path(path)
        self.text = self.path.read_text() if text is None else text
        self.lines = self.text.splitlines()
        self.tree = ast.parse(self.text, filename=str(path))

    @property
    def name(self) -> str:
        return self.path.name


# ---------------------------------------------------------------------------
# Suppressions
# ---------------------------------------------------------------------------

SUPPRESS_RE = re.compile(
    r"#\s*analysis:\s*ignore\[([A-Za-z0-9_\-, ]+)\]\s*(?:--\s*(\S.*))?$")


@dataclasses.dataclass
class Suppression:
    path: str
    line: int
    rules: tuple
    reason: Optional[str]
    used: bool = False


def _comment_lines(sf: SourceFile) -> Dict[int, str]:
    """line -> comment text, via tokenize (docstrings that *mention* the
    suppression syntax must not register as suppressions)."""
    import io
    import tokenize

    out: Dict[int, str] = {}
    try:
        for tok in tokenize.generate_tokens(io.StringIO(sf.text).readline):
            if tok.type == tokenize.COMMENT:
                out[tok.start[0]] = tok.string
    except tokenize.TokenizeError:
        pass
    return out


def find_suppressions(sf: SourceFile) -> List[Suppression]:
    out = []
    for i, comment in sorted(_comment_lines(sf).items()):
        m = SUPPRESS_RE.search(comment)
        if m:
            rules = tuple(r.strip() for r in m.group(1).split(",") if r.strip())
            out.append(Suppression(str(sf.path), i, rules, m.group(2)))
    return out


def apply_suppressions(
    violations: Sequence[Violation], sups: Sequence[Suppression]
) -> List[Violation]:
    """Drop suppressed violations; emit ``suppression`` violations for
    malformed (unknown rule / missing reason) or unused suppressions."""
    known = set(known_rule_ids())
    by_loc: Dict[tuple, List[Suppression]] = {}
    out: List[Violation] = []
    for s in sups:
        for r in s.rules:
            by_loc.setdefault((s.path, s.line, r), []).append(s)
    for v in violations:
        hits = by_loc.get((v.path, v.line, v.rule), [])
        live = [s for s in hits if s.reason and set(s.rules) <= known]
        if live:
            for s in live:
                s.used = True
        else:
            out.append(v)
    for s in sups:
        bad = [r for r in s.rules if r not in known]
        if bad:
            out.append(Violation(
                "suppression", s.path, s.line,
                f"suppression names unknown rule(s) {', '.join(bad)}; "
                f"known: {', '.join(known_rule_ids())}"))
        elif not s.reason:
            out.append(Violation(
                "suppression", s.path, s.line,
                "suppression is missing its '-- reason' justification"))
        elif not s.used:
            out.append(Violation(
                "suppression", s.path, s.line,
                f"unused suppression for [{', '.join(s.rules)}]: "
                "no violation on this line — delete it"))
    return out


# ---------------------------------------------------------------------------
# AST helpers shared by the rule modules
# ---------------------------------------------------------------------------


def dotted(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for Name/Attribute chains, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def tail(node: ast.AST) -> Optional[str]:
    """Last attribute segment (``c`` for ``a.b.c``), or the bare name."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None
