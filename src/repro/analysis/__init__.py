"""repro.analysis — JAX-aware static analysis + invariant audit.

Run as ``python -m repro.analysis`` from the repo root.  Three layers:

* AST rules (`ast_rules`, `concurrency`): tracer leaks, hidden host syncs,
  integer-cost-grid violations, mutable defaults, thread-boundary races,
  lock ordering — per-file, no imports of the checked code.
* Contract rules (`contracts`, `known_failures`): policy-registry /
  equivalence-suite drift, JobTable column dataflow, the known-failure
  registry — whole-repo, import the live registry.
* Trace rules (`jaxpr_audit`): trace every registered policy pass and
  audit the jaxpr (no int->float casts, eviction machinery confined under
  ``lax.cond``) plus the compile-counter retrace harness.

Inline suppressions: ``# analysis: ignore[rule-id] -- reason`` on the
violating line.  Suppressions without a reason, naming unknown rules, or
matching nothing are violations themselves.
"""
from __future__ import annotations

import argparse
import os
import sys
from pathlib import Path
from typing import Iterable, List, Optional, Tuple

from repro.analysis import (  # noqa: F401  (imports populate RULES)
    ast_rules,
    concurrency,
    contracts,
    event_schema,
    jaxpr_audit,
    known_failures,
)
from repro.analysis.base import (
    RULES,
    SourceFile,
    Suppression,
    Violation,
    apply_suppressions,
    find_suppressions,
)

#: default scan set for file-kind rules
DEFAULT_TARGETS = ("src/repro",)
EXCLUDE_DIRS = {"__pycache__", ".git", "analysis_fixtures"}


def find_root(start: Optional[Path] = None) -> Path:
    """Nearest ancestor that looks like the repo root (has src/repro)."""
    cur = (start or Path.cwd()).resolve()
    for cand in (cur, *cur.parents):
        if (cand / "src" / "repro").is_dir():
            return cand
    return cur


def _iter_py_files(targets: Iterable[Path]) -> List[Path]:
    out: List[Path] = []
    for t in targets:
        if t.is_file() and t.suffix == ".py":
            out.append(t)
        elif t.is_dir():
            for py in sorted(t.rglob("*.py")):
                if not EXCLUDE_DIRS & set(py.parts):
                    out.append(py)
    return out


def _relativize(path: str, root: Path) -> str:
    try:
        return str(Path(path).resolve().relative_to(root))
    except ValueError:
        return path


def collect_violations(
    root: Path,
    targets: Optional[Iterable[Path]] = None,
    include_trace: bool = True,
    include_project: bool = True,
) -> Tuple[List[Violation], List[Suppression]]:
    """All violations (suppressions applied) + the suppression list."""
    raw: List[Violation] = []
    sups: List[Suppression] = []

    files = _iter_py_files(
        [root / t for t in DEFAULT_TARGETS] if targets is None
        else list(targets))
    parsed: List[SourceFile] = []
    for py in files:
        try:
            parsed.append(SourceFile(py))
        except SyntaxError as e:
            raw.append(Violation(
                "syntax", str(py), e.lineno or 1, f"does not parse: {e.msg}"))
    for sf in parsed:
        sups.extend(find_suppressions(sf))
        for rule in RULES.values():
            if rule.kind == "file":
                raw.extend(rule.check(sf))

    for kind, enabled in (("project", include_project),
                          ("trace", include_trace)):
        if not enabled:
            continue
        for rule in RULES.values():
            if rule.kind == kind:
                raw.extend(rule.check(root))

    raw = [Violation(v.rule, _relativize(v.path, root), v.line, v.message)
           for v in raw]
    for s in sups:
        s.path = _relativize(s.path, root)
    return apply_suppressions(raw, sups), sups


def _github_summary(violations: List[Violation]) -> str:
    lines = ["## repro.analysis", ""]
    if not violations:
        lines.append("No violations. :white_check_mark:")
        return "\n".join(lines) + "\n"
    lines += [f"**{len(violations)} violation(s)**", "",
              "| Rule | Location | Message |",
              "| --- | --- | --- |"]
    for v in violations:
        msg = v.message.replace("|", "\\|")
        lines.append(f"| `{v.rule}` | `{v.path}:{v.line}` | {msg} |")
    return "\n".join(lines) + "\n"


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="JAX-aware static analysis for the repro scheduler")
    ap.add_argument("paths", nargs="*", type=Path,
                    help="files/dirs for the AST rules "
                         "(default: src/repro; project/trace rules always "
                         "run against the repo root)")
    ap.add_argument("--no-trace", action="store_true",
                    help="skip the jaxpr/retrace audit (no JAX tracing)")
    ap.add_argument("--no-project", action="store_true",
                    help="skip whole-repo contract rules (fixture mode)")
    ap.add_argument("--format", choices=("text", "github"), default="text")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule registry and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rid in sorted(RULES):
            r = RULES[rid]
            print(f"{rid:22s} {r.kind:8s} {r.doc}")
        return 0

    root = find_root()
    os.chdir(root)
    violations, _ = collect_violations(
        root,
        targets=args.paths or None,
        include_trace=not args.no_trace,
        include_project=not args.no_project,
    )
    violations.sort(key=lambda v: (v.path, v.line, v.rule))

    if args.format == "github":
        print(_github_summary(violations), end="")
    else:
        for v in violations:
            print(v)
    summary_path = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary_path:
        with open(summary_path, "a") as f:
            f.write(_github_summary(violations))

    n_rules = len(RULES)
    if violations:
        print(f"\n{len(violations)} violation(s) across {n_rules} rules.",
              file=sys.stderr)
        return 1
    if args.format == "text":
        print(f"OK: {n_rules} rules, 0 violations.")
    return 0
