"""Known-failure registry: triaged red tests, machine-validated.

`tests/known_failures.toml` lists every test that is *expected* to fail
(the pre-existing Pallas-kernel and multi-device gaps, tracked on the
ROADMAP).  The pytest hook in `tests/conftest.py` turns each entry into a
``strict=True`` xfail, which gives the registry teeth in both directions:

* a listed test that starts **passing** fails the run (stale entry — the
  fix landed, delete the line so the test guards against regressions);
* an unlisted kernel test that starts **failing** fails the run (new
  breakage, not grandfathered).

The ``known-failures`` analysis rule validates the registry itself: TOML
parses, every entry has an ``id`` and a non-empty ``reason``, ids are
unique and well-formed (``path::test``), and the referenced test file
exists on disk.
"""
from __future__ import annotations

from pathlib import Path
from typing import Dict, List

from repro.analysis.base import Violation, register

REGISTRY = Path("tests/known_failures.toml")


def _load_toml(path: Path) -> dict:
    try:
        import tomllib  # py311+
    except ImportError:
        import tomli as tomllib
    with open(path, "rb") as f:
        return tomllib.load(f)


def load_known_failures(root: Path) -> Dict[str, str]:
    """nodeid -> reason.  Raises on malformed registry (conftest wants a
    loud failure, not a silently empty xfail set)."""
    data = _load_toml(root / REGISTRY)
    out: Dict[str, str] = {}
    for entry in data.get("failure", []):
        out[str(entry["id"])] = str(entry.get("reason", ""))
    return out


@register(
    "known-failures", "project",
    "tests/known_failures.toml parses, ids are unique path::test entries "
    "pointing at real test files, every entry carries a reason")
def check_known_failures(root: Path) -> List[Violation]:
    out: List[Violation] = []
    reg_path = root / REGISTRY
    rel = str(reg_path)
    if not reg_path.exists():
        out.append(Violation(
            "known-failures", rel, 1,
            "registry missing — the kernel/multidevice xfail triage lives "
            "here; without it CI can't distinguish triaged red from new "
            "breakage"))
        return out
    try:
        data = _load_toml(reg_path)
    except Exception as e:
        out.append(Violation(
            "known-failures", rel, 1, f"registry does not parse: {e}"))
        return out

    entries = data.get("failure")
    if not isinstance(entries, list) or not entries:
        out.append(Violation(
            "known-failures", rel, 1,
            "registry has no [[failure]] entries"))
        return out

    seen: Dict[str, int] = {}
    for i, entry in enumerate(entries, start=1):
        tag = f"[[failure]] #{i}"
        nodeid = entry.get("id")
        if not isinstance(nodeid, str) or "::" not in nodeid:
            out.append(Violation(
                "known-failures", rel, 1,
                f"{tag}: id must be a 'path::test' pytest nodeid, "
                f"got {nodeid!r}"))
            continue
        if nodeid in seen:
            out.append(Violation(
                "known-failures", rel, 1,
                f"{tag}: duplicate id {nodeid!r} (first at entry "
                f"#{seen[nodeid]})"))
        seen.setdefault(nodeid, i)
        reason = entry.get("reason")
        if not isinstance(reason, str) or not reason.strip():
            out.append(Violation(
                "known-failures", rel, 1,
                f"{tag}: {nodeid!r} has no reason — every triaged failure "
                "must say why it is expected to fail"))
        test_file = nodeid.split("::", 1)[0]
        if not (root / test_file).exists():
            out.append(Violation(
                "known-failures", rel, 1,
                f"{tag}: {nodeid!r} references missing file {test_file!r}"))
        extra = set(entry) - {"id", "reason"}
        if extra:
            out.append(Violation(
                "known-failures", rel, 1,
                f"{tag}: unknown key(s) {sorted(extra)}"))
    return out
