"""Per-architecture sharding rules: DP/FSDP on the (pod, data) axes, TP/EP
on the model axis.

The rules are *path + shape* driven and divisibility-aware: if a dimension
does not divide by its assigned mesh axes, the assignment degrades to
replication for that dim (never a compile error) — e.g. kv_heads=2 < 16
model shards falls back to sharding head_dim instead.  This is what lets a
single rule set serve all 10 assigned architectures on both the (16,16)
single-pod and (2,16,16) multi-pod production meshes.

Conventions:
* default (column-parallel) 2D weight [..., in, out]: in -> FSDP, out -> TP
* row-parallel weights ({w_o, w_down, w_out}): in -> TP, out -> FSDP
* MoE expert stacks [L, E, in, out]: E -> TP (expert parallelism), in -> FSDP
* 1D / norm / scalar leaves: replicated
* activations/batch: batch dim -> (pod, data)
* KV caches: batch -> (pod, data); kv_heads -> TP if divisible else head_dim
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec

ROW_PARALLEL = {"w_o", "w_down", "w_out"}
REPLICATED = {"gate_attn", "gate_ffn", "b_gates", "dt_bias", "d_skip"}


def mesh_axes(mesh: Mesh) -> Tuple[Tuple[str, ...], str]:
    """Returns (dp_axes, tp_axis) for our mesh conventions."""
    names = mesh.axis_names
    if "pod" in names:
        return ("pod", "data"), "model"
    return ("data",), "model"


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def _fits(dim: int, mesh: Mesh, axes) -> bool:
    return dim % _axis_size(mesh, axes) == 0 and dim >= _axis_size(mesh, axes)


def _leaf_spec(path_names, shape, mesh: Mesh) -> P:
    """Sharding rule for one parameter leaf."""
    dp, tp = mesh_axes(mesh)
    name = path_names[-1] if path_names else ""
    rank = len(shape)

    if rank <= 1 or name in REPLICATED:
        return P()

    if name == "embed" or name == "meta":
        # token-gather tables: shard ONLY d_model over TP so the gather is
        # shard-local.  (V, d)-doubly-sharded tables trip a GSPMD
        # dynamic-slice verifier bug when the gather sits inside the
        # grad-accumulation while loop — observed on dbrx-132b.
        spec = [None] * rank
        if _fits(shape[-1], mesh, tp):
            spec[-1] = tp
        return P(*spec)

    # stacked-layer leading dims are never sharded; find the matrix dims
    spec = [None] * rank
    in_dim, out_dim = rank - 2, rank - 1

    is_expert = rank >= 4 and any("ffn" == p or "moe" in p for p in path_names) \
        and name in ("w_gate", "w_up", "w_down")
    if is_expert:
        # [L, E, in, out]: experts over TP
        e_dim = rank - 3
        if _fits(shape[e_dim], mesh, tp):
            spec[e_dim] = tp
        if name in ROW_PARALLEL:
            if _fits(shape[out_dim], mesh, dp):
                spec[out_dim] = dp
        else:
            if _fits(shape[in_dim], mesh, dp):
                spec[in_dim] = dp
        return P(*spec)

    if name.startswith("conv"):
        # depthwise conv [L, W, C]: channels over TP
        if _fits(shape[out_dim], mesh, tp):
            spec[out_dim] = tp
        return P(*spec)

    if name in ROW_PARALLEL:
        if _fits(shape[in_dim], mesh, tp):
            spec[in_dim] = tp
        if _fits(shape[out_dim], mesh, dp):
            spec[out_dim] = dp
    else:
        if _fits(shape[in_dim], mesh, dp):
            spec[in_dim] = dp
        if _fits(shape[out_dim], mesh, tp):
            spec[out_dim] = tp
    return P(*spec)


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            names.append(str(k.key))
        elif isinstance(k, jax.tree_util.GetAttrKey):
            names.append(k.name)
        else:
            names.append(str(k))
    return tuple(names)


def param_shardings(cfg: ModelConfig, param_shapes, mesh: Mesh):
    """NamedSharding pytree matching the parameter (or m/v) pytree."""

    def rule(path, leaf):
        return NamedSharding(mesh, _leaf_spec(_path_names(path), leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(rule, param_shapes)


def batch_shardings(cfg: ModelConfig, batch_shapes, mesh: Mesh):
    dp, _tp = mesh_axes(mesh)

    def rule(path, leaf):
        if leaf.ndim >= 1 and _fits(leaf.shape[0], mesh, dp):
            return NamedSharding(mesh, P(dp, *([None] * (leaf.ndim - 1))))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(rule, batch_shapes)


def cache_shardings(cfg: ModelConfig, cache_shapes, mesh: Mesh):
    """KV/state caches: [L, B, S, heads, hd] -> batch over DP, heads (or
    head_dim / latent dim) over TP."""
    dp, tp = mesh_axes(mesh)

    def rule(path, leaf):
        names = _path_names(path)
        name = names[-1] if names else ""
        shape = leaf.shape
        rank = leaf.ndim
        spec = [None] * rank
        if rank == 0 or name in ("length",):
            return NamedSharding(mesh, P())
        if name == "pos":                       # [B, S]
            if _fits(shape[0], mesh, dp):
                spec[0] = dp
            return NamedSharding(mesh, P(*spec))
        # stacked caches: [L, B, ...]
        if rank >= 2 and _fits(shape[1], mesh, dp):
            spec[1] = dp
        if name in ("k", "v", "xk", "xv") and rank == 5:
            if getattr(cfg, "decode_kv_shard", False) and name in ("k", "v") \
                    and _fits(shape[2], mesh, tp):
                spec[2] = tp                    # sequence-sharded (flash-decode)
            elif _fits(shape[3], mesh, tp):     # kv heads
                spec[3] = tp
            elif _fits(shape[4], mesh, tp):     # fall back to head_dim
                spec[4] = tp
        elif name in ("ckv", "kr") and rank == 4:
            if _fits(shape[3], mesh, tp):       # latent dim
                spec[3] = tp
        elif name in ("ssm_h", "ssm_conv") and rank == 4:
            if _fits(shape[-1 if name == "ssm_conv" else 2], mesh, tp):
                spec[-1 if name == "ssm_conv" else 2] = tp
        elif name in ("c",) and rank == 5:      # mLSTM matrix memory [P,B,H,dh,dh]
            if _fits(shape[2], mesh, tp):
                spec[2] = tp
            elif _fits(shape[3], mesh, tp):
                spec[3] = tp
        elif rank >= 3:
            # generic states ([P,B,H,dh] mlstm n, [P,B,d] slstm, conv tails)
            for d in range(rank - 1, 1, -1):
                if _fits(shape[d], mesh, tp):
                    spec[d] = tp
                    break
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(rule, cache_shapes)


def replicated(mesh: Mesh, tree):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
