"""Explicit collective/manual-partition helpers used where GSPMD's
automatic choices are wrong or buggy.

``embed_lookup``: token-embedding gather done under shard_map — each device
takes rows from its local [V, d/TP] shard for its local [B/DP, S] tokens.
Zero collectives, and it sidesteps a GSPMD dynamic-slice verifier bug that
the auto-partitioned gather trips at dbrx-132b sizes when the gather sits
inside the grad-accumulation loop.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def dp_tp_axes(mesh) -> Tuple[Tuple[str, ...], str]:
    names = mesh.axis_names
    dp = ("pod", "data") if "pod" in names else ("data",)
    return dp, "model"


def _dp_size(mesh, dp) -> int:
    n = 1
    for a in dp:
        n *= mesh.shape[a]
    return n


def embed_lookup(table: jax.Array, tokens: jax.Array, mesh) -> jax.Array:
    """table [V, d] (d sharded over TP), tokens [B, S] (B over DP)
    -> embeddings [B, S, d] (B over DP, d over TP)."""
    dp, tp = dp_tp_axes(mesh)

    def body(tbl, tok):
        return jnp.take(tbl, tok, axis=0)

    return jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(None, tp), P(dp, None)),
        out_specs=P(dp, None, tp),
        check_vma=False,
    )(table, tokens)


def usable_mesh(min_model: int = 2):
    """The ambient abstract mesh if it has a >1 'model' axis, else None.

    `jax.sharding.get_abstract_mesh` is only public from jax 0.5; on older
    runtimes we fall back to the private accessor, and on versions whose
    AbstractMesh lacks `.empty`/`.axis_names` (e.g. 0.4.x returns a bare
    tuple-like) we treat the ambient mesh as absent — computations then run
    unsharded, which is correct on a single-device pool."""
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is None:
        try:
            from jax._src.mesh import get_abstract_mesh as get
        except ImportError:
            return None
    mesh = get()
    if mesh is None or getattr(mesh, "empty", True):
        return None
    if "model" not in mesh.axis_names or mesh.shape["model"] < min_model:
        return None
    return mesh


def sharded_kv_decode_attention(
    q: jax.Array,          # [B, Tq, H, D]
    k_cache: jax.Array,    # [B, S, KVH, D]  (S sharded over TP)
    v_cache: jax.Array,
    k_new: jax.Array,      # [B, Tq, KVH, D]
    v_new: jax.Array,
    q_pos: jax.Array,      # [B, Tq]
    kv_pos: jax.Array,     # [B, S]
    cursor: jax.Array,     # [] int32 write position
    mesh,
):
    """Flash-decoding over the model axis (beyond-paper decode hillclimb).

    Baseline decode shards the KV cache on kv-heads/head-dim, which GSPMD
    resolves with involuntary full rematerialization (replicate the 32k-long
    cache!).  Here the cache is sharded on the *sequence* dim: each TP rank
    writes the new KV if the slot falls in its range (scatter mode="drop"),
    attends over its local S/TP slice, and the partial softmax statistics
    (m, l, acc) are combined with three tiny psums of [B, H]-sized tensors
    instead of moving the cache.

    Returns (out [B, Tq, H, D], k_cache, v_cache) — cache still S-sharded.
    Full attention only (ring/window caches keep the baseline path).
    """
    import math as _math

    dp, tp = dp_tp_axes(mesh)
    tp_size = mesh.shape[tp]
    b, tq, h, d = q.shape
    s = k_cache.shape[1]
    kvh = k_cache.shape[2]
    g = h // kvh
    assert s % tp_size == 0
    s_loc = s // tp_size
    scale = 1.0 / _math.sqrt(d)

    def body(qb, kc, vc, kn, vn, qp, kp, cur):
        # local shapes: kc/vc [B_loc, S_loc, KVH, D]; kp [B_loc, S_loc]
        rank = jax.lax.axis_index(tp)
        # 1. localized cache write (slot may be on another rank -> dropped)
        slot = cur - rank * s_loc
        idx = slot + jnp.arange(kn.shape[1], dtype=jnp.int32)
        kc = kc.at[:, idx].set(kn.astype(kc.dtype), mode="drop")
        vc = vc.at[:, idx].set(vn.astype(vc.dtype), mode="drop")
        kp = kp.at[:, idx].set(qp.astype(kp.dtype), mode="drop")
        # 2. local partial attention
        qr = qb.reshape(b_loc, tq, kvh, g, d)
        sc = jnp.einsum("bqkgd,bskd->bkgqs", qr, kc,
                        preferred_element_type=jnp.float32) * scale
        vis = (kp >= 0)[:, None] & (kp[:, None, :] <= qp[..., None])
        sc = jnp.where(vis[:, None, None], sc, -1e30)
        m_loc = sc.max(axis=-1)                              # [B,KVH,G,Tq]
        p = jnp.exp(sc - m_loc[..., None])
        l_loc = p.sum(axis=-1)
        acc_loc = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(vc.dtype), vc)
        # 3. combine partial softmax statistics across TP
        m = jax.lax.pmax(m_loc, tp)
        corr = jnp.exp(m_loc - m)
        l = jax.lax.psum(l_loc * corr, tp)
        acc = jax.lax.psum(acc_loc.astype(jnp.float32) * corr[..., None], tp)
        out = (acc / jnp.maximum(l, 1e-30)[..., None])
        out = jnp.moveaxis(out, 3, 1).reshape(b_loc, tq, h, d)
        return out.astype(qb.dtype), kc, vc, kp

    dp_size = _dp_size(mesh, dp)
    b_loc = b // dp_size
    out, kc, vc, kp = jax.shard_map(
        body, mesh=mesh,
        in_specs=(
            P(dp, None, None, None),          # q
            P(dp, tp, None, None),            # k_cache (S sharded)
            P(dp, tp, None, None),            # v_cache
            P(dp, None, None, None),          # k_new
            P(dp, None, None, None),          # v_new
            P(dp, None),                      # q_pos
            P(dp, tp),                        # kv_pos
            P(),                              # cursor
        ),
        out_specs=(P(dp, None, None, None), P(dp, tp, None, None),
                   P(dp, tp, None, None), P(dp, tp)),
        check_vma=False,
    )(q, k_cache, v_cache, k_new, v_new, q_pos, kv_pos, cursor)
    return out, kc, vc, kp


def constrain_heads(x: jax.Array, heads_axis: int = 2) -> jax.Array:
    """Sharding constraint for [B, T, H, D]-shaped attention tensors.

    GSPMD's propagation gives up (and fully REPLICATES the downstream score
    tensors — observed 341 GiB/device on hymba-1.5b whose 25/5 heads don't
    divide the 16-way model axis) after the [B,T,H*D] -> [B,T,H,D] reshape.
    Pin: batch -> DP, heads -> TP if divisible else head_dim -> TP.
    No-op without an ambient mesh."""
    mesh = usable_mesh()
    if mesh is None or x.ndim < 3:
        return x
    dp, tp = dp_tp_axes(mesh)
    tp_size = mesh.shape[tp]
    spec = [None] * x.ndim
    if x.shape[0] % _dp_size(mesh, dp) == 0:
        spec[0] = dp
    if x.shape[heads_axis] % tp_size == 0:
        spec[heads_axis] = tp
    elif x.shape[-1] % tp_size == 0:
        spec[-1] = tp
    from jax.sharding import NamedSharding
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))
