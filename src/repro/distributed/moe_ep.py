"""Expert-parallel MoE via shard_map (the production MoE path).

Why this exists: GSPMD cannot partition the sort + ragged_dot dispatch in
``repro.models.moe`` — it falls back to full replication (observed: dbrx-132b
train cell at 366 GiB/device).  This module makes expert parallelism
explicit:

* experts are sharded over the ``model`` axis (E_local = E / TP per rank);
* expert weights are additionally FSDP-sharded over the data axes and
  all-gathered (bf16) just-in-time inside the shard_map body;
* every TP rank routes ALL of its dp-shard's tokens, keeps only the
  (token, slot) pairs owned by its local experts, compacts them to a
  per-expert-capacity buffer (sort by expert + stable compaction — no
  [T, E] one-hot is ever built), runs the grouped ragged_dot, and
* the per-rank partial outputs are combined with one ``psum`` over the
  model axis (each token's top-k experts may live on different ranks).

Per-expert capacity C_e = ceil(T_local * k / E * capacity_factor); overflow
pairs are dropped (standard MoE practice).  With capacity_factor covering
the worst case (C_e >= T_local * k) the path is drop-free and numerically
equivalent to the reference — that equivalence is property-tested.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import MoEConfig
from repro.models.layers import swiglu
from repro.models.moe import load_balance_loss, route_topk


def _dp_tp_axes(mesh) -> Tuple[Tuple[str, ...], str]:
    names = mesh.axis_names
    dp = ("pod", "data") if "pod" in names else ("data",)
    return dp, "model"


def _local_dispatch(
    xf: jax.Array,          # [T, d] local tokens
    weights: jax.Array,     # [T, k] routing weights
    ids: jax.Array,         # [T, k] expert ids (global)
    w_gate: jax.Array,      # [E_loc, d, f] local experts (gathered bf16)
    w_up: jax.Array,
    w_down: jax.Array,
    my_rank: jax.Array,     # [] int32 — this rank's index on the model axis
    e_local: int,
    cap_per_expert: int,
) -> jax.Array:
    """Grouped-FFN over this rank's experts only -> [T, d] partial output."""
    t, k = ids.shape
    d = xf.shape[-1]
    pairs = t * k
    flat_ids = ids.reshape(-1)
    flat_w = weights.reshape(-1)
    local_id = flat_ids - my_rank * e_local
    mine = (local_id >= 0) & (local_id < e_local)

    # sort pairs by (local expert, arrival); foreign pairs pushed to the end
    sort_key = jnp.where(mine, local_id, e_local)
    order = jnp.argsort(sort_key, stable=True)                 # [pairs]
    sorted_ids = sort_key[order]
    counts = jnp.bincount(jnp.where(mine, local_id, e_local), length=e_local + 1)[:e_local]
    start = jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    within = jnp.arange(pairs) - start[jnp.minimum(sorted_ids, e_local - 1)]
    keep = (sorted_ids < e_local) & (within < cap_per_expert)

    # compact kept pairs to the front (stable keeps expert grouping)
    order2 = jnp.argsort(~keep, stable=True)
    cap_total = e_local * cap_per_expert
    sel = order[order2][:cap_total]                            # pair indices
    kept = keep[order2][:cap_total]

    token_src = sel // k
    xs = jnp.take(xf, token_src, axis=0)                       # [cap_total, d]
    xs = jnp.where(kept[:, None], xs, 0).astype(xf.dtype)

    counts_capped = jnp.minimum(counts, cap_per_expert).astype(jnp.int32)
    pad_rows = cap_total - jnp.sum(counts_capped)
    group_sizes = jnp.concatenate(
        [counts_capped, pad_rows[None].astype(jnp.int32)])     # [E_loc + 1]
    zero_e = jnp.zeros((1,) + w_gate.shape[1:], w_gate.dtype)
    wg = jnp.concatenate([w_gate, zero_e], axis=0)
    wu = jnp.concatenate([w_up, zero_e], axis=0)
    zero_d = jnp.zeros((1,) + w_down.shape[1:], w_down.dtype)
    wd = jnp.concatenate([w_down, zero_d], axis=0)

    gate = jax.lax.ragged_dot(xs, wg, group_sizes)
    up = jax.lax.ragged_dot(xs, wu, group_sizes)
    ys = jax.lax.ragged_dot(jax.nn.silu(gate) * up, wd, group_sizes)

    w_sel = jnp.where(kept, flat_w[sel], 0.0)
    out = jnp.zeros((t, d), jnp.float32)
    out = out.at[token_src].add(ys.astype(jnp.float32) * w_sel[:, None])
    return out


def moe_ffn_ep(
    moe: MoEConfig,
    params: dict,
    x: jax.Array,            # [B, S, d] (global view, batch sharded over dp)
    mesh,
    *,
    capacity_factor: float = 1.25,
) -> Tuple[jax.Array, jax.Array]:
    """Expert-parallel MoE FFN.  Returns (y [B,S,d], aux loss scalar)."""
    dp, tp = _dp_tp_axes(mesh)
    tp_size = mesh.shape[tp]
    e = moe.n_routed
    assert e % tp_size == 0, (e, tp_size)
    e_local = e // tp_size
    b, s, d = x.shape
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    t_local = (b // dp_size) * s
    k = moe.top_k
    cap = max(1, math.ceil(t_local * k / e * capacity_factor))

    def body(xb, router_w, w_gate, w_up, w_down):
        # xb: [B_loc, S, d]; w_*: [E_loc, d/dp, f] -> FSDP gather over dp
        my_rank = jax.lax.axis_index(tp)
        xf = xb.reshape(-1, d)
        wg = jax.lax.all_gather(
            w_gate.astype(xb.dtype), dp, axis=1, tiled=True)
        wu = jax.lax.all_gather(w_up.astype(xb.dtype), dp, axis=1, tiled=True)
        wd = jax.lax.all_gather(w_down.astype(xb.dtype), dp, axis=2, tiled=True)
        logits = jnp.einsum(
            "td,de->te", xf.astype(jnp.float32), router_w.astype(jnp.float32))
        weights, ids, probs = route_topk(logits, k)
        aux = load_balance_loss(probs, ids, e) * moe.router_aux_coef
        out = _local_dispatch(
            xf, weights, ids, wg, wu, wd,
            my_rank, e_local, cap)
        out = jax.lax.psum(out, tp)
        aux = jax.lax.pmean(aux, dp)          # identical across tp already
        return out.reshape(xb.shape).astype(xb.dtype), aux

    dp_spec = P(dp, None, None)
    y, aux = jax.shard_map(
        body, mesh=mesh,
        in_specs=(
            dp_spec,                       # x: batch over dp, replicated tp
            P(None, None),                 # router: replicated
            P(tp, dp, None),               # w_gate  [E@tp, d@dp, f]
            P(tp, dp, None),               # w_up
            P(tp, None, dp),               # w_down  [E@tp, f, d@dp]
        ),
        out_specs=(dp_spec, P()),
        check_vma=False,
    )(x, params["router"], params["w_gate"], params["w_up"], params["w_down"])

    if moe.n_shared:
        y = y + swiglu(params["shared"], x)
    return y, aux
